//! Forest → dense tensor packing for the XLA artifact.
//!
//! The compiled executable evaluates a **complete-tree layout**: every tree
//! occupies `2^D - 1` internal slots (`feat`, `thr`) and `2^D` leaf slots
//! (`leaf`), with node `i`'s children at `2i+1` / `2i+2`. The packer embeds
//! arbitrary (≤ depth-D) CART trees into that layout:
//!
//! - internal tree nodes map to their slot's feature/threshold;
//! - when a tree leaf sits above depth `D`, the remaining subtree is filled
//!   with *dummy* nodes (`feature 0`, `threshold +∞` — always routes left,
//!   see the L1 kernel contract) and every leaf slot below inherits the
//!   class, so the padded tree is semantically identical;
//! - forests smaller than the artifact's tree count are **replicated
//!   uniformly** (`k` copies of every tree scales all vote counts by `k`,
//!   preserving the majority vote and its tie-breaks exactly), which
//!   requires the slot count to be a multiple of the forest size;
//! - deeper trees are rejected ([`Error::SchemaMismatch`]) — the serving
//!   router then falls back to the native DD backend rather than silently
//!   changing semantics (DESIGN.md §7).

use super::VariantMeta;
use crate::error::{Error, Result};
use crate::forest::RandomForest;
use crate::tree::{DecisionTree, TreeNode};

/// A forest packed into the artifact tensor layout.
#[derive(Debug, Clone)]
pub struct PackedForest {
    /// `[trees × n_nodes]` feature indices.
    pub feat: Vec<i32>,
    /// `[trees × n_nodes]` thresholds (`+∞` on dummy nodes).
    pub thr: Vec<f32>,
    /// `[trees × n_leaves]` leaf class indices.
    pub leaf: Vec<i32>,
    /// Tree-slot count (matches the artifact).
    pub trees: usize,
    /// Internal slots per tree.
    pub n_nodes: usize,
    /// Leaf slots per tree.
    pub n_leaves: usize,
    /// Replication factor applied (`slots / forest size`).
    pub replication: usize,
}

impl PackedForest {
    /// Pack `forest` for the artifact described by `meta`.
    pub fn pack(forest: &RandomForest, meta: &VariantMeta) -> Result<PackedForest> {
        let n = forest.n_trees();
        if n == 0 {
            return Err(Error::invalid("cannot pack an empty forest"));
        }
        if n > meta.trees {
            return Err(Error::SchemaMismatch(format!(
                "forest has {n} trees, artifact holds {}",
                meta.trees
            )));
        }
        if meta.trees % n != 0 {
            return Err(Error::SchemaMismatch(format!(
                "artifact tree count {} is not a multiple of forest size {n}; \
                 uniform replication would distort the majority vote",
                meta.trees
            )));
        }
        if forest.n_classes() > meta.classes {
            return Err(Error::SchemaMismatch(format!(
                "forest has {} classes, artifact holds {}",
                forest.n_classes(),
                meta.classes
            )));
        }
        if forest.schema.n_features() > meta.features {
            return Err(Error::SchemaMismatch(format!(
                "forest has {} features, artifact holds {}",
                forest.schema.n_features(),
                meta.features
            )));
        }
        for (i, tree) in forest.trees.iter().enumerate() {
            if tree.depth() > meta.depth {
                return Err(Error::SchemaMismatch(format!(
                    "tree {i} has depth {} > artifact depth {} — \
                     retrain with --max-depth {} or use the DD backend",
                    tree.depth(),
                    meta.depth,
                    meta.depth
                )));
            }
        }
        let replication = meta.trees / n;
        let mut packed = PackedForest {
            feat: vec![0; meta.trees * meta.n_nodes],
            thr: vec![f32::INFINITY; meta.trees * meta.n_nodes],
            leaf: vec![0; meta.trees * meta.n_leaves],
            trees: meta.trees,
            n_nodes: meta.n_nodes,
            n_leaves: meta.n_leaves,
            replication,
        };
        for slot in 0..meta.trees {
            let tree = &forest.trees[slot % n];
            packed.pack_tree(slot, tree, meta.depth);
        }
        Ok(packed)
    }

    fn pack_tree(&mut self, slot: usize, tree: &DecisionTree, depth: usize) {
        let feat_base = slot * self.n_nodes;
        let leaf_base = slot * self.n_leaves;
        // (tree node, layout position, level); layout position is the global
        // complete-tree index: children of i are 2i+1 / 2i+2.
        let mut stack: Vec<(Option<u32>, usize, usize, i32)> = vec![(Some(0), 0, 0, 0)];
        while let Some((node, pos, level, inherited)) = stack.pop() {
            let class_here = match node {
                Some(idx) => match tree.nodes[idx as usize] {
                    TreeNode::Split {
                        feature,
                        threshold,
                        left,
                        right,
                    } => {
                        debug_assert!(level < depth);
                        self.feat[feat_base + pos] = feature as i32;
                        self.thr[feat_base + pos] = threshold;
                        stack.push((Some(left), 2 * pos + 1, level + 1, 0));
                        stack.push((Some(right), 2 * pos + 2, level + 1, 0));
                        continue;
                    }
                    TreeNode::Leaf { class } => class as i32,
                },
                None => inherited,
            };
            if level == depth {
                self.leaf[leaf_base + (pos - (self.n_leaves - 1))] = class_here;
            } else {
                // dummy always-left node; both subtrees inherit the class so
                // the reachable (leftmost) leaf — and all others — carry it.
                self.feat[feat_base + pos] = 0;
                self.thr[feat_base + pos] = f32::INFINITY;
                stack.push((None, 2 * pos + 1, level + 1, class_here));
                stack.push((None, 2 * pos + 2, level + 1, class_here));
            }
        }
    }

    /// Validate against an artifact's shape contract.
    pub fn check_compatible(&self, meta: &VariantMeta) -> Result<()> {
        if self.trees != meta.trees || self.n_nodes != meta.n_nodes || self.n_leaves != meta.n_leaves
        {
            return Err(Error::SchemaMismatch(format!(
                "packed forest ({}×{}/{}) does not match artifact ({}×{}/{})",
                self.trees, self.n_nodes, self.n_leaves, meta.trees, meta.n_nodes, meta.n_leaves
            )));
        }
        Ok(())
    }

    /// Reference evaluation of the packed tensors (pure Rust mirror of the
    /// L1 kernel; used to validate packing independently of PJRT).
    pub fn eval_row(&self, x: &[f32], depth: usize, n_classes: usize) -> Vec<u32> {
        let mut votes = vec![0u32; n_classes];
        for t in 0..self.trees {
            let mut pos = 0usize;
            for _ in 0..depth {
                let f = self.feat[t * self.n_nodes + pos] as usize;
                let thr = self.thr[t * self.n_nodes + pos];
                let right = !(x.get(f).copied().unwrap_or(0.0) < thr);
                pos = 2 * pos + 1 + usize::from(right);
            }
            let class = self.leaf[t * self.n_leaves + (pos - (self.n_leaves - 1))];
            votes[class as usize] += 1;
        }
        votes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;
    use crate::forest::ForestLearner;

    fn meta(trees: usize, depth: usize) -> VariantMeta {
        VariantMeta {
            name: "test".into(),
            batch: 4,
            trees,
            depth,
            features: 16,
            classes: 8,
            n_nodes: (1 << depth) - 1,
            n_leaves: 1 << depth,
            hlo_file: "unused".into(),
        }
    }

    #[test]
    fn packed_votes_match_forest_votes() {
        let ds = datasets::iris();
        let forest = ForestLearner::default()
            .trees(16)
            .max_depth(6)
            .seed(3)
            .fit(&ds);
        let m = meta(16, 6);
        let packed = PackedForest::pack(&forest, &m).unwrap();
        assert_eq!(packed.replication, 1);
        for i in 0..ds.n_rows() {
            let x = ds.row(i);
            let votes = packed.eval_row(x, m.depth, forest.n_classes());
            assert_eq!(votes, forest.votes(x), "row {i}");
        }
    }

    #[test]
    fn replication_preserves_majority_exactly() {
        let ds = datasets::iris();
        let forest = ForestLearner::default()
            .trees(8)
            .max_depth(5)
            .seed(9)
            .fit(&ds);
        let m = meta(32, 5); // 4x replication
        let packed = PackedForest::pack(&forest, &m).unwrap();
        assert_eq!(packed.replication, 4);
        for i in (0..ds.n_rows()).step_by(7) {
            let x = ds.row(i);
            let votes = packed.eval_row(x, m.depth, forest.n_classes());
            let base = forest.votes(x);
            let scaled: Vec<u32> = base.iter().map(|v| v * 4).collect();
            assert_eq!(votes, scaled, "row {i}");
        }
    }

    #[test]
    fn rejects_incompatible_forests() {
        let ds = datasets::iris();
        let deep = ForestLearner::default().trees(4).seed(0).fit(&ds);
        // unlimited depth almost surely exceeds 2
        let err = PackedForest::pack(&deep, &meta(4, 2)).unwrap_err();
        assert!(err.to_string().contains("depth"));
        let f8 = ForestLearner::default().trees(8).max_depth(3).seed(0).fit(&ds);
        // 12 % 8 != 0 -> replication would distort votes
        assert!(PackedForest::pack(&f8, &meta(12, 3)).is_err());
        // too many trees
        assert!(PackedForest::pack(&f8, &meta(4, 3)).is_err());
    }

    #[test]
    fn shallow_leaf_padding_is_semantically_inert() {
        // single-leaf tree (pure class 2) padded to depth 3
        let ds = datasets::iris();
        let rows: Vec<usize> = (100..150).collect(); // virginica only
        let pure = ds.select(&rows);
        let forest = ForestLearner::default().trees(2).max_depth(3).seed(1).fit(&pure);
        let m = meta(2, 3);
        let packed = PackedForest::pack(&forest, &m).unwrap();
        for i in 0..10 {
            let votes = packed.eval_row(ds.row(i), 3, pure.n_classes());
            assert_eq!(votes.iter().sum::<u32>(), 2);
            assert_eq!(votes[forest.predict(ds.row(i)) as usize], 2);
        }
    }
}
