//! Deterministic fault injection for the serving stack.
//!
//! Five named injection points cover the failure modes the fault-tolerance
//! layer must absorb:
//!
//! | point              | effect when it fires                                  |
//! |--------------------|-------------------------------------------------------|
//! | `snapshot_load`    | snapshot/bundle load returns an I/O error             |
//! | `eval_shard_panic` | one eval shard panics mid-sweep                       |
//! | `eval_slow`        | one eval shard sleeps [`SLOW_SHARD_MS`] before running|
//! | `conn_read_err`    | a socket read returns `ConnectionReset`               |
//! | `conn_write_short` | a socket write is truncated to at most one byte       |
//!
//! Points are armed from `FOREST_ADD_FAULT` (or `serve --fault`) with a
//! `point:rate:seed` spec, comma-separated for several points at once:
//!
//! ```text
//! FOREST_ADD_FAULT=eval_shard_panic:0.05:42,conn_read_err:0.01:7
//! ```
//!
//! Each point draws from its own counter-stepped splitmix64 stream, so a
//! given `(rate, seed)` pair replays the exact same fire/no-fire sequence
//! run after run — a crash found under injection is reproducible by
//! re-arming the same spec. Draw order across threads is serialised per
//! point by the atomic counter, so the Nth draw at a point is the same
//! regardless of which thread makes it.
//!
//! When nothing is armed every [`fires`] call is a single relaxed atomic
//! load and no allocation — cheap enough to leave the hooks in the warm
//! eval path permanently (`tests/alloc_frozen.rs` pins this).

use std::sync::atomic::{AtomicU64, Ordering};

/// Milliseconds one shard sleeps when `eval_slow` fires.
pub const SLOW_SHARD_MS: u64 = 25;

/// Named injection points. Discriminants index the per-point state tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum Point {
    /// Snapshot / bundle load fails with an I/O error.
    SnapshotLoad = 0,
    /// An eval shard panics at the start of its sweep.
    EvalShardPanic = 1,
    /// An eval shard sleeps [`SLOW_SHARD_MS`] before its sweep.
    EvalSlow = 2,
    /// A connection read errors with `ConnectionReset`.
    ConnReadErr = 3,
    /// A connection write is truncated (partial-write path exercise).
    ConnWriteShort = 4,
}

/// Number of injection points (size of the state tables).
pub const N_POINTS: usize = 5;

/// Every point, in discriminant order.
pub const ALL_POINTS: [Point; N_POINTS] = [
    Point::SnapshotLoad,
    Point::EvalShardPanic,
    Point::EvalSlow,
    Point::ConnReadErr,
    Point::ConnWriteShort,
];

impl Point {
    /// Spec / metrics name of the point.
    pub fn name(self) -> &'static str {
        match self {
            Point::SnapshotLoad => "snapshot_load",
            Point::EvalShardPanic => "eval_shard_panic",
            Point::EvalSlow => "eval_slow",
            Point::ConnReadErr => "conn_read_err",
            Point::ConnWriteShort => "conn_write_short",
        }
    }

    /// Inverse of [`Point::name`].
    pub fn from_name(name: &str) -> Option<Point> {
        ALL_POINTS.iter().copied().find(|p| p.name() == name)
    }
}

/// Bitmask of armed points. The only state the disarmed fast path reads.
static ARMED: AtomicU64 = AtomicU64::new(0);

/// Per-point fire probability, stored as `f64::to_bits`.
static RATE_BITS: [AtomicU64; N_POINTS] = [const { AtomicU64::new(0) }; N_POINTS];

/// Per-point draw counter; the Nth draw hashes `seed`-offset + N.
static DRAWS: [AtomicU64; N_POINTS] = [const { AtomicU64::new(0) }; N_POINTS];

/// Per-point seed, applied as a stream offset into splitmix64.
static SEEDS: [AtomicU64; N_POINTS] = [const { AtomicU64::new(0) }; N_POINTS];

/// Per-point count of draws that fired (exported to `/metrics`).
static FIRED: [AtomicU64; N_POINTS] = [const { AtomicU64::new(0) }; N_POINTS];

/// splitmix64 output function — the same mixer `obs::trace` uses for
/// request ids, duplicated here so the fault stream needs no other module.
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// True when `point` is armed at any rate. One relaxed load.
#[inline]
pub fn armed(point: Point) -> bool {
    ARMED.load(Ordering::Relaxed) & (1u64 << point as usize) != 0
}

/// Draw the next value in `point`'s stream and report whether the fault
/// fires. Disarmed points answer `false` from a single relaxed atomic
/// load without consuming a draw; armed points never allocate either.
#[inline]
pub fn fires(point: Point) -> bool {
    if !armed(point) {
        return false;
    }
    fires_armed(point)
}

/// Cold half of [`fires`], split out so the disarmed fast path stays tiny.
#[cold]
fn fires_armed(point: Point) -> bool {
    let i = point as usize;
    let n = DRAWS[i].fetch_add(1, Ordering::Relaxed);
    let seed = SEEDS[i].load(Ordering::Relaxed);
    let z = splitmix64(seed.wrapping_add(n.wrapping_mul(0x9e37_79b9_7f4a_7c15)));
    // Top 53 bits -> uniform [0, 1), exact in f64.
    let u = (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
    let rate = f64::from_bits(RATE_BITS[i].load(Ordering::Relaxed));
    let fire = u < rate;
    if fire {
        FIRED[i].fetch_add(1, Ordering::Relaxed);
    }
    fire
}

/// Fire the eval-stage points on the calling eval thread: panic
/// (`eval_shard_panic`) or stall for [`SLOW_SHARD_MS`] (`eval_slow`).
/// Serving eval paths call this once per shard (and once per guarded
/// serial batch); disarmed it costs two relaxed loads and never
/// allocates.
#[inline]
pub fn fire_eval_points() {
    if fires(Point::EvalShardPanic) {
        panic!("injected fault: eval_shard_panic");
    }
    if fires(Point::EvalSlow) {
        std::thread::sleep(std::time::Duration::from_millis(SLOW_SHARD_MS));
    }
}

/// Return an injected I/O error for `snapshot_load` when it fires.
/// Snapshot/bundle loaders call this before touching the file.
pub fn snapshot_load_err(path: &str) -> std::io::Result<()> {
    if fires(Point::SnapshotLoad) {
        return Err(std::io::Error::other(format!(
            "injected fault: snapshot_load ({path})"
        )));
    }
    Ok(())
}

/// How many times `point` has fired since the last [`disarm_all`].
pub fn fired(point: Point) -> u64 {
    FIRED[point as usize].load(Ordering::Relaxed)
}

/// Total fires across every point (the `/metrics` `faults_injected` sum).
pub fn fired_total() -> u64 {
    FIRED.iter().map(|c| c.load(Ordering::Relaxed)).sum()
}

/// Parse a `point:rate:seed[,point:rate:seed...]` spec without touching
/// the global tables. Empty spec parses to an empty list.
pub fn parse_spec(spec: &str) -> Result<Vec<(Point, f64, u64)>, String> {
    let mut out = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let mut it = part.splitn(3, ':');
        let name = it.next().unwrap_or("");
        let point = Point::from_name(name)
            .ok_or_else(|| format!("unknown fault point {name:?} in {part:?}"))?;
        let rate: f64 = it
            .next()
            .ok_or_else(|| format!("fault spec {part:?} missing rate (point:rate:seed)"))?
            .parse()
            .map_err(|_| format!("fault spec {part:?} has a non-numeric rate"))?;
        if !(0.0..=1.0).contains(&rate) {
            return Err(format!("fault rate {rate} out of [0, 1] in {part:?}"));
        }
        let seed: u64 = it
            .next()
            .ok_or_else(|| format!("fault spec {part:?} missing seed (point:rate:seed)"))?
            .parse()
            .map_err(|_| format!("fault spec {part:?} has a non-numeric seed"))?;
        out.push((point, rate, seed));
    }
    Ok(out)
}

/// Arm every point named by `spec`, resetting those points' streams and
/// fire counters so the sequence replays from draw zero. Other points
/// keep their state. Errors leave the tables untouched.
pub fn arm(spec: &str) -> Result<(), String> {
    let parsed = parse_spec(spec)?;
    for (point, rate, seed) in parsed {
        let i = point as usize;
        RATE_BITS[i].store(rate.to_bits(), Ordering::Relaxed);
        SEEDS[i].store(seed, Ordering::Relaxed);
        DRAWS[i].store(0, Ordering::Relaxed);
        FIRED[i].store(0, Ordering::Relaxed);
        ARMED.fetch_or(1u64 << i, Ordering::Relaxed);
    }
    Ok(())
}

/// Arm from the `FOREST_ADD_FAULT` environment variable, if set.
/// Invalid specs are reported, not silently ignored.
pub fn arm_from_env() -> Result<(), String> {
    match std::env::var("FOREST_ADD_FAULT") {
        Ok(spec) if !spec.trim().is_empty() => arm(&spec),
        _ => Ok(()),
    }
}

/// Disarm every point and zero all streams and counters.
pub fn disarm_all() {
    ARMED.store(0, Ordering::Relaxed);
    for i in 0..N_POINTS {
        RATE_BITS[i].store(0, Ordering::Relaxed);
        SEEDS[i].store(0, Ordering::Relaxed);
        DRAWS[i].store(0, Ordering::Relaxed);
        FIRED[i].store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_multi_point_specs_and_rejects_bad_ones() {
        let parsed = parse_spec("eval_shard_panic:0.05:42, conn_read_err:1:7").unwrap();
        assert_eq!(
            parsed,
            vec![
                (Point::EvalShardPanic, 0.05, 42),
                (Point::ConnReadErr, 1.0, 7),
            ]
        );
        assert_eq!(parse_spec("").unwrap(), vec![]);
        assert!(parse_spec("warp_core_breach:0.5:1").is_err());
        assert!(parse_spec("eval_slow:1.5:1").is_err());
        assert!(parse_spec("eval_slow:0.5").is_err());
        assert!(parse_spec("eval_slow:x:1").is_err());
        assert!(parse_spec("eval_slow:0.5:y").is_err());
    }

    #[test]
    fn point_names_round_trip() {
        for p in ALL_POINTS {
            assert_eq!(Point::from_name(p.name()), Some(p));
        }
        assert_eq!(Point::from_name("nope"), None);
    }

    // The global tables are process-wide, so every test that arms them
    // lives in this one function to stay race-free under the parallel
    // test runner (no other test in the crate arms faults).
    #[test]
    fn armed_streams_replay_exactly_and_disarm_is_total() {
        disarm_all();
        assert!(!fires(Point::EvalShardPanic), "disarmed points never fire");
        assert_eq!(fired_total(), 0);

        arm("eval_shard_panic:0.25:42").unwrap();
        let first: Vec<bool> = (0..256).map(|_| fires(Point::EvalShardPanic)).collect();
        let fired_first = fired(Point::EvalShardPanic);
        assert!(first.iter().any(|&f| f), "rate 0.25 fires within 256 draws");
        assert!(!first.iter().all(|&f| f), "rate 0.25 also skips draws");
        assert_eq!(fired_first, first.iter().filter(|&&f| f).count() as u64);

        // Re-arming the same spec resets the stream: exact replay.
        arm("eval_shard_panic:0.25:42").unwrap();
        let second: Vec<bool> = (0..256).map(|_| fires(Point::EvalShardPanic)).collect();
        assert_eq!(first, second, "same (rate, seed) replays the same draws");

        // A different seed produces a different sequence.
        arm("eval_shard_panic:0.25:43").unwrap();
        let third: Vec<bool> = (0..256).map(|_| fires(Point::EvalShardPanic)).collect();
        assert_ne!(first, third, "seed selects the stream");

        // Rate 1 always fires; rate 0 never does even while armed.
        arm("conn_read_err:1:7,conn_write_short:0:7").unwrap();
        assert!((0..32).all(|_| fires(Point::ConnReadErr)));
        assert!((0..32).all(|_| !fires(Point::ConnWriteShort)));
        assert!(armed(Point::ConnWriteShort), "rate 0 still counts as armed");

        disarm_all();
        for p in ALL_POINTS {
            assert!(!armed(p));
            assert_eq!(fired(p), 0);
        }
    }
}
