//! XLA/PJRT runtime: load and execute the AOT-compiled forest artifacts.
//!
//! `make artifacts` lowers the L2/L1 JAX+Pallas forest evaluator to **HLO
//! text** (the image's xla_extension 0.5.1 rejects jax≥0.5's serialized
//! protos — see `python/compile/aot.py`). This module:
//!
//! 1. reads the `forest_<variant>.meta.json` sidecar (the shape contract),
//! 2. parses the HLO text and compiles it once on the PJRT CPU client,
//! 3. packs a trained [`RandomForest`] into the artifact's dense
//!    complete-tree tensor layout ([`packing`]),
//! 4. executes batched classification on the request path.
//!
//! Python never runs at request time: after `make artifacts` the Rust
//! binary is self-contained.

pub mod fault;
pub mod mmap;
pub mod packing;
pub mod pool;
pub mod simd;

pub use packing::PackedForest;

use crate::batch::RowMatrix;
use crate::error::{Error, Result};
use crate::util::json::Json;
use std::path::Path;

/// Shape contract of one compiled artifact variant (from `meta.json`).
#[derive(Debug, Clone, PartialEq)]
pub struct VariantMeta {
    /// Variant name (`small`, `base`, `wide`).
    pub name: String,
    /// Fixed batch size of the executable.
    pub batch: usize,
    /// Tree-slot count.
    pub trees: usize,
    /// Complete-tree depth.
    pub depth: usize,
    /// Feature-column count.
    pub features: usize,
    /// Class-slot count.
    pub classes: usize,
    /// Internal node slots per tree (`2^depth - 1`).
    pub n_nodes: usize,
    /// Leaf slots per tree (`2^depth`).
    pub n_leaves: usize,
    /// HLO text file name within the artifacts directory.
    pub hlo_file: String,
}

impl VariantMeta {
    /// Parse a `meta.json` document.
    pub fn from_json(v: &Json) -> Result<VariantMeta> {
        let geti = |k: &str| {
            v.get_i64(k)
                .map(|x| x as usize)
                .ok_or_else(|| Error::parse(format!("meta.json: missing field '{k}'")))
        };
        let meta = VariantMeta {
            name: v
                .get_str("name")
                .ok_or_else(|| Error::parse("meta.json: missing name"))?
                .to_string(),
            batch: geti("batch")?,
            trees: geti("trees")?,
            depth: geti("depth")?,
            features: geti("features")?,
            classes: geti("classes")?,
            n_nodes: geti("n_nodes")?,
            n_leaves: geti("n_leaves")?,
            hlo_file: v
                .get_str("hlo_file")
                .ok_or_else(|| Error::parse("meta.json: missing hlo_file"))?
                .to_string(),
        };
        if meta.n_nodes != (1 << meta.depth) - 1 || meta.n_leaves != 1 << meta.depth {
            return Err(Error::parse(
                "meta.json: node/leaf counts inconsistent with depth",
            ));
        }
        Ok(meta)
    }

    /// Load `forest_<variant>.meta.json` from an artifacts directory.
    pub fn load(artifacts_dir: &str, variant: &str) -> Result<VariantMeta> {
        let path = Path::new(artifacts_dir).join(format!("forest_{variant}.meta.json"));
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Names of all variants listed in `artifacts/index.json`.
    pub fn available(artifacts_dir: &str) -> Result<Vec<String>> {
        let path = Path::new(artifacts_dir).join("index.json");
        let text = std::fs::read_to_string(path)?;
        let v = Json::parse(&text)?;
        v.get("variants")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::parse("index.json: missing variants"))?
            .iter()
            .map(|m| {
                m.get_str("name")
                    .map(String::from)
                    .ok_or_else(|| Error::parse("index.json: variant without name"))
            })
            .collect()
    }
}

/// A compiled PJRT executable for one artifact variant.
///
/// Not `Send`: PJRT client handles live on one thread. The serving layer
/// owns engines on dedicated threads (see `serve::xla_backend`).
pub struct XlaEngine {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    /// The artifact's shape contract.
    pub meta: VariantMeta,
}

impl XlaEngine {
    /// Load + compile `forest_<variant>` from the artifacts directory.
    pub fn load(artifacts_dir: &str, variant: &str) -> Result<XlaEngine> {
        let meta = VariantMeta::load(artifacts_dir, variant)?;
        let hlo_path = Path::new(artifacts_dir).join(&meta.hlo_file);
        let client = xla::PjRtClient::cpu()?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .ok_or_else(|| Error::Runtime("non-UTF-8 artifact path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        crate::log_info!(
            "runtime: compiled variant '{variant}' (B={} T={} D={}) on {}",
            meta.batch,
            meta.trees,
            meta.depth,
            client.platform_name()
        );
        Ok(XlaEngine { client, exe, meta })
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute one fixed-size batch against a packed forest.
    ///
    /// `x` must hold exactly `batch × features` values (row-major). Returns
    /// `(votes, preds)` with `votes` of length `batch × classes`.
    pub fn run(&self, x: &[f32], forest: &PackedForest) -> Result<(Vec<i32>, Vec<i32>)> {
        let m = &self.meta;
        if x.len() != m.batch * m.features {
            return Err(Error::invalid(format!(
                "batch input has {} values, artifact expects {}×{}",
                x.len(),
                m.batch,
                m.features
            )));
        }
        forest.check_compatible(m)?;
        let x_lit = xla::Literal::vec1(x).reshape(&[m.batch as i64, m.features as i64])?;
        let feat = xla::Literal::vec1(&forest.feat)
            .reshape(&[m.trees as i64, m.n_nodes as i64])?;
        let thr = xla::Literal::vec1(&forest.thr)
            .reshape(&[m.trees as i64, m.n_nodes as i64])?;
        let leaf = xla::Literal::vec1(&forest.leaf)
            .reshape(&[m.trees as i64, m.n_leaves as i64])?;
        let result = self.exe.execute::<xla::Literal>(&[x_lit, feat, thr, leaf])?[0][0]
            .to_literal_sync()?;
        let outs = result.to_tuple()?;
        if outs.len() != 2 {
            return Err(Error::Runtime(format!(
                "artifact returned {}-tuple, expected (votes, pred)",
                outs.len()
            )));
        }
        let votes = outs[0].to_vec::<i32>()?;
        let preds = outs[1].to_vec::<i32>()?;
        Ok((votes, preds))
    }

    /// Classify up to `batch` rows by padding the tail with the first row
    /// (fixed-shape executable); returns one class per input row.
    pub fn classify_rows(&self, rows: RowMatrix<'_>, forest: &PackedForest) -> Result<Vec<u32>> {
        let m = &self.meta;
        if rows.is_empty() || rows.n_rows() > m.batch {
            return Err(Error::invalid(format!(
                "row count {} not in 1..={}",
                rows.n_rows(),
                m.batch
            )));
        }
        if rows.n_features() > m.features {
            return Err(Error::SchemaMismatch(format!(
                "rows have {} features, artifact holds {}",
                rows.n_features(),
                m.features
            )));
        }
        let mut x = vec![0f32; m.batch * m.features];
        for (i, row) in rows.iter().enumerate() {
            x[i * m.features..i * m.features + row.len()].copy_from_slice(row);
        }
        // pad remaining slots with row 0 (results discarded)
        for i in rows.n_rows()..m.batch {
            let (head, tail) = x.split_at_mut(i * m.features);
            tail[..m.features].copy_from_slice(&head[..m.features]);
        }
        let (_, preds) = self.run(&x, forest)?;
        Ok(preds[..rows.n_rows()].iter().map(|&p| p as u32).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_json_roundtrip_and_validation() {
        let good = r#"{"name":"base","batch":64,"trees":128,"depth":8,"features":16,
            "classes":8,"n_nodes":255,"n_leaves":256,"hlo_file":"forest_base.hlo.txt"}"#;
        let m = VariantMeta::from_json(&Json::parse(good).unwrap()).unwrap();
        assert_eq!(m.trees, 128);
        assert_eq!(m.n_leaves, 256);
        let bad = good.replace("255", "100");
        assert!(VariantMeta::from_json(&Json::parse(&bad).unwrap()).is_err());
        let missing = r#"{"name":"x"}"#;
        assert!(VariantMeta::from_json(&Json::parse(missing).unwrap()).is_err());
    }

    #[test]
    fn load_reports_missing_artifacts_helpfully() {
        let err = VariantMeta::load("/nonexistent-dir", "base").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
