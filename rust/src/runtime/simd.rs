//! Runtime-dispatched SIMD kernels for the frozen batch sweep.
//!
//! The frozen sweeps ([`crate::frozen`]) route many parked rows through one
//! decision node at a time: load the node's threshold, compare each row's
//! feature value, and select the `lo`/`hi` forward-delta word. That inner
//! step is branchless and data-parallel, so this module vectorises it —
//! [`LANES`] rows per call — with `std::arch` intrinsics picked **once** by
//! runtime CPU-feature detection:
//!
//! - **AVX2** (x86/x86_64): one 8-lane ordered `<` compare + byte blend.
//! - **SSE2** (x86/x86_64): two 4-lane halves, and/andnot select (SSE2 has
//!   no `blendv`).
//! - **NEON** (aarch64): two 4-lane halves, `vclt` + `vbsl` select.
//! - **Scalar**: the portable fallback, also the reference semantics.
//!
//! **Bit-identity is the contract.** Every kernel computes exactly
//! `out[i] = if x[i] < thresh { hi } else { lo }` under IEEE-754 ordered
//! `<`: NaN compares false and takes `lo`, ties and signed zeros behave
//! identically in every lane width. The conformance suite pins every
//! kernel against the scalar walk on every dataset.
//!
//! Selection order: `FOREST_ADD_NO_SIMD` (any value) forces scalar for the
//! process; [`configure`] (driven by `ServeConfig::simd` / `serve
//! --no-simd`) can force scalar at runtime; otherwise the best detected
//! kernel wins. Explicit per-call selection for tests and benches goes
//! through [`Kernel`] parameters on the frozen `*_kernel_into` entry
//! points, sanitised by [`Kernel::supported`] so an unsupported request
//! degrades to a safe kernel instead of faulting.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

/// Rows evaluated per kernel call. The AVX2 kernel fills all eight lanes;
/// the 128-bit kernels split the block into two halves. Gather loops may
/// pass short tails — lanes past the live count hold stale values whose
/// outputs are ignored.
pub const LANES: usize = 8;

/// A batch-evaluation kernel. `Scalar` is always available; the SIMD
/// variants exist on every build (so names/codes are portable) but only
/// execute where [`Kernel::supported`] confirms the CPU feature.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    /// 8-lane AVX2 compare + blend (x86/x86_64).
    Avx2,
    /// 2×4-lane SSE2 compare + and/andnot select (x86/x86_64).
    Sse2,
    /// 2×4-lane NEON compare + bit select (aarch64).
    Neon,
    /// Portable scalar reference path.
    Scalar,
}

impl Kernel {
    /// Stable lowercase name (metrics label, CLI, logs).
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Avx2 => "avx2",
            Kernel::Sse2 => "sse2",
            Kernel::Neon => "neon",
            Kernel::Scalar => "scalar",
        }
    }

    /// Stable numeric code (metrics storage).
    pub fn code(self) -> u8 {
        match self {
            Kernel::Scalar => 0,
            Kernel::Sse2 => 1,
            Kernel::Avx2 => 2,
            Kernel::Neon => 3,
        }
    }

    /// Inverse of [`code`](Self::code); unknown codes read as scalar.
    pub fn from_code(code: u8) -> Kernel {
        match code {
            1 => Kernel::Sse2,
            2 => Kernel::Avx2,
            3 => Kernel::Neon,
            _ => Kernel::Scalar,
        }
    }

    /// Parse a kernel name (`avx2 | sse2 | neon | scalar`).
    pub fn parse(s: &str) -> Option<Kernel> {
        match s {
            "avx2" => Some(Kernel::Avx2),
            "sse2" => Some(Kernel::Sse2),
            "neon" => Some(Kernel::Neon),
            "scalar" => Some(Kernel::Scalar),
            _ => None,
        }
    }

    /// This kernel where the CPU supports it, else the best safe
    /// downgrade (AVX2 hosts also run the SSE2 kernel; anything the host
    /// cannot execute degrades to scalar). Every dispatch site sanitises
    /// through here, so a [`Kernel`] from config or tests can never fault.
    pub fn supported(self) -> Kernel {
        match (self, detected()) {
            (Kernel::Scalar, _) => Kernel::Scalar,
            (k, d) if k == d => k,
            (Kernel::Sse2, Kernel::Avx2) => Kernel::Sse2,
            _ => Kernel::Scalar,
        }
    }
}

/// One-time CPU probe: the widest kernel this host can execute.
fn probe() -> Kernel {
    #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return Kernel::Avx2;
        }
        if std::arch::is_x86_feature_detected!("sse2") {
            return Kernel::Sse2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Kernel::Neon;
        }
    }
    Kernel::Scalar
}

/// The detected hardware kernel (cached; ignores overrides).
pub fn detected() -> Kernel {
    static K: OnceLock<Kernel> = OnceLock::new();
    *K.get_or_init(probe)
}

/// Every kernel this host can execute, widest first (always ends with
/// `Scalar`). Conformance sweeps iterate this so each supported kernel is
/// pinned bit-identical on the hardware actually running the tests.
pub fn available() -> Vec<Kernel> {
    let mut v = Vec::new();
    #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            v.push(Kernel::Avx2);
        }
        if std::arch::is_x86_feature_detected!("sse2") {
            v.push(Kernel::Sse2);
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            v.push(Kernel::Neon);
        }
    }
    v.push(Kernel::Scalar);
    v
}

/// Runtime force-scalar override (set from `ServeConfig::simd`).
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// `false` when `FOREST_ADD_NO_SIMD` is set (any value, read once) or
/// [`configure`] disabled SIMD — mirrors `runtime::mmap::enabled`.
pub fn enabled() -> bool {
    static ENV_OK: OnceLock<bool> = OnceLock::new();
    *ENV_OK.get_or_init(|| std::env::var_os("FOREST_ADD_NO_SIMD").is_none())
        && !FORCE_SCALAR.load(Ordering::Relaxed)
}

/// Enable/disable the SIMD kernels process-wide (the env kill switch
/// still wins); returns the kernel now in effect. Called by the server
/// at startup from `ServeConfig::simd`.
pub fn configure(simd: bool) -> Kernel {
    FORCE_SCALAR.store(!simd, Ordering::Relaxed);
    kernel()
}

/// The kernel ambient eval paths use right now: scalar when disabled,
/// else the detected one.
pub fn kernel() -> Kernel {
    if enabled() {
        detected()
    } else {
        Kernel::Scalar
    }
}

/// Route up to [`LANES`] parked rows through one decision node:
/// `out[i] = if x[i] < thresh { hi } else { lo }` for every lane. All
/// kernels implement IEEE-754 ordered `<` (NaN selects `lo`), so the
/// result is bit-identical to the scalar walk. `lo`/`hi` are opaque
/// words — forward deltas or `TERM_BIT`-tagged terminal refs pass
/// through untouched.
///
/// `kernel` must come from [`kernel`], [`available`] or
/// [`Kernel::supported`]; dispatch sites sanitise once per batch.
#[inline(always)]
pub fn select_deltas(
    kernel: Kernel,
    thresh: f32,
    lo: u32,
    hi: u32,
    x: &[f32; LANES],
    out: &mut [u32; LANES],
) {
    match kernel {
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        // SAFETY: dispatch sites pass kernels sanitised by
        // `Kernel::supported`, so avx2 is present when this arm runs.
        Kernel::Avx2 => unsafe { select_avx2(thresh, lo, hi, x, out) },
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        // SAFETY: as above for sse2 (baseline on x86_64).
        Kernel::Sse2 => unsafe { select_sse2(thresh, lo, hi, x, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above for neon (baseline on aarch64).
        Kernel::Neon => unsafe { select_neon(thresh, lo, hi, x, out) },
        _ => select_scalar(thresh, lo, hi, x, out),
    }
}

/// The reference lane semantics every SIMD kernel must reproduce.
fn select_scalar(thresh: f32, lo: u32, hi: u32, x: &[f32; LANES], out: &mut [u32; LANES]) {
    for (o, &v) in out.iter_mut().zip(x.iter()) {
        *o = if v < thresh { hi } else { lo };
    }
}

#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
#[target_feature(enable = "avx2")]
unsafe fn select_avx2(thresh: f32, lo: u32, hi: u32, x: &[f32; LANES], out: &mut [u32; LANES]) {
    #[cfg(target_arch = "x86")]
    use std::arch::x86::*;
    #[cfg(target_arch = "x86_64")]
    use std::arch::x86_64::*;
    let xv = _mm256_loadu_ps(x.as_ptr());
    let tv = _mm256_set1_ps(thresh);
    // ordered, quiet `<`: a NaN lane yields false, exactly like the
    // scalar walk, so the blend keeps `lo` there
    let mask = _mm256_castps_si256(_mm256_cmp_ps::<_CMP_LT_OQ>(xv, tv));
    let lov = _mm256_set1_epi32(lo as i32);
    let hiv = _mm256_set1_epi32(hi as i32);
    let sel = _mm256_blendv_epi8(lov, hiv, mask);
    _mm256_storeu_si256(out.as_mut_ptr().cast(), sel);
}

#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
#[target_feature(enable = "sse2")]
unsafe fn select_sse2(thresh: f32, lo: u32, hi: u32, x: &[f32; LANES], out: &mut [u32; LANES]) {
    #[cfg(target_arch = "x86")]
    use std::arch::x86::*;
    #[cfg(target_arch = "x86_64")]
    use std::arch::x86_64::*;
    let tv = _mm_set1_ps(thresh);
    let lov = _mm_set1_epi32(lo as i32);
    let hiv = _mm_set1_epi32(hi as i32);
    for half in 0..2 {
        let xv = _mm_loadu_ps(x.as_ptr().add(half * 4));
        // CMPLTPS is the ordered compare: NaN lanes come back false
        let m = _mm_castps_si128(_mm_cmplt_ps(xv, tv));
        // SSE2 has no blendv: (hi & m) | (lo & !m)
        let sel = _mm_or_si128(_mm_and_si128(m, hiv), _mm_andnot_si128(m, lov));
        _mm_storeu_si128(out.as_mut_ptr().add(half * 4).cast(), sel);
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn select_neon(thresh: f32, lo: u32, hi: u32, x: &[f32; LANES], out: &mut [u32; LANES]) {
    use std::arch::aarch64::*;
    let tv = vdupq_n_f32(thresh);
    let lov = vdupq_n_u32(lo);
    let hiv = vdupq_n_u32(hi);
    for half in 0..2 {
        let xv = vld1q_f32(x.as_ptr().add(half * 4));
        // vclt is the ordered compare: NaN lanes come back false
        let m = vcltq_f32(xv, tv);
        let sel = vbslq_u32(m, hiv, lov);
        vst1q_u32(out.as_mut_ptr().add(half * 4), sel);
    }
}

/// Software prefetch of the cache line at `p` into all cache levels — the
/// sweeps hint the next tile's hot records and delta words while the
/// current lane block computes. A no-op where the target has no prefetch
/// instruction; never affects results.
#[inline(always)]
pub fn prefetch<T>(p: *const T) {
    #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
    {
        #[cfg(target_arch = "x86")]
        use std::arch::x86::{_mm_prefetch, _MM_HINT_T0};
        #[cfg(target_arch = "x86_64")]
        use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
        // SAFETY: prefetch is a pure hint; any address is permitted and
        // no memory is dereferenced architecturally.
        unsafe { _mm_prefetch::<_MM_HINT_T0>(p.cast()) };
    }
    #[cfg(not(any(target_arch = "x86", target_arch = "x86_64")))]
    let _ = p;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_codes_and_parse_roundtrip() {
        for k in [Kernel::Avx2, Kernel::Sse2, Kernel::Neon, Kernel::Scalar] {
            assert_eq!(Kernel::parse(k.name()), Some(k));
            assert_eq!(Kernel::from_code(k.code()), k);
        }
        assert_eq!(Kernel::parse("mmx"), None);
        assert_eq!(Kernel::from_code(250), Kernel::Scalar);
    }

    #[test]
    fn detection_is_stable_and_available_ends_scalar() {
        assert_eq!(detected(), detected());
        let avail = available();
        assert_eq!(*avail.last().unwrap(), Kernel::Scalar);
        assert!(avail.contains(&detected()));
        // everything reported available must sanitise to itself
        for &k in &avail {
            assert_eq!(k.supported(), k);
        }
    }

    #[test]
    fn supported_downgrades_never_fault() {
        // whatever the host, an arbitrary request lands on something the
        // host runs (scalar at worst) — and executing it must not trap
        for k in [Kernel::Avx2, Kernel::Sse2, Kernel::Neon, Kernel::Scalar] {
            let safe = k.supported();
            assert!(available().contains(&safe));
            let x = [0.5f32; LANES];
            let mut out = [0u32; LANES];
            select_deltas(safe, 1.0, 7, 9, &x, &mut out);
            assert_eq!(out, [9u32; LANES]);
        }
    }

    #[test]
    fn every_available_kernel_matches_scalar_semantics() {
        // adversarial lane values: NaN (ordered < is false -> lo), ±inf,
        // exact tie with the threshold (strict < -> lo), ±0, subnormals
        let cases: [(f32, [f32; LANES]); 3] = [
            (
                0.5,
                [0.4999, 0.5, 0.5001, f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0, 1e-40],
            ),
            (0.0, [-0.0, 0.0, -1e-40, 1e-40, f32::NAN, -1.0, 1.0, 0.0]),
            (f32::MAX, [f32::MAX, f32::MIN, 0.0, f32::NAN, 1.0, -1.0, 65504.0, -65504.0]),
        ];
        for k in available() {
            for (thresh, x) in &cases {
                let mut got = [0u32; LANES];
                let mut want = [0u32; LANES];
                select_deltas(k, *thresh, 0xdead_0001, 0x8000_0002, x, &mut got);
                select_scalar(*thresh, 0xdead_0001, 0x8000_0002, x, &mut want);
                assert_eq!(got, want, "kernel {} vs scalar at thresh {thresh}", k.name());
            }
        }
    }

    #[test]
    fn configure_forces_scalar_and_back() {
        // bit-identity makes a transient scalar window harmless to any
        // concurrently running eval test
        let before = kernel();
        assert_eq!(configure(false), Kernel::Scalar);
        assert_eq!(kernel(), Kernel::Scalar);
        let restored = configure(true);
        assert_eq!(kernel(), restored);
        // unless the env kill switch pinned the process to scalar, the
        // restored kernel is whatever detection picked originally
        if std::env::var_os("FOREST_ADD_NO_SIMD").is_none() {
            assert_eq!(restored, before);
        }
    }

    #[test]
    fn prefetch_accepts_any_pointer() {
        let v = [1u32, 2, 3];
        prefetch(v.as_ptr());
        prefetch(std::ptr::null::<u64>());
    }
}
