//! Minimal read-only file mapping, std-only (raw `mmap(2)` FFI).
//!
//! The frozen snapshot loader uses this to boot replicas without copying
//! the artifact: the kernel pages the file in on demand and shares the
//! pages across every process serving the same snapshot. No external
//! crate is available offline, so the two syscalls are declared here
//! directly; the surface is deliberately tiny (read-only, whole-file,
//! private mapping).
//!
//! Only built on 64-bit unix — `off_t` is pinned to `i64` there, which
//! keeps the FFI declaration honest. Everywhere else
//! [`supported`] reports `false` and callers fall back to `fs::read`
//! (same bytes, one copy).

/// Whether this build maps snapshot files. When `false`, snapshot loads
/// fall back to a buffered read — identical semantics, one extra copy.
pub const fn supported() -> bool {
    cfg!(all(unix, target_pointer_width = "64"))
}

/// Whether snapshot loads in this *process* take the mmap path:
/// [`supported`] on this target and not disabled via the
/// `FOREST_ADD_NO_MMAP` environment variable. The override exists so CI
/// can exercise the buffered-read (`fs::read`) fallback storage path on
/// hosts where the map would otherwise always succeed; tests that assert
/// on [`crate::frozen::FrozenDD::mapped`] compare against this, not
/// [`supported`].
pub fn enabled() -> bool {
    supported() && std::env::var_os("FOREST_ADD_NO_MMAP").is_none()
}

#[cfg(all(unix, target_pointer_width = "64"))]
mod imp {
    use crate::error::{Error, Result};
    use std::fs::File;
    use std::os::raw::{c_int, c_void};
    use std::os::unix::io::AsRawFd;
    use std::ptr::NonNull;

    // Shared by Linux and the BSDs/macOS.
    const PROT_READ: c_int = 1;
    const MAP_PRIVATE: c_int = 2;
    const MADV_WILLNEED: c_int = 3;

    extern "C" {
        fn mmap(
            addr: *mut c_void,
            length: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        fn munmap(addr: *mut c_void, length: usize) -> c_int;
        fn madvise(addr: *mut c_void, length: usize, advice: c_int) -> c_int;
    }

    /// A read-only private mapping of one whole file, unmapped on drop.
    pub struct Mmap {
        ptr: NonNull<u8>,
        len: usize,
    }

    // SAFETY: the mapping is immutable (PROT_READ, MAP_PRIVATE) and owned
    // exclusively by this value; sharing &Mmap across threads only ever
    // reads the mapped bytes.
    unsafe impl Send for Mmap {}
    unsafe impl Sync for Mmap {}

    impl Mmap {
        /// Map `path` read-only. The file descriptor is closed before
        /// returning; POSIX keeps the mapping valid regardless.
        pub fn map(path: &str) -> Result<Mmap> {
            let file = File::open(path)?;
            let len = file.metadata()?.len();
            let len = usize::try_from(len)
                .map_err(|_| Error::invalid(format!("'{path}' is too large to map")))?;
            if len == 0 {
                // mmap(2) rejects zero-length mappings; an empty file can
                // never be a valid snapshot anyway.
                return Err(Error::parse(format!("'{path}' is empty")));
            }
            let ptr = unsafe {
                mmap(
                    std::ptr::null_mut(),
                    len,
                    PROT_READ,
                    MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            if ptr as isize == -1 {
                return Err(Error::Io(std::io::Error::last_os_error()));
            }
            let ptr = NonNull::new(ptr as *mut u8)
                .ok_or_else(|| Error::Runtime("mmap returned a null mapping".into()))?;
            Ok(Mmap { ptr, len })
        }

        /// Mapped length in bytes (never 0).
        pub fn len(&self) -> usize {
            self.len
        }

        /// Always `false` (zero-length mappings cannot be constructed);
        /// present for API completeness.
        pub fn is_empty(&self) -> bool {
            self.len == 0
        }

        /// Advise the kernel that the whole mapping will be read soon
        /// (`MADV_WILLNEED`), so page-ins start before the first walk
        /// touches them — the bundle boot path calls this once per file
        /// instead of once per model. Purely advisory: failures are
        /// ignored (the mapping stays valid either way).
        pub fn advise_willneed(&self) {
            // SAFETY: exactly the live range returned by mmap in `map`.
            let _ = unsafe {
                madvise(self.ptr.as_ptr() as *mut c_void, self.len, MADV_WILLNEED)
            };
        }

        /// The mapped bytes.
        pub fn as_bytes(&self) -> &[u8] {
            // SAFETY: ptr/len describe a live PROT_READ mapping owned by
            // self; the borrow cannot outlive the unmap in Drop.
            unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
        }
    }

    impl Drop for Mmap {
        fn drop(&mut self) {
            // SAFETY: exactly the range returned by mmap in `map`.
            let _ = unsafe { munmap(self.ptr.as_ptr() as *mut c_void, self.len) };
        }
    }

    impl std::fmt::Debug for Mmap {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "Mmap({} bytes)", self.len)
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn maps_and_reads_a_file() {
            let path = std::env::temp_dir().join(format!("mmap-test-{}", std::process::id()));
            let path_s = path.to_str().unwrap().to_string();
            std::fs::write(&path, b"hello mapping").unwrap();
            let m = Mmap::map(&path_s).unwrap();
            assert_eq!(m.len(), 13);
            assert!(!m.is_empty());
            assert_eq!(m.as_bytes(), b"hello mapping");
            m.advise_willneed(); // advisory: must not disturb the mapping
            assert_eq!(m.as_bytes(), b"hello mapping");
            drop(m);
            // empty and missing files error cleanly
            std::fs::write(&path, b"").unwrap();
            assert!(Mmap::map(&path_s).is_err());
            let _ = std::fs::remove_file(&path);
            assert!(Mmap::map(&path_s).is_err());
            assert!(super::super::supported());
        }
    }
}

#[cfg(all(unix, target_pointer_width = "64"))]
pub use imp::Mmap;
