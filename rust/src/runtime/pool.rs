//! Std-only evaluation worker pool: spawn-once threads, channel fan-out,
//! scoped (borrow-friendly) batch sharding.
//!
//! Large batches are embarrassingly parallel — every row's walk is
//! independent — so the forest and frozen backends shard them across
//! cores behind a size-crossover heuristic. The pool is deliberately
//! minimal (no rayon offline): `N - 1` persistent worker threads drain a
//! shared channel, and [`WorkerPool::run_scoped`] executes a set of
//! borrowed closures with the caller's thread taking one shard, blocking
//! until every shard finished. Blocking before returning is what makes
//! lending non-`'static` closures to the long-lived workers sound: the
//! borrowed batch provably outlives every job that references it.
//!
//! One process-wide pool ([`global`]) is shared by all backends; its
//! size defaults to [`std::thread::available_parallelism`] and is
//! configurable through `ServeConfig::eval_threads` ([`configure`]).

use crate::batch::RowMatrix;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// Queue depth of the job channel. Deep enough that `run_scoped` never
/// blocks on submission in practice; if it ever fills, `send` blocking
/// until a worker drains is still correct (workers never block on jobs).
const QUEUE_DEPTH: usize = 4096;

/// A borrowed shard job. `run_scoped` guarantees it completes before the
/// call returns, so it may capture non-`'static` references.
pub type ScopedJob<'a> = Box<dyn FnOnce() + Send + 'a>;

/// A quarantined shard failure: which shard panicked and what it said.
#[derive(Debug, Clone)]
pub struct ShardPanic {
    /// Index of the failing shard in the submitted job list.
    pub shard: usize,
    /// Panic payload rendered to text (`&str`/`String` payloads kept
    /// verbatim, anything else summarised).
    pub msg: String,
}

/// Render a caught panic payload to text without dropping information
/// for the common `panic!("...")` cases. Shared with the serving router,
/// which catches panics that unwind out of serial (unsharded) eval paths.
pub(crate) fn payload_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

struct Task {
    shard: usize,
    job: Box<dyn FnOnce() + Send + 'static>,
    latch: Arc<Latch>,
}

/// Completion latch: counts outstanding jobs, records the first panic.
struct Latch {
    state: Mutex<(usize, Option<ShardPanic>)>,
    cv: Condvar,
}

impl Latch {
    fn new(jobs: usize) -> Latch {
        Latch {
            state: Mutex::new((jobs, None)),
            cv: Condvar::new(),
        }
    }

    fn done(&self, panic: Option<ShardPanic>) {
        let mut s = self.state.lock().unwrap();
        s.0 -= 1;
        if s.1.is_none() {
            s.1 = panic;
        }
        if s.0 == 0 {
            self.cv.notify_all();
        }
    }

    /// Block until every job finished; returns the first recorded panic.
    fn wait(&self) -> Option<ShardPanic> {
        let mut s = self.state.lock().unwrap();
        while s.0 > 0 {
            s = self.cv.wait(s).unwrap();
        }
        s.1.take()
    }
}

/// A pool of spawn-once worker threads fed over one shared channel.
pub struct WorkerPool {
    tx: Option<SyncSender<Task>>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` threads (0 = a pool that runs everything inline).
    pub fn new(workers: usize) -> WorkerPool {
        let (tx, rx): (SyncSender<Task>, Receiver<Task>) = mpsc::sync_channel(QUEUE_DEPTH);
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let rx = rx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("eval-worker-{w}"))
                    .spawn(move || loop {
                        // Holding the lock across `recv` is the classic
                        // shared-receiver idiom: exactly one idle worker
                        // parks in `recv`, the rest park on the mutex.
                        let task = rx.lock().unwrap().recv();
                        match task {
                            Ok(Task { shard, job, latch }) => {
                                let r = catch_unwind(AssertUnwindSafe(job));
                                latch.done(r.err().map(|p| ShardPanic {
                                    shard,
                                    msg: payload_msg(&*p),
                                }));
                            }
                            Err(_) => return, // pool dropped
                        }
                    })
                    .expect("failed to spawn eval worker"),
            );
        }
        WorkerPool {
            tx: Some(tx),
            handles,
        }
    }

    /// Number of worker threads (total parallelism is `workers() + 1`:
    /// the calling thread always takes a shard).
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Run every job to completion, fanning all but one out to the
    /// workers and executing the remaining one on the calling thread.
    /// Panics (after all jobs finished) with a message naming the first
    /// failing shard and its original payload if any job panicked.
    pub fn run_scoped(&self, jobs: Vec<ScopedJob<'_>>) {
        if let Some(p) = self.run_quarantined(jobs) {
            panic!("eval shard {} panicked: {}", p.shard, p.msg);
        }
    }

    /// [`run_scoped`](WorkerPool::run_scoped) with panic quarantine:
    /// every shard panic is caught (including on the inline path), the
    /// remaining shards still run to completion, and the first failure
    /// comes back as a [`ShardPanic`] instead of unwinding the caller.
    /// Shard index = the job's position in `jobs`.
    pub fn run_quarantined(&self, mut jobs: Vec<ScopedJob<'_>>) -> Option<ShardPanic> {
        let Some(inline) = jobs.pop() else {
            return None;
        };
        let inline_shard = jobs.len();
        if self.workers() == 0 || jobs.is_empty() {
            let mut first: Option<ShardPanic> = None;
            let mut run = |shard: usize, job: ScopedJob<'_>| {
                if let Err(p) = catch_unwind(AssertUnwindSafe(job)) {
                    if first.is_none() {
                        first = Some(ShardPanic {
                            shard,
                            msg: payload_msg(&*p),
                        });
                    }
                }
            };
            run(inline_shard, inline);
            for (shard, job) in jobs.into_iter().enumerate() {
                run(shard, job);
            }
            return first;
        }
        let latch = Arc::new(Latch::new(jobs.len()));
        let tx = self.tx.as_ref().expect("pool channel alive while borrowed");
        for (shard, job) in jobs.into_iter().enumerate() {
            // SAFETY: only the lifetime is erased. `latch.wait()` below
            // blocks until the job has run (or the send failed and it ran
            // inline), so everything the job borrows outlives it.
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(job) };
            if let Err(mpsc::SendError(task)) = tx.send(Task {
                shard,
                job,
                latch: latch.clone(),
            }) {
                let r = catch_unwind(AssertUnwindSafe(task.job));
                task.latch.done(r.err().map(|p| ShardPanic {
                    shard: task.shard,
                    msg: payload_msg(&*p),
                }));
            }
        }
        let inline_result = catch_unwind(AssertUnwindSafe(inline));
        let worker_panic = latch.wait();
        match inline_result {
            Err(p) => {
                let inline_panic = ShardPanic {
                    shard: inline_shard,
                    msg: payload_msg(&*p),
                };
                Some(worker_panic.unwrap_or(inline_panic))
            }
            Ok(()) => worker_panic,
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // disconnect: workers drain and exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Requested global parallelism (0 = auto). Read once when the global
/// pool is first built.
static REQUESTED: AtomicUsize = AtomicUsize::new(0);
static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();

/// Parallelism the platform reports (≥ 1).
pub fn default_parallelism() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Hard ceiling on configurable parallelism — a defence against wrapped
/// or absurd requests reaching `WorkerPool::new` (ServeConfig::validate
/// rejects them with a clean error first).
const MAX_EVAL_THREADS: usize = 1024;

/// Set the global pool's total evaluation parallelism (`0` = auto =
/// [`default_parallelism`]) and build it. First effective call wins —
/// the pool spawns once; later calls return the actual size. Called by
/// server startup from `ServeConfig::eval_threads`.
pub fn configure(requested: usize) -> usize {
    if requested != 0 && GLOBAL.get().is_none() {
        REQUESTED.store(requested.min(MAX_EVAL_THREADS), Ordering::Relaxed);
    }
    eval_threads()
}

/// The process-wide evaluation pool (built on first use).
pub fn global() -> &'static WorkerPool {
    GLOBAL.get_or_init(|| {
        let total = match REQUESTED.load(Ordering::Relaxed) {
            0 => default_parallelism(),
            n => n,
        };
        WorkerPool::new(total.saturating_sub(1))
    })
}

/// Total evaluation parallelism of the global pool (workers + caller).
pub fn eval_threads() -> usize {
    global().workers() + 1
}

/// How many shards to cut a batch of `rows` into: at most one per
/// evaluation thread, and never so many that a shard drops below
/// `min_per_shard` rows (fan-out overhead would eat the win).
pub fn shard_count(rows: usize, min_per_shard: usize) -> usize {
    eval_threads().min(rows / min_per_shard.max(1)).max(1)
}

/// Outcome of a quarantined sharded run
/// ([`run_sharded_quarantined`] / [`run_sharded2_quarantined`]).
#[derive(Debug)]
pub enum ShardedRun {
    /// Batch too small to shard — caller takes its serial path.
    TooSmall,
    /// Every shard completed.
    Done,
    /// A shard panicked and was quarantined; the other shards still
    /// completed and their output ranges are valid.
    Quarantined {
        /// The first quarantined failure (shard index + panic text).
        panic: ShardPanic,
        /// Half-open row range whose output the failing shard owned
        /// (its contents are unspecified — re-evaluate before use).
        rows: std::ops::Range<usize>,
    },
}

/// Shard a batch across the global pool: cut `rows` and its parallel
/// output slice into contiguous per-shard chunks (disjoint output ranges
/// ⇒ results bit-identical to the serial order at any thread count), run
/// `body(shard, out_chunk)` for each with the calling thread taking one,
/// and block until all finish. Returns `false` without touching `out`
/// when the batch is too small to shard — callers then take their serial
/// path. This is the one sharding scaffold every batch backend shares.
/// A shard panic unwinds the caller, naming the shard; serving paths
/// that must survive it use [`run_sharded_quarantined`] instead.
pub fn run_sharded<'a, F>(
    rows: RowMatrix<'a>,
    out: &mut [u32],
    min_per_shard: usize,
    body: F,
) -> bool
where
    F: Fn(RowMatrix<'a>, &mut [u32]) + Send + Sync,
{
    match run_sharded_quarantined(rows, out, min_per_shard, body) {
        ShardedRun::TooSmall => false,
        ShardedRun::Done => true,
        ShardedRun::Quarantined { panic, .. } => {
            panic!("eval shard {} panicked: {}", panic.shard, panic.msg)
        }
    }
}

/// [`run_sharded`] with panic quarantine: a panicking shard is caught,
/// the remaining shards complete (their disjoint output chunks stay
/// bit-identical to the serial order), and the caller gets the failing
/// shard's index, panic text, and output row range back as data.
pub fn run_sharded_quarantined<'a, F>(
    rows: RowMatrix<'a>,
    out: &mut [u32],
    min_per_shard: usize,
    body: F,
) -> ShardedRun
where
    F: Fn(RowMatrix<'a>, &mut [u32]) + Send + Sync,
{
    let n_rows = rows.n_rows();
    let shards = shard_count(n_rows, min_per_shard);
    if shards <= 1 {
        return ShardedRun::TooSmall;
    }
    let chunk = n_rows.div_ceil(shards);
    let body = &body;
    let jobs: Vec<ScopedJob<'_>> = out
        .chunks_mut(chunk)
        .enumerate()
        .map(|(i, out_chunk)| {
            let shard = rows.slice(i * chunk, out_chunk.len());
            let job: ScopedJob<'_> = Box::new(move || {
                // Per-shard wall-clock lands in the process-wide shard
                // table (atomics only — the sweep itself stays alloc-free).
                let t0 = std::time::Instant::now();
                body(shard, out_chunk);
                crate::obs::trace::record_shard(i, t0.elapsed().as_micros() as u64);
            });
            job
        })
        .collect();
    crate::obs::trace::note_shard_run(jobs.len());
    match global().run_quarantined(jobs) {
        None => ShardedRun::Done,
        Some(panic) => {
            let start = (panic.shard * chunk).min(n_rows);
            let end = (start + chunk).min(n_rows);
            ShardedRun::Quarantined {
                panic,
                rows: start..end,
            }
        }
    }
}

/// [`run_sharded`] with a second per-row output slice (classes + steps):
/// both are cut into the same contiguous per-shard chunks, so the
/// bit-identity guarantee covers the step counts too. `out_a` and
/// `out_b` must be the same length as the batch.
pub fn run_sharded2<'a, F>(
    rows: RowMatrix<'a>,
    out_a: &mut [u32],
    out_b: &mut [u32],
    min_per_shard: usize,
    body: F,
) -> bool
where
    F: Fn(RowMatrix<'a>, &mut [u32], &mut [u32]) + Send + Sync,
{
    match run_sharded2_quarantined(rows, out_a, out_b, min_per_shard, body) {
        ShardedRun::TooSmall => false,
        ShardedRun::Done => true,
        ShardedRun::Quarantined { panic, .. } => {
            panic!("eval shard {} panicked: {}", panic.shard, panic.msg)
        }
    }
}

/// [`run_sharded2`] with panic quarantine — see
/// [`run_sharded_quarantined`] for the contract.
pub fn run_sharded2_quarantined<'a, F>(
    rows: RowMatrix<'a>,
    out_a: &mut [u32],
    out_b: &mut [u32],
    min_per_shard: usize,
    body: F,
) -> ShardedRun
where
    F: Fn(RowMatrix<'a>, &mut [u32], &mut [u32]) + Send + Sync,
{
    debug_assert_eq!(out_a.len(), rows.n_rows());
    debug_assert_eq!(out_b.len(), rows.n_rows());
    let n_rows = rows.n_rows();
    let shards = shard_count(n_rows, min_per_shard);
    if shards <= 1 {
        return ShardedRun::TooSmall;
    }
    let chunk = n_rows.div_ceil(shards);
    let body = &body;
    let jobs: Vec<ScopedJob<'_>> = out_a
        .chunks_mut(chunk)
        .zip(out_b.chunks_mut(chunk))
        .enumerate()
        .map(|(i, (chunk_a, chunk_b))| {
            let shard = rows.slice(i * chunk, chunk_a.len());
            let job: ScopedJob<'_> = Box::new(move || {
                let t0 = std::time::Instant::now();
                body(shard, chunk_a, chunk_b);
                crate::obs::trace::record_shard(i, t0.elapsed().as_micros() as u64);
            });
            job
        })
        .collect();
    crate::obs::trace::note_shard_run(jobs.len());
    match global().run_quarantined(jobs) {
        None => ShardedRun::Done,
        Some(panic) => {
            let start = (panic.shard * chunk).min(n_rows);
            let end = (start + chunk).min(n_rows);
            ShardedRun::Quarantined {
                panic,
                rows: start..end,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn shards_run_and_results_land() {
        let pool = WorkerPool::new(3);
        assert_eq!(pool.workers(), 3);
        let mut out = vec![0u64; 16];
        {
            let jobs: Vec<ScopedJob<'_>> = out
                .chunks_mut(4)
                .enumerate()
                .map(|(i, chunk)| {
                    let job: ScopedJob<'_> = Box::new(move || {
                        for (k, slot) in chunk.iter_mut().enumerate() {
                            *slot = (i * 4 + k) as u64 * 2;
                        }
                    });
                    job
                })
                .collect();
            pool.run_scoped(jobs);
        }
        let want: Vec<u64> = (0..16).map(|v| v * 2).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn zero_worker_pool_runs_inline() {
        let pool = WorkerPool::new(0);
        let hits = AtomicU64::new(0);
        let jobs: Vec<ScopedJob<'_>> = (0..5)
            .map(|_| {
                let job: ScopedJob<'_> = Box::new(|| {
                    hits.fetch_add(1, Ordering::Relaxed);
                });
                job
            })
            .collect();
        pool.run_scoped(jobs);
        assert_eq!(hits.load(Ordering::Relaxed), 5);
        pool.run_scoped(Vec::new()); // empty job list is a no-op
    }

    #[test]
    fn worker_panic_propagates_after_all_shards_finish() {
        let pool = WorkerPool::new(2);
        let finished = Arc::new(AtomicU64::new(0));
        let result = catch_unwind(AssertUnwindSafe(|| {
            let f1 = finished.clone();
            let f2 = finished.clone();
            let jobs: Vec<ScopedJob<'_>> = vec![
                Box::new(|| panic!("shard boom")),
                Box::new(move || {
                    f1.fetch_add(1, Ordering::Relaxed);
                }),
                Box::new(move || {
                    f2.fetch_add(1, Ordering::Relaxed);
                }),
            ];
            pool.run_scoped(jobs);
        }));
        // Regression: the re-raised panic names the failing shard and
        // carries the original payload text (it used to be a generic
        // "worker-pool shard panicked").
        let payload = result.expect_err("panic must propagate to the caller");
        let msg = payload_msg(&*payload);
        assert!(msg.contains("shard 0"), "message names the shard: {msg}");
        assert!(msg.contains("shard boom"), "payload preserved: {msg}");
        assert_eq!(finished.load(Ordering::Relaxed), 2, "other shards still ran");
        // the pool survives a panicked job
        let ok = AtomicU64::new(0);
        let jobs: Vec<ScopedJob<'_>> = vec![
            Box::new(|| {
                ok.fetch_add(1, Ordering::Relaxed);
            }),
            Box::new(|| {
                ok.fetch_add(1, Ordering::Relaxed);
            }),
        ];
        pool.run_scoped(jobs);
        assert_eq!(ok.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn quarantine_reports_the_panic_as_data_and_completes_the_rest() {
        let pool = WorkerPool::new(2);
        let finished = Arc::new(AtomicU64::new(0));
        let f1 = finished.clone();
        let f2 = finished.clone();
        let jobs: Vec<ScopedJob<'_>> = vec![
            Box::new(move || {
                f1.fetch_add(1, Ordering::Relaxed);
            }),
            Box::new(|| panic!("quarantine me")),
            Box::new(move || {
                f2.fetch_add(1, Ordering::Relaxed);
            }),
        ];
        let p = pool.run_quarantined(jobs).expect("panic must be reported");
        assert_eq!(p.shard, 1);
        assert_eq!(p.msg, "quarantine me");
        assert_eq!(finished.load(Ordering::Relaxed), 2, "other shards still ran");
        // clean runs report nothing
        assert!(pool.run_quarantined(vec![Box::new(|| {})]).is_none());
        assert!(pool.run_quarantined(Vec::new()).is_none());
        // the inline (last) job's panic is quarantined too, with a
        // String payload preserved verbatim
        let p = pool
            .run_quarantined(vec![Box::new(|| {
                std::panic::panic_any("inline 7".to_string())
            })])
            .expect("inline panic must be reported");
        assert_eq!(p.shard, 0);
        assert_eq!(p.msg, "inline 7");
        // zero-worker pools quarantine on the inline-everything path
        let inline_pool = WorkerPool::new(0);
        let ran = AtomicU64::new(0);
        let jobs: Vec<ScopedJob<'_>> = vec![
            Box::new(|| panic!("first")),
            Box::new(|| {
                ran.fetch_add(1, Ordering::Relaxed);
            }),
            Box::new(|| panic!("last")),
        ];
        let p = inline_pool.run_quarantined(jobs).expect("panic reported");
        assert_eq!(ran.load(Ordering::Relaxed), 1, "healthy shard still ran");
        // the inline job (shard 2) runs first on this path, so it is
        // the first recorded failure
        assert_eq!((p.shard, p.msg.as_str()), (2, "last"));
    }

    #[test]
    fn run_sharded_quarantined_names_the_failing_row_range() {
        let cells: Vec<f32> = (0..4096).map(|i| i as f32).collect();
        let rows = RowMatrix::new(&cells, 1).unwrap();
        let mut out = vec![0u32; 4096];
        let outcome = run_sharded_quarantined(rows, &mut out, 64, |shard, out_chunk| {
            if shard.row(0)[0] == 0.0 {
                panic!("poisoned shard");
            }
            for (slot, row) in out_chunk.iter_mut().zip(shard.iter()) {
                *slot = row[0] as u32 + 1;
            }
        });
        match outcome {
            ShardedRun::TooSmall => assert_eq!(eval_threads(), 1),
            ShardedRun::Done => panic!("shard 0 must be quarantined"),
            ShardedRun::Quarantined { panic, rows: range } => {
                assert_eq!(panic.shard, 0);
                assert_eq!(panic.msg, "poisoned shard");
                assert_eq!(range.start, 0);
                assert!(!range.is_empty() && range.end <= 4096);
                // every row outside the quarantined range still computed
                for (i, &v) in out.iter().enumerate().skip(range.end) {
                    assert_eq!(v, i as u32 + 1, "row {i}");
                }
            }
        }
        let mut a = vec![0u32; 4096];
        let mut b = vec![0u32; 4096];
        let outcome = run_sharded2_quarantined(rows, &mut a, &mut b, 64, |shard, ca, cb| {
            if shard.row(0)[0] == 0.0 {
                panic!("poisoned shard");
            }
            for ((sa, sb), row) in ca.iter_mut().zip(cb.iter_mut()).zip(shard.iter()) {
                *sa = row[0] as u32 + 1;
                *sb = row[0] as u32 + 2;
            }
        });
        match outcome {
            ShardedRun::TooSmall => assert_eq!(eval_threads(), 1),
            ShardedRun::Done => panic!("shard 0 must be quarantined"),
            ShardedRun::Quarantined { panic, rows: range } => {
                assert_eq!((panic.shard, range.start), (0, 0));
                for i in range.end..4096 {
                    assert_eq!(a[i], i as u32 + 1, "row {i}");
                    assert_eq!(b[i], i as u32 + 2, "row {i}");
                }
            }
        }
    }

    #[test]
    fn run_sharded_covers_every_row_or_declines() {
        let cells: Vec<f32> = (0..4096).map(|i| i as f32).collect();
        let rows = RowMatrix::new(&cells, 1).unwrap();
        let mut out = vec![0u32; 4096];
        let did = run_sharded(rows, &mut out, 64, |shard, out_chunk| {
            for (slot, row) in out_chunk.iter_mut().zip(shard.iter()) {
                *slot = row[0] as u32 + 1;
            }
        });
        if eval_threads() > 1 {
            assert!(did, "4096 rows must shard on a multicore host");
            for (i, &v) in out.iter().enumerate() {
                assert_eq!(v, i as u32 + 1, "row {i}");
            }
        } else {
            assert!(!did);
        }
        // too small to shard: declines and leaves the output untouched
        let mut small = vec![9u32; 4];
        assert!(!run_sharded(rows.slice(0, 4), &mut small, 64, |_, _| {}));
        assert_eq!(small, vec![9; 4]);
    }

    #[test]
    fn run_sharded2_covers_both_outputs_or_declines() {
        let cells: Vec<f32> = (0..4096).map(|i| i as f32).collect();
        let rows = RowMatrix::new(&cells, 1).unwrap();
        let mut a = vec![0u32; 4096];
        let mut b = vec![0u32; 4096];
        let did = run_sharded2(rows, &mut a, &mut b, 64, |shard, ca, cb| {
            for ((sa, sb), row) in ca.iter_mut().zip(cb.iter_mut()).zip(shard.iter()) {
                *sa = row[0] as u32 + 1;
                *sb = row[0] as u32 + 2;
            }
        });
        if eval_threads() > 1 {
            assert!(did, "4096 rows must shard on a multicore host");
            for i in 0..4096 {
                assert_eq!(a[i], i as u32 + 1, "row {i}");
                assert_eq!(b[i], i as u32 + 2, "row {i}");
            }
        } else {
            assert!(!did);
        }
        let mut sa = vec![9u32; 4];
        let mut sb = vec![9u32; 4];
        assert!(!run_sharded2(rows.slice(0, 4), &mut sa, &mut sb, 64, |_, _, _| {}));
        assert_eq!(sa, vec![9; 4]);
        assert_eq!(sb, vec![9; 4]);
    }

    #[test]
    fn global_pool_and_shard_heuristic() {
        assert!(eval_threads() >= 1);
        assert_eq!(shard_count(0, 256), 1);
        assert_eq!(shard_count(255, 256), 1);
        let k = shard_count(1 << 20, 256);
        assert!((1..=eval_threads()).contains(&k));
        if eval_threads() > 1 {
            assert!(k > 1, "a million rows must shard on a multicore host");
        }
        // configure after the pool exists is a no-op report
        assert_eq!(configure(0), eval_threads());
    }
}
