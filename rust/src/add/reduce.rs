//! Unsatisfiable-path elimination (§5).
//!
//! Symbolic aggregation treats predicates as independent Boolean variables,
//! so the aggregated diagram contains paths whose predicate literals
//! contradict each other semantically (`petallength < 2.45` followed by the
//! false branch of `petallength < 2.7`). This pass rebuilds the diagram
//! under an incremental feasibility store: nodes whose predicate is already
//! entailed (either way) by the path constraints disappear, and every
//! surviving path is satisfiable.
//!
//! Properties (matching the paper's §5 discussion):
//! - **compositional**: sound to apply after every aggregation step — this
//!   is what keeps intermediate diagrams small and makes the approach scale
//!   beyond ~100 trees;
//! - **not a normal form**: results can depend on aggregation order, but
//!   contain no infeasible path and no entailed (semantically redundant)
//!   decision node;
//! - memoised on `(node, store projected onto the node's support)` so
//!   shared cones under equivalent constraint contexts are rebuilt once.
//!
//! The [`Reducer`] keeps its memo **across calls**: during incremental
//! aggregation the diagram after `combine` shares almost its entire
//! structure with the previously reduced diagram, so a persistent cache
//! turns the per-tree reduction from `O(diagram)` into `O(changed cone)` —
//! the difference between hours and seconds at 10,000 trees (see
//! EXPERIMENTS.md §Perf).

use super::{Manager, Monoid, NodeId, Terminal};
use crate::feas::interval::CtxKey;
use crate::feas::IntervalStore;
use crate::predicate::PredicatePool;
use crate::util::fxhash::FxHashMap;
use std::rc::Rc;
use std::sync::Arc;

/// Remove all unsatisfiable paths and entailed decisions from the cone
/// under `root` (one-shot; see [`Reducer`] for the incremental form).
pub fn reduce_feasible<T: Terminal>(mgr: &mut Manager<T>, root: NodeId) -> NodeId {
    Reducer::new(mgr.pool().clone()).reduce(mgr, root)
}

/// Reusable unsat-path eliminator with a persistent memo.
///
/// A `Reducer` is bound to one manager's node-id space: it may be reused
/// across many `reduce` calls on the **same** manager (ids are stable under
/// hash-consing), and must be [`clear`](Reducer::clear)ed when the manager
/// is rebuilt/compacted (ids change) or swapped.
pub struct Reducer {
    pool: Arc<PredicatePool>,
    store: IntervalStore,
    /// (node, store projection on the node's support) -> reduced node
    memo: FxHashMap<(NodeId, CtxKey), NodeId>,
    /// node -> sorted feature set of its cone
    support: FxHashMap<NodeId, Rc<Vec<u32>>>,
}

impl Reducer {
    /// New reducer over a predicate pool.
    pub fn new(pool: Arc<PredicatePool>) -> Reducer {
        Reducer {
            store: IntervalStore::new(pool.domains()),
            pool,
            memo: FxHashMap::default(),
            support: FxHashMap::default(),
        }
    }

    /// Reduce the cone under `root` (same manager across calls!).
    pub fn reduce<T: Terminal>(&mut self, mgr: &mut Manager<T>, root: NodeId) -> NodeId {
        assert!(
            Arc::ptr_eq(mgr.pool(), &self.pool),
            "reducer bound to a different predicate pool"
        );
        debug_assert_eq!(self.store.mark(), 0, "store must be fully unwound");
        self.go(mgr, root)
    }

    /// Entries in the persistent memo (cache-pressure monitoring).
    pub fn cache_len(&self) -> usize {
        self.memo.len()
    }

    /// Drop all cached state (mandatory after a manager rebuild — node ids
    /// are reassigned there).
    pub fn clear(&mut self) {
        self.memo.clear();
        self.support.clear();
    }

    fn support<T: Terminal>(&mut self, mgr: &Manager<T>, id: NodeId) -> Rc<Vec<u32>> {
        if let Some(s) = self.support.get(&id) {
            return s.clone();
        }
        let out: Rc<Vec<u32>> = if id.is_terminal() {
            Rc::new(Vec::new())
        } else {
            let n = mgr.internal(id);
            let f = self.pool.pred(n.level).feature;
            let hi = self.support(mgr, n.hi);
            let lo = self.support(mgr, n.lo);
            let mut merged: Vec<u32> = Vec::with_capacity(hi.len() + lo.len() + 1);
            merged.extend_from_slice(&hi);
            for &x in lo.iter() {
                merged.push(x);
            }
            merged.push(f);
            merged.sort_unstable();
            merged.dedup();
            Rc::new(merged)
        };
        self.support.insert(id, out.clone());
        out
    }

    fn go<T: Terminal>(&mut self, mgr: &mut Manager<T>, id: NodeId) -> NodeId {
        if id.is_terminal() {
            return id;
        }
        let n = mgr.internal(id);
        let pred = self.pool.pred(n.level);
        // Entailed decisions vanish: the path constraints already decide them.
        match self.store.implied(pred) {
            Some(true) => return self.go(mgr, n.hi),
            Some(false) => return self.go(mgr, n.lo),
            None => {}
        }
        let support = self.support(mgr, id);
        let key = (id, self.store.project_ctx(support.iter().copied()));
        if let Some(&r) = self.memo.get(&key) {
            return r;
        }
        let mark = self.store.mark();
        self.store.assume(pred, true);
        let hi = self.go(mgr, n.hi);
        self.store.undo_to(mark);
        self.store.assume(pred, false);
        let lo = self.go(mgr, n.lo);
        self.store.undo_to(mark);
        let out = mgr.mk(n.level, hi, lo);
        self.memo.insert(key, out);
        out
    }
}

/// Feasibility-fused monoid apply: computes `reduce(combine(f, g))`
/// without ever materialising the unreduced product.
///
/// This is the compiler's actual hot path. A plain `combine` followed by a
/// reduction builds the full symbolic product first — including all the
/// infeasible/entailed structure the reduction immediately deletes — and,
/// because the monoid join rewrites **every** terminal, nothing of that
/// work is shareable across aggregation steps. Fusing the interval store
/// into the apply prunes entailed branches *during* the product
/// construction, so per-tree cost tracks the size of the **reduced**
/// result (EXPERIMENTS.md §Perf quantifies the difference).
pub struct FusedCombiner {
    pool: Arc<PredicatePool>,
    store: IntervalStore,
    memo: FxHashMap<(NodeId, NodeId, CtxKey), NodeId>,
    support: FxHashMap<NodeId, std::rc::Rc<Vec<u32>>>,
    /// instrumentation: product-node visits / memo hits / entailed skips
    pub visits: u64,
    /// memo hits
    pub hits: u64,
    /// entailed-predicate short-circuits
    pub skips: u64,
}

impl FusedCombiner {
    /// New fused combiner over a predicate pool.
    pub fn new(pool: Arc<PredicatePool>) -> FusedCombiner {
        FusedCombiner {
            store: IntervalStore::new(pool.domains()),
            pool,
            memo: FxHashMap::default(),
            support: FxHashMap::default(),
            visits: 0,
            hits: 0,
            skips: 0,
        }
    }

    /// `reduce(combine(f, g))` in one pass. `f` and `g` should themselves be
    /// reduced (the aggregation loop maintains this inductively).
    pub fn combine<T: Monoid>(&mut self, mgr: &mut Manager<T>, f: NodeId, g: NodeId) -> NodeId {
        assert!(
            Arc::ptr_eq(mgr.pool(), &self.pool),
            "combiner bound to a different predicate pool"
        );
        // (f, g) memo entries are only valid within one store lineage; the
        // support/memo survive across calls because node ids are stable and
        // keys embed the projected context.
        self.go(mgr, f, g)
    }

    /// Entries in the persistent memo.
    pub fn cache_len(&self) -> usize {
        self.memo.len()
    }

    /// Drop cached state (mandatory after a manager rebuild).
    pub fn clear(&mut self) {
        self.memo.clear();
        self.support.clear();
    }

    /// Drop only the product memo, keeping the (still-valid) support cache.
    ///
    /// Called between aggregation steps: memo entries reference the previous
    /// accumulator/tree nodes, which can never recur, so keeping them only
    /// inflates the table (GBs at thousands of trees) and slows every probe.
    pub fn clear_memo(&mut self) {
        self.memo.clear();
    }

    fn support<T: Terminal>(&mut self, mgr: &Manager<T>, id: NodeId) -> std::rc::Rc<Vec<u32>> {
        if let Some(s) = self.support.get(&id) {
            return s.clone();
        }
        let out: std::rc::Rc<Vec<u32>> = if id.is_terminal() {
            std::rc::Rc::new(Vec::new())
        } else {
            let n = mgr.internal(id);
            let fe = self.pool.pred(n.level).feature;
            let hi = self.support(mgr, n.hi);
            let lo = self.support(mgr, n.lo);
            let mut merged: Vec<u32> = Vec::with_capacity(hi.len() + lo.len() + 1);
            merged.extend_from_slice(&hi);
            for &x in lo.iter() {
                merged.push(x);
            }
            merged.push(fe);
            merged.sort_unstable();
            merged.dedup();
            std::rc::Rc::new(merged)
        };
        self.support.insert(id, out.clone());
        out
    }

    fn go<T: Monoid>(&mut self, mgr: &mut Manager<T>, f: NodeId, g: NodeId) -> NodeId {
        self.visits += 1;
        if f.is_terminal() && g.is_terminal() {
            let v = mgr.terminal_value(f).combine(mgr.terminal_value(g));
            return mgr.terminal(v);
        }
        let t = mgr.level(f).min(mgr.level(g));
        let pred = self.pool.pred(t);
        // Entailed tests never materialise in the product.
        match self.store.implied(pred) {
            Some(true) => {
                self.skips += 1;
                let (fh, _) = mgr.cofactors(f, t);
                let (gh, _) = mgr.cofactors(g, t);
                return self.go(mgr, fh, gh);
            }
            Some(false) => {
                self.skips += 1;
                let (_, fl) = mgr.cofactors(f, t);
                let (_, gl) = mgr.cofactors(g, t);
                return self.go(mgr, fl, gl);
            }
            None => {}
        }
        // Context key: store projected onto the union of both supports
        // (merged without allocation — both support sets are sorted).
        let sf = self.support(mgr, f);
        let sg = self.support(mgr, g);
        let key = (f, g, self.store.project_ctx(MergeSorted::new(&sf, &sg)));
        if let Some(&r) = self.memo.get(&key) {
            self.hits += 1;
            return r;
        }
        let (fh, fl) = mgr.cofactors(f, t);
        let (gh, gl) = mgr.cofactors(g, t);
        let mark = self.store.mark();
        self.store.assume(pred, true);
        let hi = self.go(mgr, fh, gh);
        self.store.undo_to(mark);
        self.store.assume(pred, false);
        let lo = self.go(mgr, fl, gl);
        self.store.undo_to(mark);
        let out = mgr.mk(t, hi, lo);
        self.memo.insert(key, out);
        out
    }
}

/// Deduplicating merge of two sorted `u32` slices, without allocation.
struct MergeSorted<'a> {
    a: &'a [u32],
    b: &'a [u32],
}

impl<'a> MergeSorted<'a> {
    fn new(a: &'a [u32], b: &'a [u32]) -> Self {
        MergeSorted { a, b }
    }
}

impl Iterator for MergeSorted<'_> {
    type Item = u32;

    #[inline]
    fn next(&mut self) -> Option<u32> {
        match (self.a.first(), self.b.first()) {
            (Some(&x), Some(&y)) => {
                if x < y {
                    self.a = &self.a[1..];
                    Some(x)
                } else if y < x {
                    self.b = &self.b[1..];
                    Some(y)
                } else {
                    self.a = &self.a[1..];
                    self.b = &self.b[1..];
                    Some(x)
                }
            }
            (Some(&x), None) => {
                self.a = &self.a[1..];
                Some(x)
            }
            (None, Some(&y)) => {
                self.b = &self.b[1..];
                Some(y)
            }
            (None, None) => None,
        }
    }
}

/// Enumerate all root-to-terminal paths of a cone as literal lists
/// (`(level, taken-branch)`); used by tests and the DOT tooling. Paths are
/// capped at `limit` to keep pathological cones enumerable.
pub fn enumerate_paths<T: Terminal>(
    mgr: &Manager<T>,
    root: NodeId,
    limit: usize,
) -> Vec<Vec<(u32, bool)>> {
    let mut out = Vec::new();
    let mut path = Vec::new();
    fn rec<T: Terminal>(
        mgr: &Manager<T>,
        id: NodeId,
        path: &mut Vec<(u32, bool)>,
        out: &mut Vec<Vec<(u32, bool)>>,
        limit: usize,
    ) {
        if out.len() >= limit {
            return;
        }
        if id.is_terminal() {
            out.push(path.clone());
            return;
        }
        let n = mgr.internal(id);
        path.push((n.level, true));
        rec(mgr, n.hi, path, out, limit);
        path.pop();
        path.push((n.level, false));
        rec(mgr, n.lo, path, out, limit);
        path.pop();
    }
    rec(mgr, root, &mut path, &mut out, limit);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::add::{ClassLabel, ClassVector, Manager};
    use crate::feas::dpll::conjunction_sat;
    use crate::predicate::{Domain, Predicate, PredicatePool};

    /// Pool: L0: x0 < 1.0, L1: x0 < 2.0, L2: x1 < 0.0 (all real).
    fn pool() -> Arc<PredicatePool> {
        Arc::new(PredicatePool::from_predicates(
            vec![
                Predicate {
                    feature: 0,
                    threshold: 1.0,
                },
                Predicate {
                    feature: 0,
                    threshold: 2.0,
                },
                Predicate {
                    feature: 1,
                    threshold: 0.0,
                },
            ],
            vec![Domain::Real, Domain::Real],
            2,
        ))
    }

    #[test]
    fn entailed_node_is_removed() {
        let mut m: Manager<ClassLabel> = Manager::new(pool());
        let t0 = m.terminal(0);
        let t1 = m.terminal(1);
        let t2 = m.terminal(2);
        // hi branch of L0 (x0 < 1) contains a test of L1 (x0 < 2) —
        // entailed true, so the L1 node is semantically redundant.
        let redundant = m.mk(1, t1, t2); // x0<2 ? 1 : 2
        let root = m.mk(0, redundant, t0); // x0<1 ? (x0<2 ? 1 : 2) : 0
        let before = m.size(root);
        assert_eq!(before.internal, 2);
        let reduced = reduce_feasible(&mut m, root);
        let after = m.size(reduced);
        assert_eq!(after.internal, 1, "redundant inner test must vanish");
        // semantics preserved on feasible inputs
        for x in [[0.5f32, 0.0], [1.5, 0.0], [2.5, 0.0]] {
            let want = if x[0] < 1.0 { 1 } else { 0 };
            assert_eq!(*m.eval(reduced, &x).0, want, "x={x:?}");
        }
    }

    #[test]
    fn infeasible_branch_is_bypassed() {
        let mut m: Manager<ClassLabel> = Manager::new(pool());
        let t0 = m.terminal(0);
        let t1 = m.terminal(1);
        let t9 = m.terminal(9);
        // lo branch of L1 (x0 >= 2) tests L0 (x0 < 1): entailed false, so
        // its hi child (terminal 9) is unreachable.
        let dead = m.mk(0, t9, t1);
        let root = m.ite(1, t0, dead);
        let reduced = reduce_feasible(&mut m, root);
        // 9 must not appear anywhere in the reduced cone
        let paths = enumerate_paths(&m, reduced, 100);
        for p in &paths {
            let mut id = reduced;
            for &(lvl, taken) in p {
                let n = m.internal(id);
                assert_eq!(n.level, lvl);
                id = if taken { n.hi } else { n.lo };
            }
            assert_ne!(*m.terminal_value(id), 9, "unreachable terminal survived");
        }
    }

    #[test]
    fn all_surviving_paths_are_satisfiable() {
        let pl = pool();
        let mut m: Manager<ClassVector> = Manager::new(pl.clone());
        let a = m.terminal(ClassVector(vec![1, 0]));
        let b = m.terminal(ClassVector(vec![0, 1]));
        let c = m.terminal(ClassVector(vec![2, 2]));
        let n2 = m.mk(2, a, b);
        let n1a = m.mk(1, n2, c);
        let n1b = m.mk(1, b, n2);
        let root = m.mk(0, n1a, n1b);
        let reduced = reduce_feasible(&mut m, root);
        let paths = enumerate_paths(&m, reduced, 1000);
        assert!(!paths.is_empty());
        for path in paths {
            let lits: Vec<(Predicate, bool)> =
                path.iter().map(|&(lvl, v)| (pl.pred(lvl), v)).collect();
            assert!(
                conjunction_sat(pl.domains(), &lits),
                "unsat path survived: {lits:?}"
            );
        }
    }

    #[test]
    fn reduction_is_idempotent() {
        let mut m: Manager<ClassLabel> = Manager::new(pool());
        let t0 = m.terminal(0);
        let t1 = m.terminal(1);
        let inner = m.mk(1, t0, t1);
        let root = m.mk(0, inner, inner);
        let r1 = reduce_feasible(&mut m, root);
        let r2 = reduce_feasible(&mut m, r1);
        assert_eq!(r1, r2);
    }

    #[test]
    fn persistent_reducer_matches_one_shot_and_caches() {
        use crate::data::datasets;
        use crate::forest::ForestLearner;
        use crate::predicate::PredicateOrder;
        let ds = datasets::iris();
        let forest = ForestLearner::default().trees(8).seed(3).fit(&ds);
        let pl = Arc::new(PredicatePool::from_forest(
            &forest,
            PredicateOrder::FeatureThreshold,
        ));
        let mut m1: Manager<ClassVector> = Manager::new(pl.clone());
        let mut m2: Manager<ClassVector> = Manager::new(pl.clone());
        let mut persistent = Reducer::new(pl.clone());
        let mut acc1 = m1.terminal(ClassVector::zero(3));
        let mut acc2 = m2.terminal(ClassVector::zero(3));
        for tree in &forest.trees {
            let t1 = m1
                .from_tree(tree, &|c| ClassVector::unit(c as u16, 3))
                .unwrap();
            acc1 = m1.combine(acc1, t1);
            acc1 = persistent.reduce(&mut m1, acc1);
            let t2 = m2
                .from_tree(tree, &|c| ClassVector::unit(c as u16, 3))
                .unwrap();
            acc2 = m2.combine(acc2, t2);
            acc2 = reduce_feasible(&mut m2, acc2); // fresh memo each time
        }
        assert!(persistent.cache_len() > 0);
        assert_eq!(m1.size(acc1).total(), m2.size(acc2).total());
        for i in 0..ds.n_rows() {
            assert_eq!(m1.eval(acc1, ds.row(i)).0, m2.eval(acc2, ds.row(i)).0);
        }
        // clear() resets the cache but not correctness
        persistent.clear();
        assert_eq!(persistent.cache_len(), 0);
        let again = persistent.reduce(&mut m1, acc1);
        assert_eq!(again, acc1, "already-reduced diagram is a fixpoint");
    }

    #[test]
    fn preserves_semantics_on_a_learned_forest_diagram() {
        use crate::data::datasets;
        use crate::forest::ForestLearner;
        use crate::predicate::PredicateOrder;
        let ds = datasets::iris();
        let forest = ForestLearner::default().trees(5).seed(3).fit(&ds);
        let pl = Arc::new(PredicatePool::from_forest(
            &forest,
            PredicateOrder::FeatureThreshold,
        ));
        let mut m: Manager<ClassVector> = Manager::new(pl);
        let mut acc = m.terminal(ClassVector::zero(3));
        for tree in &forest.trees {
            let t = m
                .from_tree(tree, &|c| ClassVector::unit(c as u16, 3))
                .unwrap();
            acc = m.combine(acc, t);
        }
        let before = m.size(acc);
        let reduced = reduce_feasible(&mut m, acc);
        let after = m.size(reduced);
        assert!(after.total() <= before.total());
        for i in 0..ds.n_rows() {
            let x = ds.row(i);
            assert_eq!(m.eval(acc, x).0, m.eval(reduced, x).0, "row {i}");
        }
    }

    #[test]
    fn grid_domains_enable_extra_elimination() {
        // Grid {0,1,2}: after x >= 1.5 (i.e. x = 2), the test x < 2.5 is
        // entailed true on the grid but not over the reals.
        let pl = Arc::new(PredicatePool::from_predicates(
            vec![
                Predicate {
                    feature: 0,
                    threshold: 1.5,
                },
                Predicate {
                    feature: 0,
                    threshold: 2.5,
                },
            ],
            vec![Domain::Grid { cardinality: 3 }],
            1,
        ));
        let mut m: Manager<ClassLabel> = Manager::new(pl);
        let t0 = m.terminal(0);
        let t1 = m.terminal(1);
        let t2 = m.terminal(2);
        let inner = m.mk(1, t1, t2); // x < 2.5 ? 1 : 2
        let root = m.mk(0, t0, inner); // x < 1.5 ? 0 : inner
        let reduced = reduce_feasible(&mut m, root);
        assert_eq!(m.size(reduced).internal, 1);
        assert_eq!(*m.eval(reduced, &[2.0]).0, 1);
        assert_eq!(*m.eval(reduced, &[0.0]).0, 0);
    }
}
