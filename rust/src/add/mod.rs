//! Algebraic Decision Diagrams — the ADD-Lib substitute at the core of the
//! paper's aggregation machinery (§3–§4).
//!
//! A [`Manager`] owns a hash-consed node arena over a fixed
//! [`PredicatePool`] (the variable order). Diagrams are canonical for that
//! order: the unique table guarantees that structurally equal cones share
//! nodes, and the ADD reduction rule (`hi == lo ⇒ child`) removes redundant
//! tests, so semantic equality of functions coincides with [`NodeId`]
//! equality within one manager.
//!
//! Operations, mirroring the paper's toolbox:
//! - [`Manager::from_tree`] — the transformation `d(t)` of §3.2 via `ite`,
//! - [`Manager::combine`] — the lifted monoid join (`∘` on words, `+` on
//!   vectors) used for incremental forest aggregation,
//! - [`Manager::map_into`] — lifted monadic transformations (the
//!   majority-vote abstraction `mv` of §4.2, or the word→vector
//!   abstraction),
//! - [`Manager::eval`] — classification with the §6 step-count metric,
//! - [`reduce`](reduce::reduce_feasible) — unsatisfiable-path elimination
//!   (§5),
//! - [`dot`](dot::to_dot) — Graphviz export of the diagrams (Figs. 2–5).

pub mod dot;
pub mod reduce;
pub mod terminal;

pub use terminal::{ClassLabel, ClassVector, ClassWord, Monoid, Terminal};

use crate::error::{Error, Result};
use crate::predicate::PredicatePool;
use crate::util::fxhash::{FxHashMap, FxHashSet};
use std::collections::HashMap;
use std::sync::Arc;

/// Handle to a node within one [`Manager`].
///
/// The high bit tags terminals; the remaining 31 bits index the respective
/// arena. Ids are only meaningful within the manager that produced them.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(u32);

const TERM_BIT: u32 = 1 << 31;

impl NodeId {
    /// True when this id denotes a terminal value.
    #[inline]
    pub fn is_terminal(self) -> bool {
        self.0 & TERM_BIT != 0
    }

    #[inline]
    fn term_index(self) -> usize {
        (self.0 & !TERM_BIT) as usize
    }

    #[inline]
    fn node_index(self) -> usize {
        self.0 as usize
    }
}

/// An internal decision node: tests the pool predicate at `level`; `hi` is
/// the branch where the predicate **holds** (`x[f] < t`), `lo` where it
/// does not.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Internal {
    /// Pool level (= position in the global predicate order).
    pub level: u32,
    /// Child when the predicate holds.
    pub hi: NodeId,
    /// Child when the predicate fails.
    pub lo: NodeId,
}

/// Size of a diagram cone (the paper's Fig. 7 / Table 2 measure).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SizeStats {
    /// Distinct internal (decision) nodes.
    pub internal: usize,
    /// Distinct terminal nodes.
    pub terminals: usize,
}

impl SizeStats {
    /// Internal + terminal node count.
    pub fn total(&self) -> usize {
        self.internal + self.terminals
    }
}

/// Hash-consing ADD manager over terminal co-domain `T`.
#[derive(Debug)]
pub struct Manager<T> {
    pool: Arc<PredicatePool>,
    nodes: Vec<Internal>,
    terminals: Vec<T>,
    term_index: FxHashMap<T, u32>,
    unique: FxHashMap<(u32, NodeId, NodeId), NodeId>,
    combine_cache: FxHashMap<(NodeId, NodeId), NodeId>,
    ite_cache: FxHashMap<(u32, NodeId, NodeId), NodeId>,
}

impl<T: Terminal> Manager<T> {
    /// New empty manager over a predicate pool (the variable order).
    pub fn new(pool: Arc<PredicatePool>) -> Self {
        Manager {
            pool,
            nodes: Vec::new(),
            terminals: Vec::new(),
            term_index: FxHashMap::default(),
            unique: FxHashMap::default(),
            combine_cache: FxHashMap::default(),
            ite_cache: FxHashMap::default(),
        }
    }

    /// The shared predicate pool.
    pub fn pool(&self) -> &Arc<PredicatePool> {
        &self.pool
    }

    /// Total arena sizes `(internal, terminal)` — includes garbage from
    /// intermediate results (see [`Manager::rebuild`] for compaction).
    pub fn arena_sizes(&self) -> (usize, usize) {
        (self.nodes.len(), self.terminals.len())
    }

    /// Intern a terminal value.
    pub fn terminal(&mut self, value: T) -> NodeId {
        if let Some(&i) = self.term_index.get(&value) {
            return NodeId(i | TERM_BIT);
        }
        let i = self.terminals.len() as u32;
        assert!(i < TERM_BIT, "terminal arena overflow");
        self.terminals.push(value.clone());
        self.term_index.insert(value, i);
        NodeId(i | TERM_BIT)
    }

    /// Terminal value of a terminal id.
    pub fn terminal_value(&self, id: NodeId) -> &T {
        debug_assert!(id.is_terminal());
        &self.terminals[id.term_index()]
    }

    /// Internal node data.
    pub fn internal(&self, id: NodeId) -> Internal {
        debug_assert!(!id.is_terminal());
        self.nodes[id.node_index()]
    }

    /// Level of a node; terminals sort below every predicate (`u32::MAX`).
    #[inline]
    pub fn level(&self, id: NodeId) -> u32 {
        if id.is_terminal() {
            u32::MAX
        } else {
            self.nodes[id.node_index()].level
        }
    }

    /// Hash-consed constructor applying the ADD reduction rule.
    pub fn mk(&mut self, level: u32, hi: NodeId, lo: NodeId) -> NodeId {
        if hi == lo {
            return hi;
        }
        debug_assert!(level < self.level(hi) && level < self.level(lo), "level order violated");
        if let Some(&id) = self.unique.get(&(level, hi, lo)) {
            return id;
        }
        let id = NodeId(self.nodes.len() as u32);
        assert!(id.0 < TERM_BIT, "node arena overflow");
        self.nodes.push(Internal { level, hi, lo });
        self.unique.insert((level, hi, lo), id);
        id
    }

    /// Cofactors of `f` with respect to the predicate at `level`:
    /// `(f | pred=true, f | pred=false)`.
    #[inline]
    pub fn cofactors(&self, f: NodeId, level: u32) -> (NodeId, NodeId) {
        if !f.is_terminal() {
            let n = self.nodes[f.node_index()];
            if n.level == level {
                return (n.hi, n.lo);
            }
        }
        (f, f)
    }

    /// `ite(p, g, h)`: the diagram that behaves as `g` when the predicate at
    /// `level` holds and as `h` otherwise. This is the workhorse of the
    /// tree transformation `d(t)` (§3.2); children may test predicates that
    /// precede `level` in the order — they are pushed down recursively so
    /// the result is properly ordered.
    pub fn ite(&mut self, level: u32, g: NodeId, h: NodeId) -> NodeId {
        if g == h {
            return g;
        }
        let key = (level, g, h);
        if let Some(&r) = self.ite_cache.get(&key) {
            return r;
        }
        let t = level.min(self.level(g)).min(self.level(h));
        let res = if t == level {
            // Both children are at or below `level`: select cofactors.
            let (ghi, _) = self.cofactors(g, level);
            let (_, hlo) = self.cofactors(h, level);
            self.mk(level, ghi, hlo)
        } else {
            // Some child tests an earlier predicate: expand it first.
            let (ghi, glo) = self.cofactors(g, t);
            let (hhi, hlo) = self.cofactors(h, t);
            let hi = self.ite(level, ghi, hhi);
            let lo = self.ite(level, glo, hlo);
            self.mk(t, hi, lo)
        };
        self.ite_cache.insert(key, res);
        res
    }

    /// Transform a decision tree into an ADD (`d(t)` of §3.2), mapping leaf
    /// classes into terminals with `leaf`.
    pub fn from_tree<F: Fn(u32) -> T + ?Sized>(
        &mut self,
        tree: &crate::tree::DecisionTree,
        leaf: &F,
    ) -> Result<NodeId> {
        self.from_tree_at(tree, 0, leaf)
    }

    fn from_tree_at<F: Fn(u32) -> T + ?Sized>(
        &mut self,
        tree: &crate::tree::DecisionTree,
        idx: u32,
        leaf: &F,
    ) -> Result<NodeId> {
        match tree.nodes[idx as usize] {
            crate::tree::TreeNode::Leaf { class } => Ok(self.terminal(leaf(class))),
            crate::tree::TreeNode::Split {
                feature,
                threshold,
                left,
                right,
            } => {
                let level = self.pool.level_of(feature, threshold).ok_or_else(|| {
                    Error::invalid(format!(
                        "predicate x{feature} < {threshold} missing from pool"
                    ))
                })?;
                // `left` is the `< threshold` branch = predicate TRUE.
                let g = self.from_tree_at(tree, left, leaf)?;
                let h = self.from_tree_at(tree, right, leaf)?;
                Ok(self.ite(level, g, h))
            }
        }
    }

    /// Evaluate a diagram on a row; returns the terminal value and the
    /// number of decision nodes traversed (the §6 step count for diagrams).
    pub fn eval<'a>(&'a self, root: NodeId, x: &[f32]) -> (&'a T, usize) {
        let mut id = root;
        let mut steps = 0usize;
        while !id.is_terminal() {
            let n = self.nodes[id.node_index()];
            steps += 1;
            id = if self.pool.holds(n.level, x) { n.hi } else { n.lo };
        }
        (self.terminal_value(id), steps)
    }

    /// Node count of the cone rooted at `root`.
    pub fn size(&self, root: NodeId) -> SizeStats {
        let mut seen = FxHashSet::default();
        let mut stats = SizeStats::default();
        let mut stack = vec![root];
        while let Some(id) = stack.pop() {
            if !seen.insert(id) {
                continue;
            }
            if id.is_terminal() {
                stats.terminals += 1;
            } else {
                stats.internal += 1;
                let n = self.nodes[id.node_index()];
                stack.push(n.hi);
                stack.push(n.lo);
            }
        }
        stats
    }

    /// Copy the cone under `root` into another manager over the same pool
    /// (used for garbage-collecting compaction during long aggregations).
    pub fn copy_into(&self, dst: &mut Manager<T>, root: NodeId) -> NodeId {
        assert!(
            Arc::ptr_eq(&self.pool, &dst.pool),
            "managers must share a predicate pool"
        );
        let mut memo: HashMap<NodeId, NodeId> = HashMap::new();
        self.copy_rec(dst, root, &mut memo)
    }

    fn copy_rec(
        &self,
        dst: &mut Manager<T>,
        id: NodeId,
        memo: &mut HashMap<NodeId, NodeId>,
    ) -> NodeId {
        if let Some(&m) = memo.get(&id) {
            return m;
        }
        let out = if id.is_terminal() {
            dst.terminal(self.terminal_value(id).clone())
        } else {
            let n = self.nodes[id.node_index()];
            let hi = self.copy_rec(dst, n.hi, memo);
            let lo = self.copy_rec(dst, n.lo, memo);
            dst.mk(n.level, hi, lo)
        };
        memo.insert(id, out);
        out
    }

    /// Compact: rebuild only the live cone, dropping garbage nodes and all
    /// operation caches. Returns the new manager and translated root.
    pub fn rebuild(&self, root: NodeId) -> (Manager<T>, NodeId) {
        let mut dst = Manager::new(self.pool.clone());
        let root = self.copy_into(&mut dst, root);
        (dst, root)
    }

    /// Lift a monadic transformation over the terminals (§4.2): copy the
    /// structure into `dst` (a manager over co-domain `U`, same pool),
    /// applying `f` to every terminal. Merged terminals collapse the
    /// structure automatically through `mk`'s reduction rule.
    pub fn map_into<U: Terminal>(
        &self,
        dst: &mut Manager<U>,
        root: NodeId,
        f: &impl Fn(&T) -> U,
    ) -> NodeId {
        assert!(
            Arc::ptr_eq(&self.pool, &dst.pool),
            "managers must share a predicate pool"
        );
        let mut memo: HashMap<NodeId, NodeId> = HashMap::new();
        self.map_rec(dst, root, f, &mut memo)
    }

    fn map_rec<U: Terminal>(
        &self,
        dst: &mut Manager<U>,
        id: NodeId,
        f: &impl Fn(&T) -> U,
        memo: &mut HashMap<NodeId, NodeId>,
    ) -> NodeId {
        if let Some(&m) = memo.get(&id) {
            return m;
        }
        let out = if id.is_terminal() {
            dst.terminal(f(self.terminal_value(id)))
        } else {
            let n = self.nodes[id.node_index()];
            let hi = self.map_rec(dst, n.hi, f, memo);
            let lo = self.map_rec(dst, n.lo, f, memo);
            dst.mk(n.level, hi, lo)
        };
        memo.insert(id, out);
        out
    }

    /// Drop all operation caches (unique table stays — it defines identity).
    pub fn clear_caches(&mut self) {
        self.combine_cache.clear();
        self.ite_cache.clear();
    }
}

impl<T: Monoid> Manager<T> {
    /// The lifted monoid join of §3.2/§4.1: terminal-wise `combine` of two
    /// diagrams (concatenation `∘` for words, `+` for vectors). Results are
    /// memoised persistently — incremental aggregation re-uses subresults
    /// across trees.
    pub fn combine(&mut self, f: NodeId, g: NodeId) -> NodeId {
        if f.is_terminal() && g.is_terminal() {
            let v = self
                .terminal_value(f)
                .combine(self.terminal_value(g));
            return self.terminal(v);
        }
        let key = (f, g);
        if let Some(&r) = self.combine_cache.get(&key) {
            return r;
        }
        let t = self.level(f).min(self.level(g));
        let (fh, fl) = self.cofactors(f, t);
        let (gh, gl) = self.cofactors(g, t);
        let hi = self.combine(fh, gh);
        let lo = self.combine(fl, gl);
        let res = self.mk(t, hi, lo);
        self.combine_cache.insert(key, res);
        res
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predicate::{Domain, Predicate, PredicatePool};

    /// Pool with 3 predicates on 2 real features:
    /// L0: x0 < 1.0, L1: x0 < 2.0, L2: x1 < 0.0
    pub(crate) fn tiny_pool() -> Arc<PredicatePool> {
        Arc::new(PredicatePool::from_predicates(
            vec![
                Predicate {
                    feature: 0,
                    threshold: 1.0,
                },
                Predicate {
                    feature: 0,
                    threshold: 2.0,
                },
                Predicate {
                    feature: 1,
                    threshold: 0.0,
                },
            ],
            vec![Domain::Real, Domain::Real],
            2,
        ))
    }

    #[test]
    fn hash_consing_shares_nodes() {
        let mut m: Manager<ClassLabel> = Manager::new(tiny_pool());
        let a = m.terminal(0);
        let b = m.terminal(1);
        let n1 = m.mk(0, a, b);
        let n2 = m.mk(0, a, b);
        assert_eq!(n1, n2);
        assert_eq!(m.arena_sizes().0, 1);
        // terminal interning
        assert_eq!(m.terminal(0), a);
    }

    #[test]
    fn reduction_rule_collapses_equal_children() {
        let mut m: Manager<ClassLabel> = Manager::new(tiny_pool());
        let a = m.terminal(7);
        assert_eq!(m.mk(1, a, a), a);
    }

    #[test]
    fn eval_follows_predicates() {
        let mut m: Manager<ClassLabel> = Manager::new(tiny_pool());
        let t0 = m.terminal(0);
        let t1 = m.terminal(1);
        let t2 = m.terminal(2);
        // x1 < 0 ? c1 : c2, under x0 < 1 ? c0 : ...
        let inner = m.mk(2, t1, t2);
        let root = m.mk(0, t0, inner);
        assert_eq!(m.eval(root, &[0.5, 5.0]), (&0, 1));
        assert_eq!(m.eval(root, &[1.5, -1.0]), (&1, 2));
        assert_eq!(m.eval(root, &[1.5, 1.0]), (&2, 2));
    }

    #[test]
    fn ite_orders_out_of_order_children() {
        let mut m: Manager<ClassLabel> = Manager::new(tiny_pool());
        let t0 = m.terminal(0);
        let t1 = m.terminal(1);
        let t2 = m.terminal(2);
        // g tests level 0, h tests level 1; ite on level 2 must push the
        // level-2 predicate *below* both.
        let g = m.mk(0, t0, t1);
        let h = m.mk(1, t1, t2);
        let r = m.ite(2, g, h);
        assert_eq!(m.level(r), 0);
        // semantics: pred2(x) = x1 < 0 selects g else h
        for (x, want) in [
            ([0.5f32, -1.0], 0), // pred2 true -> g; x0<1 -> 0
            ([1.5, -1.0], 1),    // pred2 true -> g; !(x0<1) -> 1
            ([0.5, 1.0], 1),     // pred2 false -> h; x0<2 -> 1
            ([2.5, 1.0], 2),     // pred2 false -> h; !(x0<2) -> 2
        ] {
            assert_eq!(*m.eval(r, &x).0, want, "x={x:?}");
        }
    }

    #[test]
    fn ite_canonical_same_function_same_id() {
        let mut m: Manager<ClassLabel> = Manager::new(tiny_pool());
        let t0 = m.terminal(0);
        let t1 = m.terminal(1);
        // Build (p0 ? t0 : t1) two different ways.
        let direct = m.mk(0, t0, t1);
        let via_ite = m.ite(0, t0, t1);
        assert_eq!(direct, via_ite);
    }

    #[test]
    fn combine_words_concatenates_pointwise() {
        let mut m: Manager<ClassWord> = Manager::new(tiny_pool());
        let wa = ClassWord::singleton(0);
        let wb = ClassWord::singleton(1);
        let ta = m.terminal(wa.clone());
        let tb = m.terminal(wb.clone());
        // f = p0 ? [0] : [1] ; g = p2 ? [0] : [1]
        let f = m.mk(0, ta, tb);
        let g = m.mk(2, ta, tb);
        let fg = m.combine(f, g);
        // x = (0.5, -1) -> p0 true, p2 true -> [0,0]
        assert_eq!(m.eval(fg, &[0.5, -1.0]).0 .0, vec![0, 0]);
        // x = (1.5, 1) -> p0 false, p2 false -> [1,1]
        assert_eq!(m.eval(fg, &[1.5, 1.0]).0 .0, vec![1, 1]);
        // x = (0.5, 1) -> [0,1]; order preserved (f before g)
        assert_eq!(m.eval(fg, &[0.5, 1.0]).0 .0, vec![0, 1]);
        let gf = m.combine(g, f);
        assert_eq!(m.eval(gf, &[0.5, 1.0]).0 .0, vec![1, 0]);
    }

    #[test]
    fn combine_vectors_adds_and_collapses() {
        let mut m: Manager<ClassVector> = Manager::new(tiny_pool());
        let u0 = ClassVector::unit(0, 2);
        let u1 = ClassVector::unit(1, 2);
        let t0 = m.terminal(u0.clone());
        let t1 = m.terminal(u1.clone());
        let f = m.mk(0, t0, t1);
        let g = m.mk(0, t1, t0); // opposite votes on the same predicate
        let sum = m.combine(f, g);
        // Both branches now sum to (1,1): the diagram must collapse to a
        // single terminal — the "partial collapse" of §4.1.
        assert!(sum.is_terminal());
        assert_eq!(m.terminal_value(sum).0, vec![1, 1]);
    }

    #[test]
    fn map_into_majority_abstraction() {
        let pool = tiny_pool();
        let mut mv: Manager<ClassVector> = Manager::new(pool.clone());
        let v20 = mv.terminal(ClassVector(vec![2, 0]));
        let v11a = mv.terminal(ClassVector(vec![1, 1]));
        let inner = mv.mk(1, v20, v11a);
        let v02 = mv.terminal(ClassVector(vec![0, 2]));
        let root = mv.mk(0, inner, v02);
        let mut ml: Manager<ClassLabel> = Manager::new(pool);
        let mapped = mv.map_into(&mut ml, root, &|v| v.majority());
        // (2,0) -> 0, (1,1) -> 0 (tie to low), so the level-1 node collapses.
        assert_eq!(ml.level(mapped), 0);
        let n = ml.internal(mapped);
        assert!(n.hi.is_terminal() && n.lo.is_terminal());
        assert_eq!(*ml.terminal_value(n.hi), 0);
        assert_eq!(*ml.terminal_value(n.lo), 1);
    }

    #[test]
    fn from_tree_matches_tree_semantics() {
        use crate::data::datasets;
        use crate::forest::ForestLearner;
        use crate::predicate::PredicateOrder;
        let ds = datasets::iris();
        let forest = ForestLearner::default().trees(3).seed(5).fit(&ds);
        let pool = Arc::new(PredicatePool::from_forest(
            &forest,
            PredicateOrder::FeatureThreshold,
        ));
        let mut m: Manager<ClassLabel> = Manager::new(pool);
        for tree in &forest.trees {
            let root = m.from_tree(tree, &|c| c as u16).unwrap();
            for i in 0..ds.n_rows() {
                let x = ds.row(i);
                assert_eq!(*m.eval(root, x).0 as u32, tree.predict(x));
            }
        }
    }

    #[test]
    fn size_counts_shared_nodes_once() {
        let mut m: Manager<ClassLabel> = Manager::new(tiny_pool());
        let t0 = m.terminal(0);
        let t1 = m.terminal(1);
        let shared = m.mk(2, t0, t1);
        let root = m.mk(0, shared, shared); // collapses to shared!
        assert_eq!(root, shared);
        let a = m.mk(1, shared, t0);
        let root2 = m.mk(0, a, shared);
        let s = m.size(root2);
        assert_eq!(s.internal, 3);
        assert_eq!(s.terminals, 2);
        assert_eq!(s.total(), 5);
    }

    #[test]
    fn rebuild_preserves_semantics_and_compacts() {
        let mut m: Manager<ClassLabel> = Manager::new(tiny_pool());
        // create garbage
        for i in 0..50u16 {
            let t = m.terminal(i);
            let t2 = m.terminal(i + 1);
            m.mk(0, t, t2);
        }
        let t0 = m.terminal(100);
        let t1 = m.terminal(101);
        let live = m.mk(1, t0, t1);
        let (m2, live2) = m.rebuild(live);
        assert!(m2.arena_sizes().0 < m.arena_sizes().0);
        assert_eq!(m2.arena_sizes(), (1, 2));
        for x in [[0.5f32, 0.0], [3.0, 0.0]] {
            assert_eq!(m.eval(live, &x).0, m2.eval(live2, &x).0);
        }
    }

    #[test]
    fn combine_with_empty_word_is_identity() {
        let mut m: Manager<ClassWord> = Manager::new(tiny_pool());
        let eps = m.terminal(ClassWord::empty());
        let a = m.terminal(ClassWord(vec![1, 0]));
        let b = m.terminal(ClassWord(vec![2]));
        let f = m.mk(0, a, b);
        let l = m.combine(eps, f);
        let r = m.combine(f, eps);
        assert_eq!(l, f);
        assert_eq!(r, f);
    }
}
