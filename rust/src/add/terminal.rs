//! Terminal co-domains of the ADDs — the algebraic structures of §3.1/§4.
//!
//! - [`ClassWord`]: the string monoid `W = (C*, ∘, ε)` — one symbol per
//!   tree, fully information-preserving (§3.1).
//! - [`ClassVector`]: the monoid `V = (ℕ^|C|, +, 0)` of per-class vote
//!   frequencies — the coarsest *compositional* abstraction (§4.1).
//! - [`ClassLabel`]: the plain class co-domain `C` after the majority-vote
//!   abstraction `mv` (§4.2) — not a monoid, only the target of the final
//!   monadic transformation.

use std::hash::Hash;

/// Requirements on terminal values stored in an ADD.
pub trait Terminal: Clone + Eq + Hash + std::fmt::Debug {}
impl<T: Clone + Eq + Hash + std::fmt::Debug> Terminal for T {}

/// A monoid structure on a terminal type — what makes the incremental
/// aggregation `d(t₀) ∘ d(t₁) ∘ …` of §3.2 well-defined.
pub trait Monoid: Terminal {
    /// The associative join.
    fn combine(&self, other: &Self) -> Self;
}

/// Class word `c ∈ C*`: the sequence of per-tree decisions.
#[derive(Debug, Clone, PartialEq, Eq, Hash, Default)]
pub struct ClassWord(pub Vec<u16>);

impl ClassWord {
    /// The empty word ε (decision of the empty forest).
    pub fn empty() -> Self {
        ClassWord(Vec::new())
    }

    /// Single-symbol word for one tree's decision.
    pub fn singleton(class: u16) -> Self {
        ClassWord(vec![class])
    }

    /// Word length = number of aggregated trees.
    pub fn len(&self) -> usize {
        self.0.len()
    }

    /// True for ε.
    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// Majority vote over the word (runtime aggregation; costs `len` reads —
    /// the §6 metric charges these). Ties break to the lowest class index.
    pub fn majority(&self, n_classes: usize) -> u16 {
        let mut counts = vec![0u32; n_classes];
        for &c in &self.0 {
            counts[c as usize] += 1;
        }
        argmax(&counts)
    }

    /// Abstraction to class frequencies (§4.1's `W → V` step).
    pub fn to_vector(&self, n_classes: usize) -> ClassVector {
        let mut counts = vec![0u32; n_classes];
        for &c in &self.0 {
            counts[c as usize] += 1;
        }
        ClassVector(counts)
    }
}

impl Monoid for ClassWord {
    fn combine(&self, other: &Self) -> Self {
        let mut w = Vec::with_capacity(self.0.len() + other.0.len());
        w.extend_from_slice(&self.0);
        w.extend_from_slice(&other.0);
        ClassWord(w)
    }
}

/// Class vector `v ∈ ℕ^|C|`: per-class vote frequencies.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ClassVector(pub Vec<u32>);

impl ClassVector {
    /// The 0 vector for `n_classes` classes.
    pub fn zero(n_classes: usize) -> Self {
        ClassVector(vec![0; n_classes])
    }

    /// The unit vector `i(c)`.
    pub fn unit(class: u16, n_classes: usize) -> Self {
        let mut v = vec![0; n_classes];
        v[class as usize] = 1;
        ClassVector(v)
    }

    /// Total number of votes (= number of aggregated trees).
    pub fn total(&self) -> u32 {
        self.0.iter().sum()
    }

    /// The majority vote `mv(v) = argmax_c v_c` (§4.2); ties to the lowest
    /// class index. Costs `|C|` reads at runtime (§6 metric).
    pub fn majority(&self) -> u16 {
        argmax(&self.0)
    }
}

impl Monoid for ClassVector {
    fn combine(&self, other: &Self) -> Self {
        debug_assert_eq!(self.0.len(), other.0.len());
        ClassVector(
            self.0
                .iter()
                .zip(&other.0)
                .map(|(a, b)| a + b)
                .collect(),
        )
    }
}

/// Final class label after the majority-vote abstraction.
pub type ClassLabel = u16;

/// Tie-to-lowest argmax — the single definition of the crate's vote
/// semantics (`frozen` reuses it so the two layouts can never drift).
pub(crate) fn argmax(counts: &[u32]) -> u16 {
    let mut best = 0usize;
    for (i, &c) in counts.iter().enumerate() {
        if c > counts[best] {
            best = i;
        }
    }
    best as u16
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_monoid_laws() {
        let a = ClassWord(vec![0, 1]);
        let b = ClassWord(vec![2]);
        let c = ClassWord(vec![1, 1]);
        // associativity
        assert_eq!(a.combine(&b).combine(&c), a.combine(&b.combine(&c)));
        // identity
        assert_eq!(ClassWord::empty().combine(&a), a);
        assert_eq!(a.combine(&ClassWord::empty()), a);
        // NOT commutative (word order matters)
        assert_ne!(a.combine(&b), b.combine(&a));
    }

    #[test]
    fn vector_monoid_laws() {
        let a = ClassVector(vec![1, 0, 2]);
        let b = ClassVector(vec![0, 3, 1]);
        let c = ClassVector(vec![5, 0, 0]);
        assert_eq!(a.combine(&b).combine(&c), a.combine(&b.combine(&c)));
        assert_eq!(ClassVector::zero(3).combine(&a), a);
        // commutative (the abstraction forgets tree identity)
        assert_eq!(a.combine(&b), b.combine(&a));
    }

    #[test]
    fn word_to_vector_is_a_homomorphism() {
        let a = ClassWord(vec![0, 2, 2]);
        let b = ClassWord(vec![1, 2]);
        assert_eq!(
            a.combine(&b).to_vector(3),
            a.to_vector(3).combine(&b.to_vector(3))
        );
    }

    #[test]
    fn majorities_agree_across_abstractions() {
        let w = ClassWord(vec![2, 0, 2, 1, 2, 0]);
        let v = w.to_vector(3);
        assert_eq!(w.majority(3), v.majority());
        assert_eq!(v.majority(), 2);
    }

    #[test]
    fn majority_tie_breaks_low() {
        assert_eq!(ClassWord(vec![1, 0]).majority(2), 0);
        assert_eq!(ClassVector(vec![3, 3, 1]).majority(), 0);
        assert_eq!(ClassVector(vec![0, 0, 0]).majority(), 0); // empty forest
    }

    #[test]
    fn unit_and_singleton_correspond() {
        assert_eq!(ClassWord::singleton(2).to_vector(4), ClassVector::unit(2, 4));
        assert_eq!(ClassVector::unit(2, 4).total(), 1);
    }
}
