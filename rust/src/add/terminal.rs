//! Terminal co-domains of the ADDs — the algebraic structures of §3.1/§4.
//!
//! - [`ClassWord`]: the string monoid `W = (C*, ∘, ε)` — one symbol per
//!   tree, fully information-preserving (§3.1).
//! - [`ClassVector`]: the monoid `V = (ℕ^|C|, +, 0)` of per-class vote
//!   frequencies — the coarsest *compositional* abstraction (§4.1).
//! - [`ClassLabel`]: the plain class co-domain `C` after the majority-vote
//!   abstraction `mv` (§4.2) — not a monoid, only the target of the final
//!   monadic transformation.

use std::hash::Hash;

/// Requirements on terminal values stored in an ADD.
pub trait Terminal: Clone + Eq + Hash + std::fmt::Debug {}
impl<T: Clone + Eq + Hash + std::fmt::Debug> Terminal for T {}

/// A monoid structure on a terminal type — what makes the incremental
/// aggregation `d(t₀) ∘ d(t₁) ∘ …` of §3.2 well-defined.
pub trait Monoid: Terminal {
    /// The associative join.
    fn combine(&self, other: &Self) -> Self;
}

/// Class word `c ∈ C*`: the sequence of per-tree decisions.
#[derive(Debug, Clone, PartialEq, Eq, Hash, Default)]
pub struct ClassWord(pub Vec<u16>);

impl ClassWord {
    /// The empty word ε (decision of the empty forest).
    pub fn empty() -> Self {
        ClassWord(Vec::new())
    }

    /// Single-symbol word for one tree's decision.
    pub fn singleton(class: u16) -> Self {
        ClassWord(vec![class])
    }

    /// Word length = number of aggregated trees.
    pub fn len(&self) -> usize {
        self.0.len()
    }

    /// True for ε.
    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// Majority vote over the word (runtime aggregation; costs `len` reads —
    /// the §6 metric charges these). Ties break to the lowest class index.
    pub fn majority(&self, n_classes: usize) -> u16 {
        let mut counts = vec![0u32; n_classes];
        for &c in &self.0 {
            counts[c as usize] += 1;
        }
        argmax(&counts)
    }

    /// Abstraction to class frequencies (§4.1's `W → V` step).
    pub fn to_vector(&self, n_classes: usize) -> ClassVector {
        let mut counts = vec![0u32; n_classes];
        for &c in &self.0 {
            counts[c as usize] += 1;
        }
        ClassVector(counts)
    }
}

impl Monoid for ClassWord {
    fn combine(&self, other: &Self) -> Self {
        let mut w = Vec::with_capacity(self.0.len() + other.0.len());
        w.extend_from_slice(&self.0);
        w.extend_from_slice(&other.0);
        ClassWord(w)
    }
}

/// Class vector `v ∈ ℕ^|C|`: per-class vote frequencies.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ClassVector(pub Vec<u32>);

impl ClassVector {
    /// The 0 vector for `n_classes` classes.
    pub fn zero(n_classes: usize) -> Self {
        ClassVector(vec![0; n_classes])
    }

    /// The unit vector `i(c)`.
    pub fn unit(class: u16, n_classes: usize) -> Self {
        let mut v = vec![0; n_classes];
        v[class as usize] = 1;
        ClassVector(v)
    }

    /// Total number of votes (= number of aggregated trees).
    pub fn total(&self) -> u32 {
        self.0.iter().sum()
    }

    /// The majority vote `mv(v) = argmax_c v_c` (§4.2); ties to the lowest
    /// class index. Costs `|C|` reads at runtime (§6 metric).
    pub fn majority(&self) -> u16 {
        argmax(&self.0)
    }
}

impl Monoid for ClassVector {
    fn combine(&self, other: &Self) -> Self {
        debug_assert_eq!(self.0.len(), other.0.len());
        ClassVector(
            self.0
                .iter()
                .zip(&other.0)
                .map(|(a, b)| a + b)
                .collect(),
        )
    }
}

/// Final class label after the majority-vote abstraction.
pub type ClassLabel = u16;

/// Tie-to-lowest argmax — the single definition of the crate's vote
/// semantics (`frozen` reuses it so the two layouts can never drift).
pub fn argmax(counts: &[u32]) -> u16 {
    let mut best = 0usize;
    for (i, &c) in counts.iter().enumerate() {
        if c > counts[best] {
            best = i;
        }
    }
    best as u16
}

/// Class-weighted argmax: `argmax_c counts_c · weights_c`, ties to the
/// lowest class index — the imbalanced-data decision rule. Scores are
/// computed in `f64` so `count × weight` is exact for any realistic
/// forest size; with all-ones weights this is exactly [`argmax`].
/// `weights` must have one entry per class.
pub fn weighted_argmax(counts: &[u32], weights: &[f32]) -> u16 {
    debug_assert_eq!(counts.len(), weights.len());
    let mut best = 0usize;
    let mut best_score = f64::NEG_INFINITY;
    for (i, (&c, &w)) in counts.iter().zip(weights).enumerate() {
        let score = c as f64 * w as f64;
        if score > best_score {
            best = i;
            best_score = score;
        }
    }
    best as u16
}

/// Per-class probability estimates `counts_c / Σ counts` — the fraction
/// of trees voting for each class, i.e. the standard random-forest
/// probability estimate (Louppe, *Understanding Random Forests* §4.2).
/// The empty vote vector yields all zeros.
pub fn probabilities(counts: &[u32]) -> Vec<f64> {
    let total: u64 = counts.iter().map(|&c| c as u64).sum();
    if total == 0 {
        return vec![0.0; counts.len()];
    }
    counts.iter().map(|&c| c as f64 / total as f64).collect()
}

/// Expected value of a vote vector under a per-class value table:
/// `Σ_c counts_c · values_c / Σ_c counts_c` — the regression-forest
/// prediction (each tree votes for a value bin; the ensemble answers
/// the mean). Accumulated in `f64` in ascending class order, so every
/// backend that produces the same vote vector produces the *same bits*.
/// The empty vote vector yields `0.0`.
pub fn expected_value(counts: &[u32], values: &[f32]) -> f64 {
    debug_assert_eq!(counts.len(), values.len());
    let mut sum = 0.0f64;
    let mut total = 0u64;
    for (&c, &v) in counts.iter().zip(values) {
        sum += c as f64 * v as f64;
        total += c as u64;
    }
    if total == 0 {
        0.0
    } else {
        sum / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_monoid_laws() {
        let a = ClassWord(vec![0, 1]);
        let b = ClassWord(vec![2]);
        let c = ClassWord(vec![1, 1]);
        // associativity
        assert_eq!(a.combine(&b).combine(&c), a.combine(&b.combine(&c)));
        // identity
        assert_eq!(ClassWord::empty().combine(&a), a);
        assert_eq!(a.combine(&ClassWord::empty()), a);
        // NOT commutative (word order matters)
        assert_ne!(a.combine(&b), b.combine(&a));
    }

    #[test]
    fn vector_monoid_laws() {
        let a = ClassVector(vec![1, 0, 2]);
        let b = ClassVector(vec![0, 3, 1]);
        let c = ClassVector(vec![5, 0, 0]);
        assert_eq!(a.combine(&b).combine(&c), a.combine(&b.combine(&c)));
        assert_eq!(ClassVector::zero(3).combine(&a), a);
        // commutative (the abstraction forgets tree identity)
        assert_eq!(a.combine(&b), b.combine(&a));
    }

    #[test]
    fn word_to_vector_is_a_homomorphism() {
        let a = ClassWord(vec![0, 2, 2]);
        let b = ClassWord(vec![1, 2]);
        assert_eq!(
            a.combine(&b).to_vector(3),
            a.to_vector(3).combine(&b.to_vector(3))
        );
    }

    #[test]
    fn majorities_agree_across_abstractions() {
        let w = ClassWord(vec![2, 0, 2, 1, 2, 0]);
        let v = w.to_vector(3);
        assert_eq!(w.majority(3), v.majority());
        assert_eq!(v.majority(), 2);
    }

    #[test]
    fn majority_tie_breaks_low() {
        assert_eq!(ClassWord(vec![1, 0]).majority(2), 0);
        assert_eq!(ClassVector(vec![3, 3, 1]).majority(), 0);
        assert_eq!(ClassVector(vec![0, 0, 0]).majority(), 0); // empty forest
    }

    #[test]
    fn unit_and_singleton_correspond() {
        assert_eq!(ClassWord::singleton(2).to_vector(4), ClassVector::unit(2, 4));
        assert_eq!(ClassVector::unit(2, 4).total(), 1);
    }

    #[test]
    fn weighted_argmax_reweights_and_ties_low() {
        // unit weights reduce to plain argmax, ties included
        assert_eq!(weighted_argmax(&[3, 3, 1], &[1.0, 1.0, 1.0]), 0);
        assert_eq!(weighted_argmax(&[1, 5, 2], &[1.0, 1.0, 1.0]), 1);
        // upweighting the rare class flips the decision
        assert_eq!(weighted_argmax(&[8, 2, 0], &[1.0, 5.0, 1.0]), 1);
        // weighted ties still break to the lowest index
        assert_eq!(weighted_argmax(&[2, 1, 0], &[1.0, 2.0, 1.0]), 0);
        // all-zero counts: class 0
        assert_eq!(weighted_argmax(&[0, 0], &[9.0, 9.0]), 0);
    }

    #[test]
    fn probabilities_normalise() {
        assert_eq!(probabilities(&[1, 3]), vec![0.25, 0.75]);
        assert_eq!(probabilities(&[0, 0, 0]), vec![0.0, 0.0, 0.0]);
        let p = probabilities(&[7, 11, 2]);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn expected_value_is_the_count_weighted_mean() {
        // 3 votes at 1.0, 1 vote at 5.0 → (3 + 5) / 4 = 2.0
        assert_eq!(expected_value(&[3, 1], &[1.0, 5.0]), 2.0);
        assert_eq!(expected_value(&[0, 0], &[1.0, 5.0]), 0.0);
        // deterministic: same counts, same bits
        let a = expected_value(&[2, 5, 9], &[0.1, 0.2, 0.3]);
        let b = expected_value(&[2, 5, 9], &[0.1, 0.2, 0.3]);
        assert_eq!(a.to_bits(), b.to_bits());
    }
}
