//! Graphviz (DOT) export of decision diagrams — the rendering behind the
//! paper's Figures 2–5.

use super::{Manager, NodeId, Terminal};
use crate::data::Schema;
use std::collections::HashMap;
use std::fmt::Write as _;

/// Render the cone under `root` as a DOT digraph. Decision nodes show the
/// pool predicate (using `schema` feature names); terminals are rendered
/// with `term` (e.g. a class label, a vote vector). Solid edges are the
/// `true` (`<`) branch, dashed the `false` branch — the paper's convention.
pub fn to_dot<T: Terminal>(
    mgr: &Manager<T>,
    root: NodeId,
    schema: &Schema,
    term: &impl Fn(&T) -> String,
) -> String {
    let mut out = String::from("digraph add {\n  rankdir=TB;\n  node [fontname=\"Helvetica\"];\n");
    let mut names: HashMap<NodeId, String> = HashMap::new();
    let mut stack = vec![root];
    let mut next = 0usize;
    // First pass: name + declare nodes.
    while let Some(id) = stack.pop() {
        if names.contains_key(&id) {
            continue;
        }
        let name = format!("n{next}");
        next += 1;
        if id.is_terminal() {
            let _ = writeln!(
                out,
                "  {name} [shape=box, style=filled, fillcolor=lightgrey, label=\"{}\"];",
                escape(&term(mgr.terminal_value(id)))
            );
        } else {
            let n = mgr.internal(id);
            let _ = writeln!(
                out,
                "  {name} [shape=ellipse, label=\"{}\"];",
                escape(&mgr.pool().render(n.level, schema))
            );
            stack.push(n.hi);
            stack.push(n.lo);
        }
        names.insert(id, name);
    }
    // Second pass: edges.
    let mut seen = std::collections::HashSet::new();
    let mut stack = vec![root];
    while let Some(id) = stack.pop() {
        if id.is_terminal() || !seen.insert(id) {
            continue;
        }
        let n = mgr.internal(id);
        let _ = writeln!(out, "  {} -> {} [style=solid];", names[&id], names[&n.hi]);
        let _ = writeln!(out, "  {} -> {} [style=dashed];", names[&id], names[&n.lo]);
        stack.push(n.hi);
        stack.push(n.lo);
    }
    out.push_str("}\n");
    out
}

fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::add::{ClassLabel, Manager};
    use crate::data::{Feature, FeatureKind};
    use crate::predicate::{Domain, Predicate, PredicatePool};
    use std::sync::Arc;

    #[test]
    fn dot_contains_nodes_edges_and_labels() {
        let pool = Arc::new(PredicatePool::from_predicates(
            vec![Predicate {
                feature: 0,
                threshold: 1.65,
            }],
            vec![Domain::Real],
            1,
        ));
        let schema = Schema {
            features: vec![Feature {
                name: "petalwidth".into(),
                kind: FeatureKind::Numeric,
            }],
            classes: vec!["a".into(), "b".into()],
            task: crate::data::Task::Classification,
        };
        let mut m: Manager<ClassLabel> = Manager::new(pool);
        let a = m.terminal(0);
        let b = m.terminal(1);
        let root = m.mk(0, a, b);
        let dot = to_dot(&m, root, &schema, &|c| format!("class {c}"));
        assert!(dot.starts_with("digraph add {"));
        assert!(dot.contains("petalwidth < 1.65"));
        assert!(dot.contains("class 0"));
        assert!(dot.contains("class 1"));
        assert!(dot.contains("style=solid"));
        assert!(dot.contains("style=dashed"));
        assert!(dot.trim_end().ends_with('}'));
    }

    #[test]
    fn shared_nodes_rendered_once() {
        let pool = Arc::new(PredicatePool::from_predicates(
            vec![
                Predicate {
                    feature: 0,
                    threshold: 1.0,
                },
                Predicate {
                    feature: 0,
                    threshold: 2.0,
                },
            ],
            vec![Domain::Real],
            1,
        ));
        let schema = Schema {
            features: vec![Feature {
                name: "x".into(),
                kind: FeatureKind::Numeric,
            }],
            classes: vec![],
            task: crate::data::Task::Classification,
        };
        let mut m: Manager<ClassLabel> = Manager::new(pool);
        let a = m.terminal(0);
        let b = m.terminal(1);
        let shared = m.mk(1, a, b);
        let root = m.mk(0, shared, b);
        let dot = to_dot(&m, root, &schema, &|c| c.to_string());
        // 2 decision nodes + 2 terminals = 4 node declarations
        assert_eq!(dot.matches("shape=ellipse").count(), 2);
        assert_eq!(dot.matches("shape=box").count(), 2);
    }
}
