//! Structural validation of frozen diagrams.
//!
//! Two entry points for the two input forms:
//!
//! - [`validate`] checks the **raw** form ([`RawFrozen`]: absolute child
//!   references, `Vec`-backed arrays) — run by `FrozenDD::from_raw` on
//!   every freeze and on every v1 upgrade-on-load.
//! - [`validate_loaded`] checks the **canonical plane** form (forward-
//!   delta children, hot records, precomputed terminal tables) — run by
//!   the v2 zero-copy loader over the borrowed views before a
//!   [`FrozenDD`] is ever evaluated. Beyond the structural rules it also
//!   proves the derived planes (hot records, term class/agg tables)
//!   consistent with the cold sections they were derived from, so a
//!   tampered-but-checksummed snapshot cannot smuggle in a divergent
//!   answer table.
//!
//! A diagram that passes is well-formed, fully reachable and properly
//! ordered — the evaluation paths can then index without checks.
//!
//! [`FrozenDD`]: crate::frozen::FrozenDD

use crate::error::{Error, Result};
use crate::frozen::storage::HotRec;
use crate::frozen::{FrozenDD, FrozenTerminals, HotPlane, RawFrozen, TermPlanes, TERM_BIT};

fn err(msg: impl Into<String>) -> Error {
    Error::parse(format!("frozen: {}", msg.into()))
}

#[allow(clippy::needless_range_loop)] // the node sweep indexes four parallel arrays
pub(crate) fn validate(raw: &RawFrozen) -> Result<()> {
    let n_features = raw.schema.n_features();
    let n_classes = raw.schema.n_classes();
    if n_classes == 0 {
        return Err(err("schema has no classes"));
    }
    raw.schema.validate_task().map_err(|e| err(e.to_string()))?;
    if raw.pred_feature.len() != raw.pred_threshold.len() {
        return Err(err("predicate table arrays disagree on length"));
    }
    let n_preds = raw.pred_feature.len();
    for (l, &f) in raw.pred_feature.iter().enumerate() {
        if f as usize >= n_features {
            return Err(err(format!(
                "predicate {l} tests feature {f} but the schema has {n_features}"
            )));
        }
    }

    let n_nodes = raw.node_level.len();
    if raw.node_lo.len() != n_nodes || raw.node_hi.len() != n_nodes {
        return Err(err("node arrays disagree on length"));
    }
    if n_nodes as u64 >= u64::from(TERM_BIT) {
        return Err(err("node array overflows the reference tag"));
    }
    let n_terms = raw.terminals.len();
    if n_terms == 0 {
        return Err(err("a diagram needs at least one terminal"));
    }

    if raw.terminals.abstraction() != raw.abstraction {
        return Err(err("terminal storage does not match the abstraction"));
    }
    match &raw.terminals {
        FrozenTerminals::Word { offsets, symbols } => {
            if offsets.first() != Some(&0) {
                return Err(err("word offsets must start at 0"));
            }
            if offsets.windows(2).any(|w| w[0] > w[1]) {
                return Err(err("word offsets must be non-decreasing"));
            }
            if offsets.last().copied() != Some(symbols.len() as u32) {
                return Err(err("word offsets do not cover the symbol array"));
            }
            if symbols.iter().any(|&s| s as usize >= n_classes) {
                return Err(err("word symbol out of class range"));
            }
        }
        FrozenTerminals::Vector { stride, counts } => {
            if *stride as usize != n_classes {
                return Err(err("vote vector stride does not match |C|"));
            }
            if counts.len() != n_terms * n_classes {
                return Err(err("vote vector payload has the wrong arity"));
            }
        }
        FrozenTerminals::Majority { classes } => {
            if classes.iter().any(|&c| c as usize >= n_classes) {
                return Err(err("terminal class out of range"));
            }
        }
    }
    // (`Abstraction::Word`'s aggregation reads are metered per terminal,
    // so a zero `n_trees` is legal there — it only weakens the cost
    // model, never the predictions.)

    // Root: a terminal reference for the single-terminal diagram,
    // otherwise node 0 — the batch pass sweeps the arrays in index order
    // and must start at the root.
    if raw.root & TERM_BIT != 0 {
        if (raw.root & !TERM_BIT) as usize >= n_terms {
            return Err(err("root terminal out of range"));
        }
        if n_nodes != 0 {
            return Err(err("terminal root with non-empty node arrays"));
        }
    } else {
        if n_nodes == 0 {
            return Err(err("internal root with empty node arrays"));
        }
        if raw.root != 0 {
            return Err(err("internal root must be node 0 (topological order)"));
        }
    }

    // Per-node invariants + reachability in one forward sweep (children
    // sit strictly after parents, so reachability propagates in order).
    let mut node_reached = vec![false; n_nodes];
    let mut term_reached = vec![false; n_terms];
    if raw.root & TERM_BIT != 0 {
        term_reached[(raw.root & !TERM_BIT) as usize] = true;
    } else {
        node_reached[0] = true;
    }
    for i in 0..n_nodes {
        let level = raw.node_level[i];
        if level as usize >= n_preds {
            return Err(err(format!("node {i} level {level} out of range")));
        }
        let (lo, hi) = (raw.node_lo[i], raw.node_hi[i]);
        if lo == hi {
            return Err(err(format!("node {i} is redundant (lo == hi)")));
        }
        for child in [lo, hi] {
            if child & TERM_BIT != 0 {
                let t = (child & !TERM_BIT) as usize;
                if t >= n_terms {
                    return Err(err(format!("node {i} references terminal {t} out of range")));
                }
                if node_reached[i] {
                    term_reached[t] = true;
                }
            } else {
                let c = child as usize;
                if c <= i || c >= n_nodes {
                    return Err(err(format!(
                        "node {i} child {c} breaks the topological order"
                    )));
                }
                if raw.node_level[c] <= level {
                    return Err(err(format!(
                        "node {i} child {c} does not descend in the predicate order"
                    )));
                }
                if node_reached[i] {
                    node_reached[c] = true;
                }
            }
        }
    }
    if node_reached.iter().any(|r| !r) {
        return Err(err("unreachable node (the arrays must be exactly the cone)"));
    }
    if term_reached.iter().any(|r| !r) {
        return Err(err("unreferenced terminal"));
    }
    Ok(())
}

/// Validate the canonical plane form a v2 snapshot loads into (see the
/// module docs). Works entirely over the borrowed views — no section is
/// copied to be checked.
pub(crate) fn validate_loaded(dd: &FrozenDD) -> Result<()> {
    let n_features = dd.schema.n_features();
    let n_classes = dd.schema.n_classes();
    if n_classes == 0 {
        return Err(err("schema has no classes"));
    }
    dd.schema.validate_task().map_err(|e| err(e.to_string()))?;
    let n_preds = dd.pred_feature.len();
    if dd.pred_threshold.len() != n_preds {
        return Err(err("predicate table arrays disagree on length"));
    }
    for (l, &f) in dd.pred_feature.iter().enumerate() {
        if f as usize >= n_features {
            return Err(err(format!(
                "predicate {l} tests feature {f} but the schema has {n_features}"
            )));
        }
    }

    let n_nodes = dd.node_level.len();
    if dd.hot.len() != n_nodes || dd.lo.len() != n_nodes || dd.hi.len() != n_nodes {
        return Err(err("node planes disagree on length"));
    }
    if n_nodes as u64 >= u64::from(TERM_BIT) {
        return Err(err("node array overflows the reference tag"));
    }
    let n_terms = dd.terminals.len();
    if n_terms == 0 {
        return Err(err("a diagram needs at least one terminal"));
    }
    if dd.terminals.abstraction() != dd.abstraction {
        return Err(err("terminal storage does not match the abstraction"));
    }
    match &dd.terminals {
        TermPlanes::Word { offsets, symbols } => {
            if offsets.len() != n_terms + 1 {
                return Err(err("word offset table has the wrong arity"));
            }
            if offsets.first() != Some(&0) {
                return Err(err("word offsets must start at 0"));
            }
            if offsets.windows(2).any(|w| w[0] > w[1]) {
                return Err(err("word offsets must be non-decreasing"));
            }
            if offsets.last().copied() != Some(symbols.len() as u32) {
                return Err(err("word offsets do not cover the symbol array"));
            }
            if symbols.iter().any(|&s| s as usize >= n_classes) {
                return Err(err("word symbol out of class range"));
            }
        }
        TermPlanes::Vector { stride, counts } => {
            if *stride as usize != n_classes {
                return Err(err("vote vector stride does not match |C|"));
            }
            if counts.len() != n_terms * n_classes {
                return Err(err("vote vector payload has the wrong arity"));
            }
        }
        TermPlanes::Majority { classes } => {
            if classes.iter().any(|&c| c as usize >= n_classes) {
                return Err(err("terminal class out of range"));
            }
        }
    }
    // The precomputed answer tables must agree with the payloads they
    // were derived from (a checksummed-but-inconsistent snapshot is
    // rejected, not served).
    if dd.term_class.len() != n_terms || dd.term_agg_reads.len() != n_terms {
        return Err(err("terminal class/aggregation tables have the wrong arity"));
    }
    let mut counts_buf = Vec::new();
    for i in 0..n_terms {
        if dd.term_class[i] != dd.terminals.class_of_with(i, n_classes, &mut counts_buf) {
            return Err(err(format!(
                "terminal {i} class table disagrees with its payload"
            )));
        }
        if dd.term_agg_reads[i] != dd.terminals.agg_reads_of(i, n_classes) {
            return Err(err(format!(
                "terminal {i} aggregation table disagrees with its payload"
            )));
        }
    }

    // Root: a terminal reference for the single-terminal diagram,
    // otherwise node 0.
    if dd.root & TERM_BIT != 0 {
        if (dd.root & !TERM_BIT) as usize >= n_terms {
            return Err(err("root terminal out of range"));
        }
        if n_nodes != 0 {
            return Err(err("terminal root with non-empty node arrays"));
        }
    } else {
        if n_nodes == 0 {
            return Err(err("internal root with empty node arrays"));
        }
        if dd.root != 0 {
            return Err(err("internal root must be node 0 (topological order)"));
        }
    }

    // Per-node invariants + reachability in one forward sweep. Children
    // are forward deltas: child = i + delta, delta ≥ 1.
    let mut node_reached = vec![false; n_nodes];
    let mut term_reached = vec![false; n_terms];
    if dd.root & TERM_BIT != 0 {
        term_reached[(dd.root & !TERM_BIT) as usize] = true;
    } else {
        node_reached[0] = true;
    }
    for i in 0..n_nodes {
        let level = dd.node_level[i] as usize;
        if level >= n_preds {
            return Err(err(format!("node {i} level {level} out of range")));
        }
        // Hot-plane consistency: the inlined walk record must match the
        // predicate table bit-for-bit.
        let (hot_feat, hot_thresh) = match &dd.hot {
            HotPlane::U16(p) => {
                let h = p[i];
                (u32::from(h.feat), h.thresh)
            }
            HotPlane::U32(p) => {
                let h = p[i];
                (h.feat, h.thresh)
            }
            // Quantisation rewrites the predicate table to the decoded
            // f16 values, so the bit-for-bit comparison still holds.
            HotPlane::Q16(p) => {
                let h = p[i];
                (u32::from(h.feat), h.threshold())
            }
        };
        if hot_feat != dd.pred_feature[level]
            || hot_thresh.to_bits() != dd.pred_threshold[level].to_bits()
        {
            return Err(err(format!(
                "node {i} hot record disagrees with predicate {level}"
            )));
        }
        let (lo, hi) = (dd.lo[i], dd.hi[i]);
        if lo == hi {
            return Err(err(format!("node {i} is redundant (lo == hi)")));
        }
        for stored in [lo, hi] {
            if stored & TERM_BIT != 0 {
                let t = (stored & !TERM_BIT) as usize;
                if t >= n_terms {
                    return Err(err(format!(
                        "node {i} references terminal {t} out of range"
                    )));
                }
                if node_reached[i] {
                    term_reached[t] = true;
                }
            } else {
                if stored == 0 {
                    return Err(err(format!("node {i} has a zero forward delta")));
                }
                let c = i + stored as usize;
                if c >= n_nodes {
                    return Err(err(format!(
                        "node {i} child {c} breaks the topological order"
                    )));
                }
                if dd.node_level[c] as usize <= level {
                    return Err(err(format!(
                        "node {i} child {c} does not descend in the predicate order"
                    )));
                }
                if node_reached[i] {
                    node_reached[c] = true;
                }
            }
        }
    }
    if node_reached.iter().any(|r| !r) {
        return Err(err("unreachable node (the arrays must be exactly the cone)"));
    }
    if term_reached.iter().any(|r| !r) {
        return Err(err("unreferenced terminal"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compile::Abstraction;
    use crate::data::{Feature, FeatureKind, Schema};

    fn schema() -> Schema {
        Schema {
            features: vec![
                Feature {
                    name: "x0".into(),
                    kind: FeatureKind::Numeric,
                },
                Feature {
                    name: "x1".into(),
                    kind: FeatureKind::Numeric,
                },
            ],
            classes: vec!["a".into(), "b".into()],
            task: crate::data::Task::Classification,
        }
    }

    /// The fixture diagram: x0 < 0.5 ? a : (x1 < 0.5 ? b : a).
    fn tiny() -> RawFrozen {
        RawFrozen {
            schema: schema(),
            abstraction: Abstraction::Majority,
            unsat_elim: true,
            n_trees: 3,
            pred_feature: vec![0, 1],
            pred_threshold: vec![0.5, 0.5],
            node_level: vec![0, 1],
            node_lo: vec![1, TERM_BIT],
            node_hi: vec![TERM_BIT, TERM_BIT | 1],
            root: 0,
            terminals: FrozenTerminals::Majority {
                classes: vec![0, 1],
            },
        }
    }

    #[test]
    fn accepts_the_fixture_shape() {
        validate(&tiny()).unwrap();
    }

    #[test]
    fn rejects_structural_corruption() {
        let cases: Vec<(&str, Box<dyn Fn(&mut RawFrozen)>)> = vec![
            ("level out of range", Box::new(|r| r.node_level[0] = 9)),
            ("redundant node", Box::new(|r| r.node_hi[1] = TERM_BIT)),
            ("topological break", Box::new(|r| r.node_lo[1] = 0)),
            ("terminal out of range", Box::new(|r| r.node_hi[1] = TERM_BIT | 7)),
            ("root not node 0", Box::new(|r| r.root = 1)),
            ("class out of range", Box::new(|r| {
                r.terminals = FrozenTerminals::Majority {
                    classes: vec![0, 9],
                };
            })),
            ("abstraction mismatch", Box::new(|r| r.abstraction = Abstraction::Vector)),
            ("unreferenced terminal", Box::new(|r| {
                r.terminals = FrozenTerminals::Majority {
                    classes: vec![0, 1, 1],
                };
            })),
            ("level order violation", Box::new(|r| r.node_level[1] = 0)),
            ("predicate feature out of range", Box::new(|r| r.pred_feature[0] = 5)),
        ];
        for (what, corrupt) in cases {
            let mut raw = tiny();
            corrupt(&mut raw);
            assert!(validate(&raw).is_err(), "{what} must be rejected");
        }
    }

    #[test]
    fn rejects_unreachable_nodes() {
        let mut raw = tiny();
        // Append a node nothing points to.
        raw.node_level.push(1);
        raw.node_lo.push(TERM_BIT);
        raw.node_hi.push(TERM_BIT | 1);
        assert!(validate(&raw).is_err());
    }

    #[test]
    fn terminal_root_requires_empty_node_arrays() {
        let raw = RawFrozen {
            schema: schema(),
            abstraction: Abstraction::Majority,
            unsat_elim: false,
            n_trees: 1,
            pred_feature: vec![],
            pred_threshold: vec![],
            node_level: vec![],
            node_lo: vec![],
            node_hi: vec![],
            root: TERM_BIT,
            terminals: FrozenTerminals::Majority { classes: vec![1] },
        };
        validate(&raw).unwrap();
        let mut bad = tiny();
        bad.root = TERM_BIT;
        assert!(validate(&bad).is_err(), "terminal root atop nodes");
    }
}
