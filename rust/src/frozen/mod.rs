//! FrozenDD: the flat, immutable serving form of a compiled diagram.
//!
//! A [`CompiledDD`](crate::compile::CompiledDD) lives in a hash-consed
//! arena ([`add::Manager`](crate::add::Manager)) — ideal for aggregation,
//! but every evaluation pays pointer-chasing through node ids, a predicate
//! pool indirection per decision, and JSON parsing at replica startup.
//! Post-compilation the diagram never changes, so the serving fleet runs
//! this frozen rendering instead:
//!
//! - **Narrow hot/cold node encoding**: the walk reads a *hot plane* of
//!   6-byte records ([`storage::Hot16`]: `u16` feature + `f32` threshold,
//!   with a `u32` escape hatch past 65 536 features) plus two `u32` child
//!   arrays holding **forward deltas** (children sit strictly after
//!   parents in the topological order, so a child reference is `i +
//!   delta`, or a [`TERM_BIT`]-tagged terminal index). Cold data —
//!   levels, the predicate tables, full terminal payloads — lives in
//!   separate planes the walk never touches. Hot bytes per decision: ≤ 8,
//!   half the previous 16-byte AoS node.
//! - **Zero-copy snapshot boot**: the `fdd-v2` snapshot ([`snapshot`])
//!   writes every plane 64-byte-aligned and little-endian, so
//!   [`FrozenDD::load`] `mmap`s the artifact
//!   ([`crate::runtime::mmap`]) and the on-disk bytes *are* the runtime
//!   arrays ([`storage::Plane`] borrows them from the shared
//!   [`storage::SnapshotBuf`]). No copy, no per-node allocation — the
//!   counting-allocator test `tests/alloc_frozen.rs` enforces it.
//!   Legacy `fdd-v1` artifacts still load through an upgrade-on-load
//!   path.
//! - **Multi-model artifact bundles** ([`bundle`]): a fleet's models pack
//!   into one `fab-v1` file (manifest + 64-byte-aligned member
//!   snapshots); [`bundle::Bundle::load`] maps the file once,
//!   `MADV_WILLNEED`-hints it, and every entry boots as a zero-copy
//!   [`FrozenDD`] borrowing its slice of the shared mapping —
//!   `Engine::register_bundle` / `serve --bundle` turn that into a whole
//!   registry per `mmap(2)`.
//! - **A cache-tiled batch sweep** ([`FrozenDD::classify_batch`]):
//!   batches move through the diagram in topological node *tiles* sized
//!   to an LLC budget (`ServeConfig::tile_bytes`,
//!   [`configure_tile_bytes`]; auto-default
//!   [`DEFAULT_TILE_BYTES`]). Rows walk as far as the resident tile
//!   allows, then park on the destination tile's intrusive chain
//!   ([`BatchScratch`]) — each tile of a larger-than-LLC diagram is
//!   streamed through cache once per batch instead of once per round.
//!   Diagrams within the budget keep the round-based counting-scatter
//!   sweep; batches small relative to the diagram fall back to plain
//!   walks; large batches shard across the evaluation worker pool
//!   ([`crate::runtime::pool`]). All paths are allocation-free once the
//!   scratch is warm.
//! - **Batch cost metering** ([`FrozenDD::classify_batch_steps`]): the
//!   sweeps optionally record the §6 step count per row, bit-identical
//!   to [`FrozenDD::classify_with_steps`], so cost accounting survives
//!   the batch path.
//! - **Explicit-SIMD branchless kernels** ([`crate::runtime::simd`]):
//!   the round-based sweep evaluates up to 8 parked rows per hot record
//!   with masked `<` compares and a blend-select of the lo/hi delta
//!   words (AVX2/SSE2/NEON behind one-time runtime detection; the tiled
//!   sweep adds software prefetch of the next parked row's node data).
//!   `FOREST_ADD_NO_SIMD` / `ServeConfig::simd = false` force the scalar
//!   walk, and [`FrozenDD::classify_batch_kernel_into`] pins any kernel
//!   explicitly. Two freeze-time transforms keep the lanes fed:
//!   [`FreezeOpts::pack_features`] reorders feature columns by node-test
//!   frequency (the permutation rides in the snapshot and is applied
//!   transparently on load) and [`FreezeOpts::quantize_f16`] narrows
//!   thresholds to IEEE-754 binary16 ([`storage::HotQ16`], 4-byte hot
//!   records). All of it is bit-identity-pinned against the scalar walk.
//!
//! Predictions and §6 step counts are bit-identical to the source
//! `CompiledDD` (enforced by `tests/conformance.rs`) across every
//! encoding, tile size, thread count, and load path: freezing is a
//! memory-layout change, never a semantic one.

pub mod bundle;
pub mod snapshot;

pub(crate) mod builder;
pub(crate) mod storage;
mod validate;

pub use storage::{FeatWidth, ThreshQuant};

use crate::add::terminal::argmax;
use crate::add::SizeStats;
use crate::batch::RowMatrix;
use crate::classifier::{BackendKind, Classifier, ClassifierInfo, CostModel};
use crate::compile::Abstraction;
use crate::data::Schema;
use crate::error::{Error, Result};
use crate::runtime::{fault, pool, simd};
use std::cell::RefCell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use storage::{Hot16, Hot32, HotQ16, HotRec, Plane};

/// Batches with fewer rows than `nodes / WALK_FALLBACK_FACTOR` take
/// per-row walks instead of a sweep (a sweep's cost is dominated by the
/// node span it touches, not the row count).
const WALK_FALLBACK_FACTOR: usize = 32;

/// Minimum batch size before the sweep is sharded across the worker pool.
const PAR_MIN_ROWS: usize = 512;

/// Minimum rows per parallel shard (below this, fan-out overhead eats
/// the multi-core win).
const PAR_ROWS_PER_SHARD: usize = 256;

/// Default LLC budget of the tiled sweep: 4 MiB of hot node data —
/// conservatively half of a typical last-level-cache slice. Diagrams
/// whose hot planes fit the budget use the round-based sweep instead.
pub const DEFAULT_TILE_BYTES: usize = 4 << 20;

/// Smallest tile the sweep will cut, in nodes — a floor against
/// degenerate budgets (`tile_bytes: 1` in a test still gives whole
/// tiles, just many of them).
const MIN_TILE_NODES: usize = 64;

/// Chain terminator of the tiled sweep's per-tile row lists.
const CHAIN_END: u32 = u32::MAX;

/// High bit of a child reference: set ⇒ the remaining bits index the
/// terminal arrays, clear ⇒ they hold the **forward delta** to the child
/// node (`child = node + delta`). Mirrors the
/// [`add::NodeId`](crate::add::NodeId) tagging convention.
pub const TERM_BIT: u32 = 1 << 31;

/// Process-wide tile budget in bytes (0 = auto = [`DEFAULT_TILE_BYTES`]).
static TILE_BYTES: AtomicUsize = AtomicUsize::new(0);

/// Set the tiled sweep's LLC budget in bytes (`0` = auto). Called by
/// server startup from `ServeConfig::tile_bytes`; returns the effective
/// budget.
pub fn configure_tile_bytes(bytes: usize) -> usize {
    TILE_BYTES.store(bytes, Ordering::Relaxed);
    tile_bytes()
}

/// The effective tile budget in bytes.
pub fn tile_bytes() -> usize {
    match TILE_BYTES.load(Ordering::Relaxed) {
        0 => DEFAULT_TILE_BYTES,
        n => n,
    }
}

/// Dispatch a body over the concrete hot-plane encoding, binding `$hot`
/// to the record slice. All arms monomorphise the same generic
/// evaluator.
macro_rules! with_hot {
    ($dd:expr, $hot:ident, $body:block) => {
        match &$dd.hot {
            HotPlane::U16(plane) => {
                let $hot: &[Hot16] = plane;
                $body
            }
            HotPlane::U32(plane) => {
                let $hot: &[Hot32] = plane;
                $body
            }
            HotPlane::Q16(plane) => {
                let $hot: &[HotQ16] = plane;
                $body
            }
        }
    };
}

/// Raw terminal storage, one variant per [`Abstraction`] — the mutable,
/// `Vec`-backed form the freezer and the v1 snapshot loader build.
/// Payloads are kept verbatim (not just the precomputed class) so
/// snapshots remain information-complete.
#[derive(Debug, Clone, PartialEq, Eq)]
pub(crate) enum FrozenTerminals {
    /// Class words: terminal `i` is `symbols[offsets[i]..offsets[i + 1]]`.
    Word { offsets: Vec<u32>, symbols: Vec<u16> },
    /// Vote vectors: terminal `i` is `counts[i * stride..(i + 1) * stride]`.
    Vector { stride: u32, counts: Vec<u32> },
    /// Bare class labels.
    Majority { classes: Vec<u16> },
}

impl FrozenTerminals {
    pub(crate) fn empty_word() -> FrozenTerminals {
        FrozenTerminals::Word {
            offsets: vec![0],
            symbols: Vec::new(),
        }
    }

    pub(crate) fn empty_vector(n_classes: usize) -> FrozenTerminals {
        FrozenTerminals::Vector {
            stride: n_classes as u32,
            counts: Vec::new(),
        }
    }

    pub(crate) fn empty_majority() -> FrozenTerminals {
        FrozenTerminals::Majority {
            classes: Vec::new(),
        }
    }

    pub(crate) fn push_word(&mut self, word: &[u16]) {
        match self {
            FrozenTerminals::Word { offsets, symbols } => {
                symbols.extend_from_slice(word);
                offsets.push(symbols.len() as u32);
            }
            _ => panic!("terminal kind mismatch: expected word storage"),
        }
    }

    pub(crate) fn push_vector(&mut self, row: &[u32]) {
        match self {
            FrozenTerminals::Vector { stride, counts } => {
                assert_eq!(row.len(), *stride as usize, "vote vector arity");
                counts.extend_from_slice(row);
            }
            _ => panic!("terminal kind mismatch: expected vector storage"),
        }
    }

    pub(crate) fn push_class(&mut self, class: u16) {
        match self {
            FrozenTerminals::Majority { classes } => classes.push(class),
            _ => panic!("terminal kind mismatch: expected majority storage"),
        }
    }

    /// Number of terminals stored.
    pub(crate) fn len(&self) -> usize {
        match self {
            FrozenTerminals::Word { offsets, .. } => offsets.len() - 1,
            FrozenTerminals::Vector { stride, counts } => {
                if *stride == 0 {
                    0
                } else {
                    counts.len() / *stride as usize
                }
            }
            FrozenTerminals::Majority { classes } => classes.len(),
        }
    }

    /// The abstraction this storage belongs to.
    pub(crate) fn abstraction(&self) -> Abstraction {
        match self {
            FrozenTerminals::Word { .. } => Abstraction::Word,
            FrozenTerminals::Vector { .. } => Abstraction::Vector,
            FrozenTerminals::Majority { .. } => Abstraction::Majority,
        }
    }

    /// Best-effort forest size recovered from the payloads (word length /
    /// vote total), for diagrams whose compile stats were not persisted.
    pub(crate) fn infer_trees(&self) -> u32 {
        match self {
            FrozenTerminals::Word { offsets, .. } => offsets
                .windows(2)
                .map(|w| w[1] - w[0])
                .max()
                .unwrap_or(0),
            FrozenTerminals::Vector { stride, counts } => {
                if *stride == 0 {
                    0
                } else {
                    counts
                        .chunks_exact(*stride as usize)
                        .map(|row| row.iter().sum())
                        .max()
                        .unwrap_or(0)
                }
            }
            FrozenTerminals::Majority { .. } => 0,
        }
    }
}

/// Terminal payloads in their frozen plane form — borrowed straight from
/// a v2 snapshot, or owned when built by the freezer.
#[derive(Debug, Clone)]
pub(crate) enum TermPlanes {
    Word {
        offsets: Plane<u32>,
        symbols: Plane<u16>,
    },
    Vector {
        stride: u32,
        counts: Plane<u32>,
    },
    Majority {
        classes: Plane<u16>,
    },
}

impl TermPlanes {
    pub(crate) fn from_raw(raw: FrozenTerminals) -> TermPlanes {
        match raw {
            FrozenTerminals::Word { offsets, symbols } => TermPlanes::Word {
                offsets: Plane::Owned(offsets),
                symbols: Plane::Owned(symbols),
            },
            FrozenTerminals::Vector { stride, counts } => TermPlanes::Vector {
                stride,
                counts: Plane::Owned(counts),
            },
            FrozenTerminals::Majority { classes } => TermPlanes::Majority {
                classes: Plane::Owned(classes),
            },
        }
    }

    /// Number of terminals stored.
    pub(crate) fn len(&self) -> usize {
        match self {
            TermPlanes::Word { offsets, .. } => offsets.len().saturating_sub(1),
            TermPlanes::Vector { stride, counts } => {
                if *stride == 0 {
                    0
                } else {
                    counts.len() / *stride as usize
                }
            }
            TermPlanes::Majority { classes } => classes.len(),
        }
    }

    /// The abstraction this storage belongs to.
    pub(crate) fn abstraction(&self) -> Abstraction {
        match self {
            TermPlanes::Word { .. } => Abstraction::Word,
            TermPlanes::Vector { .. } => Abstraction::Vector,
            TermPlanes::Majority { .. } => Abstraction::Majority,
        }
    }

    /// Majority class of terminal `i`, via the crate's one `argmax` (ties
    /// break to the lowest class index, like every other layout).
    /// `counts` is a caller-owned scratch buffer so validation and
    /// derivation loops allocate once, not per terminal.
    pub(crate) fn class_of_with(
        &self,
        i: usize,
        n_classes: usize,
        counts: &mut Vec<u32>,
    ) -> u16 {
        match self {
            TermPlanes::Word { offsets, symbols } => {
                counts.clear();
                counts.resize(n_classes, 0);
                for &s in &symbols[offsets[i] as usize..offsets[i + 1] as usize] {
                    counts[s as usize] += 1;
                }
                argmax(counts)
            }
            TermPlanes::Vector {
                stride,
                counts: votes,
            } => {
                let s = *stride as usize;
                argmax(&votes[i * s..(i + 1) * s])
            }
            TermPlanes::Majority { classes } => classes[i],
        }
    }

    /// Write terminal `i`'s per-class vote counts into `out` (length
    /// `n_classes`). Word payloads are counted through the §4.1
    /// homomorphism, vector payloads copied verbatim. Returns `false`
    /// for majority terminals — the abstraction has discarded the
    /// distribution and `out` is left untouched.
    pub(crate) fn counts_into(&self, i: usize, out: &mut [u32]) -> bool {
        match self {
            TermPlanes::Word { offsets, symbols } => {
                out.fill(0);
                for &s in &symbols[offsets[i] as usize..offsets[i + 1] as usize] {
                    out[s as usize] += 1;
                }
                true
            }
            TermPlanes::Vector {
                stride,
                counts: votes,
            } => {
                let s = *stride as usize;
                out.copy_from_slice(&votes[i * s..(i + 1) * s]);
                true
            }
            TermPlanes::Majority { .. } => false,
        }
    }

    /// §6 aggregation reads still paid at runtime when terminal `i` is
    /// reached: the word length for class words, `|C|` for vote vectors,
    /// zero after the majority abstraction.
    pub(crate) fn agg_reads_of(&self, i: usize, n_classes: usize) -> u32 {
        match self {
            TermPlanes::Word { offsets, .. } => offsets[i + 1] - offsets[i],
            TermPlanes::Vector { .. } => n_classes as u32,
            TermPlanes::Majority { .. } => 0,
        }
    }
}

/// The raw (serialisable) fields of a [`FrozenDD`], before validation and
/// derivation of the evaluation planes. Built by [`builder::freeze_cone`]
/// and by the [`snapshot`] v1 (upgrade-on-load) parser. Child references
/// here are **absolute** node indices; [`FrozenDD::from_raw`] converts
/// them to the canonical forward-delta encoding.
pub(crate) struct RawFrozen {
    pub schema: Schema,
    pub abstraction: Abstraction,
    pub unsat_elim: bool,
    pub n_trees: u32,
    /// Predicate tables, indexed by level (the global variable order).
    pub pred_feature: Vec<u32>,
    pub pred_threshold: Vec<f32>,
    /// Node arrays in topological order (root first, children strictly
    /// after parents).
    pub node_level: Vec<u32>,
    pub node_lo: Vec<u32>,
    pub node_hi: Vec<u32>,
    /// Root reference ([`TERM_BIT`]-tagged when the diagram is a single
    /// terminal; otherwise always node 0).
    pub root: u32,
    pub terminals: FrozenTerminals,
}

/// The hot walk plane in its concrete encoding (chosen against the
/// schema at freeze time, recorded in the snapshot META).
#[derive(Debug, Clone)]
pub(crate) enum HotPlane {
    U16(Plane<Hot16>),
    U32(Plane<Hot32>),
    /// `u16` features with f16-quantised thresholds
    /// (`freeze --quantize-f16`).
    Q16(Plane<HotQ16>),
}

impl HotPlane {
    pub(crate) fn len(&self) -> usize {
        match self {
            HotPlane::U16(p) => p.len(),
            HotPlane::U32(p) => p.len(),
            HotPlane::Q16(p) => p.len(),
        }
    }

    pub(crate) fn width(&self) -> FeatWidth {
        match self {
            HotPlane::U16(_) | HotPlane::Q16(_) => FeatWidth::U16,
            HotPlane::U32(_) => FeatWidth::U32,
        }
    }

    pub(crate) fn quant(&self) -> ThreshQuant {
        match self {
            HotPlane::Q16(_) => ThreshQuant::F16,
            _ => ThreshQuant::F32,
        }
    }
}

/// Freeze-time feature-column packing (`freeze --pack-features`):
/// `perm[slot]` is the original feature id served by packed column
/// `slot`, ordered by descending node-test frequency so the features the
/// sweep gathers most share cache lines; `rank` is the inverse map
/// (original id → packed column) the gather uses. The hot plane keeps
/// **original** feature ids on disk and in memory, so single-row walks
/// and readers that ignore the permutation section stay correct — only
/// the batch sweeps, which copy rows into packed scratch cells, consult
/// `rank`.
#[derive(Debug, Clone)]
pub(crate) struct FeatPack {
    pub(crate) perm: Plane<u32>,
    pub(crate) rank: Vec<u32>,
}

impl FeatPack {
    /// Build the inverse map, rejecting anything that is not a true
    /// permutation of `0..perm.len()` (a forged snapshot section must
    /// fail here, not scramble gathers).
    pub(crate) fn from_perm(perm: Plane<u32>) -> Result<FeatPack> {
        let n = perm.len();
        let mut rank = vec![u32::MAX; n];
        for (slot, &f) in perm.iter().enumerate() {
            if f as usize >= n || rank[f as usize] != u32::MAX {
                return Err(Error::parse(
                    "fdd snapshot: feature permutation is not a permutation",
                ));
            }
            rank[f as usize] = slot as u32;
        }
        Ok(FeatPack { perm, rank })
    }
}

/// Optional freeze-time layout transforms, applied by
/// [`CompiledDD::freeze_with`](crate::compile::CompiledDD::freeze_with)
/// after the structural freeze. Both default off; the default snapshot
/// bytes are unchanged.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FreezeOpts {
    /// Reorder feature columns by descending node-test frequency so the
    /// batch gather's hot columns share cache lines. The permutation is
    /// stored in its own snapshot section and applied transparently on
    /// load; predictions are bit-identical.
    pub pack_features: bool,
    /// Quantise thresholds to IEEE-754 binary16 (ties round away from
    /// zero), halving the hot plane to 4 bytes/node. The predicate table
    /// is rewritten to the widened values so every plane stays
    /// self-consistent; freezing fails if a threshold falls outside the
    /// f16 range or two thresholds of one feature would collide.
    pub quantize_f16: bool,
}

/// An immutable, cache-friendly snapshot of a compiled decision diagram.
///
/// Built with [`CompiledDD::freeze`](crate::compile::CompiledDD::freeze)
/// or loaded from an `fdd` snapshot via [`FrozenDD::load`] — on 64-bit
/// unix the v2 load is an `mmap` whose mapped bytes back the node and
/// terminal planes directly. Served through the [`Classifier`] trait as
/// [`BackendKind::Frozen`].
#[derive(Debug, Clone)]
pub struct FrozenDD {
    schema: Schema,
    abstraction: Abstraction,
    unsat_elim: bool,
    n_trees: u32,
    /// Root reference ([`TERM_BIT`]-tagged for single-terminal diagrams,
    /// otherwise node 0).
    root: u32,
    /// Cold planes: predicate tables and per-node levels — inspection,
    /// validation and re-serialisation only; the walk never reads them.
    pred_feature: Plane<u32>,
    pred_threshold: Plane<f32>,
    node_level: Plane<u32>,
    /// Hot planes: the walk records plus the forward-delta child arrays.
    hot: HotPlane,
    lo: Plane<u32>,
    hi: Plane<u32>,
    /// Freeze-time feature-column packing (`None` = natural order).
    pack: Option<FeatPack>,
    /// Terminal payloads (cold) and the precomputed per-terminal majority
    /// class / §6 aggregation reads (hot).
    terminals: TermPlanes,
    term_class: Plane<u16>,
    term_agg_reads: Plane<u32>,
    /// Whether the planes borrow an mmap'd snapshot (diagnostics only).
    mapped: bool,
}

/// The single-row walk over the narrow planes: one ≤ 8-byte hot record
/// and one child word per decision, child = `node + delta`. Returns the
/// terminal index and the decision count.
#[inline(always)]
fn walk<H: HotRec>(hot: &[H], lo: &[u32], hi: &[u32], root: u32, x: &[f32]) -> (usize, u32) {
    if root & TERM_BIT != 0 {
        return ((root & !TERM_BIT) as usize, 0);
    }
    let mut n = 0usize;
    let mut steps = 0u32;
    loop {
        let h = hot[n];
        steps += 1;
        let stored = if x[h.feat_ix()] < h.threshold() {
            hi[n]
        } else {
            lo[n]
        };
        if stored & TERM_BIT != 0 {
            return ((stored & !TERM_BIT) as usize, steps);
        }
        n += stored as usize;
    }
}

/// Nodes per tile under a byte budget: one hot record plus the two child
/// words is what the in-tile walk keeps resident.
fn tile_span<H: HotRec>(tile_budget: usize) -> usize {
    let per_node = std::mem::size_of::<H>() + 8;
    (tile_budget / per_node).max(MIN_TILE_NODES)
}

impl FrozenDD {
    /// Validate raw fields and derive the evaluation planes (hot records,
    /// forward deltas, per-terminal class/aggregation reads).
    pub(crate) fn from_raw(raw: RawFrozen) -> Result<FrozenDD> {
        Self::from_raw_with_width(raw, None)
    }

    /// [`FrozenDD::from_raw`] with an explicit feature-index width
    /// (`None` = narrowest that fits the schema). The `u32` escape hatch
    /// exists for schemas past 65 536 features; forcing `U16` onto a
    /// wider schema errors.
    pub(crate) fn from_raw_with_width(
        raw: RawFrozen,
        forced: Option<FeatWidth>,
    ) -> Result<FrozenDD> {
        validate::validate(&raw)?;
        let RawFrozen {
            schema,
            abstraction,
            unsat_elim,
            n_trees,
            pred_feature,
            pred_threshold,
            node_level,
            node_lo,
            node_hi,
            root,
            terminals,
        } = raw;
        let width = forced.unwrap_or_else(|| FeatWidth::for_features(schema.n_features()));
        if width == FeatWidth::U16 && pred_feature.iter().any(|&f| f > u32::from(u16::MAX)) {
            return Err(Error::invalid(
                "u16 feature encoding cannot index this schema (use the u32 escape hatch)",
            ));
        }
        let hot = match width {
            FeatWidth::U16 => HotPlane::U16(Plane::Owned(
                node_level
                    .iter()
                    .map(|&l| Hot16 {
                        feat: pred_feature[l as usize] as u16,
                        thresh: pred_threshold[l as usize],
                    })
                    .collect(),
            )),
            FeatWidth::U32 => HotPlane::U32(Plane::Owned(
                node_level
                    .iter()
                    .map(|&l| Hot32 {
                        feat: pred_feature[l as usize],
                        thresh: pred_threshold[l as usize],
                    })
                    .collect(),
            )),
        };
        // Forward deltas: validate() proved every internal child sits
        // strictly after its parent.
        let to_delta = |refs: Vec<u32>| -> Vec<u32> {
            refs.into_iter()
                .enumerate()
                .map(|(i, r)| if r & TERM_BIT != 0 { r } else { r - i as u32 })
                .collect()
        };
        let lo = Plane::Owned(to_delta(node_lo));
        let hi = Plane::Owned(to_delta(node_hi));
        let terminals = TermPlanes::from_raw(terminals);
        let n_classes = schema.n_classes();
        let mut counts = Vec::new();
        let term_class = Plane::Owned(
            (0..terminals.len())
                .map(|i| terminals.class_of_with(i, n_classes, &mut counts))
                .collect(),
        );
        let term_agg_reads = Plane::Owned(
            (0..terminals.len())
                .map(|i| terminals.agg_reads_of(i, n_classes))
                .collect(),
        );
        Ok(FrozenDD {
            schema,
            abstraction,
            unsat_elim,
            n_trees,
            root,
            pred_feature: Plane::Owned(pred_feature),
            pred_threshold: Plane::Owned(pred_threshold),
            node_level: Plane::Owned(node_level),
            hot,
            lo,
            hi,
            pack: None,
            terminals,
            term_class,
            term_agg_reads,
            mapped: false,
        })
    }

    /// Schema of the training data.
    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    /// Which abstraction the terminals carry.
    pub fn abstraction(&self) -> Abstraction {
        self.abstraction
    }

    /// Whether unsatisfiable-path elimination was applied at compile time.
    pub fn unsat_elim(&self) -> bool {
        self.unsat_elim
    }

    /// Forest size the diagram was compiled from (`0` when unknown).
    pub fn n_trees(&self) -> usize {
        self.n_trees as usize
    }

    /// Number of distinct predicates (= diagram levels).
    pub fn n_preds(&self) -> usize {
        self.pred_feature.len()
    }

    /// Feature-index width of the hot plane (`U16` unless the schema
    /// needed the `u32` escape hatch).
    pub fn feat_width(&self) -> FeatWidth {
        self.hot.width()
    }

    /// Threshold encoding of the hot plane (`F16` after
    /// `freeze --quantize-f16`).
    pub fn thresh_quant(&self) -> ThreshQuant {
        self.hot.quant()
    }

    /// Whether a freeze-time feature-column permutation rides with this
    /// diagram (`freeze --pack-features`).
    pub fn packed_features(&self) -> bool {
        self.pack.is_some()
    }

    /// Apply the optional freeze-time layout transforms (the second half
    /// of [`CompiledDD::freeze_with`](crate::compile::CompiledDD::freeze_with)).
    pub fn apply_freeze_opts(mut self, opts: FreezeOpts) -> Result<FrozenDD> {
        if opts.pack_features {
            let perm = builder::feature_permutation(
                self.schema.n_features(),
                self.node_level
                    .iter()
                    .map(|&l| self.pred_feature[l as usize] as usize),
            );
            self.pack = Some(FeatPack::from_perm(Plane::Owned(perm))?);
        }
        if opts.quantize_f16 {
            self = self.quantize_f16()?;
        }
        Ok(self)
    }

    /// Narrow the hot plane to f16 thresholds. The predicate table is
    /// rewritten to the widened (decoded) values, so the hot records,
    /// the cold planes and every evaluation path agree bit-for-bit on
    /// what each node compares against.
    fn quantize_f16(mut self) -> Result<FrozenDD> {
        if !matches!(self.hot, HotPlane::U16(_)) {
            return Err(Error::invalid(
                "f16 threshold quantisation requires the u16 feature encoding",
            ));
        }
        let mut qbits = Vec::with_capacity(self.pred_threshold.len());
        for &t in self.pred_threshold.iter() {
            if !t.is_finite() || t.abs() > storage::F16_MAX {
                return Err(Error::invalid(format!(
                    "threshold {t} is outside the f16 range; freeze without --quantize-f16"
                )));
            }
            qbits.push(storage::f32_to_f16_bits(t));
        }
        // Two distinct thresholds of one feature collapsing onto one f16
        // value would merge predicates the diagram orders strictly —
        // refuse instead of shipping a diagram whose level order lies.
        let mut keys: Vec<(u32, u16, u32)> = self
            .pred_feature
            .iter()
            .zip(self.pred_threshold.iter())
            .zip(qbits.iter())
            .map(|((&f, &t), &q)| (f, q, t.to_bits()))
            .collect();
        keys.sort_unstable();
        for w in keys.windows(2) {
            if w[0].0 == w[1].0 && w[0].1 == w[1].1 && w[0].2 != w[1].2 {
                return Err(Error::invalid(format!(
                    "feature {} thresholds {} and {} collide in f16; freeze without --quantize-f16",
                    w[0].0,
                    f32::from_bits(w[0].2),
                    f32::from_bits(w[1].2),
                )));
            }
        }
        let hot = HotPlane::Q16(Plane::Owned(
            self.node_level
                .iter()
                .map(|&l| HotQ16 {
                    feat: self.pred_feature[l as usize] as u16,
                    qthresh: qbits[l as usize],
                })
                .collect(),
        ));
        self.hot = hot;
        self.pred_threshold = Plane::Owned(
            qbits
                .iter()
                .map(|&q| storage::f16_bits_to_f32(q))
                .collect(),
        );
        Ok(self)
    }

    /// Whether the planes borrow an mmap'd snapshot file (the zero-copy
    /// boot path) rather than owned memory.
    pub fn mapped(&self) -> bool {
        self.mapped
    }

    /// Series label, paper style plus the layout tag
    /// (e.g. `Most frequent class DD* [frozen]`).
    pub fn label(&self) -> String {
        format!("{} [frozen]", self.abstraction.label(self.unsat_elim))
    }

    /// Diagram size (same Fig. 7 / Table 2 measure as
    /// [`CompiledDD::size`](crate::compile::CompiledDD::size)).
    pub fn size(&self) -> SizeStats {
        SizeStats {
            internal: self.hot.len(),
            terminals: self.terminals.len(),
        }
    }

    /// §6 aggregation reads per classification (`n` for class words,
    /// `|C|` for vote vectors, `0` after the majority abstraction).
    pub fn aggregation_reads(&self) -> usize {
        match self.abstraction {
            Abstraction::Word => self.n_trees as usize,
            Abstraction::Vector => self.schema.n_classes(),
            Abstraction::Majority => 0,
        }
    }

    /// Classify one row (majority-vote semantics in every abstraction).
    pub fn classify(&self, x: &[f32]) -> u32 {
        self.classify_with_steps(x).0
    }

    /// Classify with the §6 step metric — bit-identical to
    /// [`CompiledDD::classify_with_steps`](crate::compile::CompiledDD::classify_with_steps)
    /// on the source diagram.
    pub fn classify_with_steps(&self, x: &[f32]) -> (u32, usize) {
        let (t, steps) = with_hot!(self, hot, { walk(hot, &self.lo, &self.hi, self.root, x) });
        (
            u32::from(self.term_class[t]),
            steps as usize + self.term_agg_reads[t] as usize,
        )
    }

    /// Classify a batch through the tiled node sweep, sharding large
    /// batches across the evaluation worker pool.
    ///
    /// Shards are contiguous row ranges with disjoint output slices, so
    /// the result is bit-identical to the single-threaded sweep (and to
    /// per-row walks) regardless of thread count or tile budget.
    pub fn classify_batch(&self, rows: RowMatrix<'_>) -> Vec<u32> {
        let tile = tile_bytes();
        let kernel = simd::kernel();
        let mut out = vec![0u32; rows.n_rows()];
        let sharded = rows.n_rows() >= PAR_MIN_ROWS
            && pool::run_sharded(rows, &mut out, PAR_ROWS_PER_SHARD, |shard, out_chunk| {
                SCRATCH.with(|s| {
                    self.sweep_dispatch::<false, false>(
                        shard,
                        &mut s.borrow_mut(),
                        out_chunk,
                        &mut [],
                        tile,
                        kernel,
                        None,
                    )
                });
            });
        if !sharded {
            SCRATCH.with(|s| {
                self.sweep_dispatch::<false, false>(
                    rows,
                    &mut s.borrow_mut(),
                    &mut out,
                    &mut [],
                    tile,
                    kernel,
                    None,
                )
            });
        }
        out
    }

    /// Serving-path batch classification with the fault-tolerance
    /// guards: the `eval_shard_panic` / `eval_slow` injection points
    /// fire per shard, shard panics are quarantined (the healthy shards
    /// complete, the failure comes back as [`Error::EvalPanic`] naming
    /// the shard and its row range), and `deadline` is checked between
    /// sweep tiles/rounds so expired requests stop consuming cores.
    /// Fault-free, deadline-less calls are bit-identical to
    /// [`FrozenDD::classify_batch`].
    pub fn classify_batch_guarded(
        &self,
        rows: RowMatrix<'_>,
        deadline: Option<Instant>,
    ) -> Result<Vec<u32>> {
        let tile = tile_bytes();
        let kernel = simd::kernel();
        let mut out = vec![0u32; rows.n_rows()];
        let outcome = if rows.n_rows() >= PAR_MIN_ROWS {
            pool::run_sharded_quarantined(rows, &mut out, PAR_ROWS_PER_SHARD, |shard, out_chunk| {
                fault::fire_eval_points();
                SCRATCH.with(|s| {
                    self.sweep_dispatch::<false, false>(
                        shard,
                        &mut s.borrow_mut(),
                        out_chunk,
                        &mut [],
                        tile,
                        kernel,
                        deadline,
                    )
                });
            })
        } else {
            pool::ShardedRun::TooSmall
        };
        match outcome {
            pool::ShardedRun::Done => Ok(out),
            pool::ShardedRun::TooSmall => {
                // Serial path: the injection points still apply; a panic
                // here unwinds into the router's catch_unwind guard.
                fault::fire_eval_points();
                SCRATCH.with(|s| {
                    self.sweep_dispatch::<false, false>(
                        rows,
                        &mut s.borrow_mut(),
                        &mut out,
                        &mut [],
                        tile,
                        kernel,
                        deadline,
                    )
                });
                Ok(out)
            }
            pool::ShardedRun::Quarantined { panic, rows: range } => Err(Error::EvalPanic {
                shard: panic.shard,
                msg: format!("{} (rows {}..{})", panic.msg, range.start, range.end),
            }),
        }
    }

    /// Classify a batch *with the §6 step count per row* — the batch
    /// counterpart of [`FrozenDD::classify_with_steps`], so cost metering
    /// survives the batch path. Sharded and tiled exactly like
    /// [`FrozenDD::classify_batch`]; steps are bit-identical to the
    /// single-row walk.
    pub fn classify_batch_steps(&self, rows: RowMatrix<'_>) -> (Vec<u32>, Vec<u32>) {
        let tile = tile_bytes();
        let kernel = simd::kernel();
        let mut out = vec![0u32; rows.n_rows()];
        let mut steps = vec![0u32; rows.n_rows()];
        let sharded = rows.n_rows() >= PAR_MIN_ROWS
            && pool::run_sharded2(
                rows,
                &mut out,
                &mut steps,
                PAR_ROWS_PER_SHARD,
                |shard, out_chunk, steps_chunk| {
                    SCRATCH.with(|s| {
                        self.sweep_dispatch::<true, false>(
                            shard,
                            &mut s.borrow_mut(),
                            out_chunk,
                            steps_chunk,
                            tile,
                            kernel,
                            None,
                        )
                    });
                },
            );
        if !sharded {
            SCRATCH.with(|s| {
                self.sweep_dispatch::<true, false>(
                    rows,
                    &mut s.borrow_mut(),
                    &mut out,
                    &mut steps,
                    tile,
                    kernel,
                    None,
                )
            });
        }
        (out, steps)
    }

    /// Steps-metered counterpart of [`FrozenDD::classify_batch_guarded`]
    /// — same quarantine, injection, and deadline semantics.
    pub fn classify_batch_steps_guarded(
        &self,
        rows: RowMatrix<'_>,
        deadline: Option<Instant>,
    ) -> Result<(Vec<u32>, Vec<u32>)> {
        let tile = tile_bytes();
        let kernel = simd::kernel();
        let mut out = vec![0u32; rows.n_rows()];
        let mut steps = vec![0u32; rows.n_rows()];
        let outcome = if rows.n_rows() >= PAR_MIN_ROWS {
            pool::run_sharded2_quarantined(
                rows,
                &mut out,
                &mut steps,
                PAR_ROWS_PER_SHARD,
                |shard, out_chunk, steps_chunk| {
                    fault::fire_eval_points();
                    SCRATCH.with(|s| {
                        self.sweep_dispatch::<true, false>(
                            shard,
                            &mut s.borrow_mut(),
                            out_chunk,
                            steps_chunk,
                            tile,
                            kernel,
                            deadline,
                        )
                    });
                },
            )
        } else {
            pool::ShardedRun::TooSmall
        };
        match outcome {
            pool::ShardedRun::Done => Ok((out, steps)),
            pool::ShardedRun::TooSmall => {
                fault::fire_eval_points();
                SCRATCH.with(|s| {
                    self.sweep_dispatch::<true, false>(
                        rows,
                        &mut s.borrow_mut(),
                        &mut out,
                        &mut steps,
                        tile,
                        kernel,
                        deadline,
                    )
                });
                Ok((out, steps))
            }
            pool::ShardedRun::Quarantined { panic, rows: range } => Err(Error::EvalPanic {
                shard: panic.shard,
                msg: format!("{} (rows {}..{})", panic.msg, range.start, range.end),
            }),
        }
    }

    /// Single-threaded batch classification with an explicit, reusable
    /// [`BatchScratch`].
    pub fn classify_batch_with(&self, rows: RowMatrix<'_>, scratch: &mut BatchScratch) -> Vec<u32> {
        let mut out = vec![0u32; rows.n_rows()];
        self.sweep_dispatch::<false, false>(
            rows,
            scratch,
            &mut out,
            &mut [],
            tile_bytes(),
            simd::kernel(),
            None,
        );
        out
    }

    /// Single-threaded batch classification into a caller-owned output
    /// vector — with a warm `scratch` and `out`, the steady state
    /// allocates nothing (asserted by `tests/alloc_frozen.rs`).
    pub fn classify_batch_into(
        &self,
        rows: RowMatrix<'_>,
        scratch: &mut BatchScratch,
        out: &mut Vec<u32>,
    ) {
        self.classify_batch_into_tiled(rows, scratch, out, 0);
    }

    /// [`FrozenDD::classify_batch_into`] with an explicit tile budget in
    /// bytes (`0` = the configured global budget) — the hook benches and
    /// conformance tests use to pin every tile size.
    pub fn classify_batch_into_tiled(
        &self,
        rows: RowMatrix<'_>,
        scratch: &mut BatchScratch,
        out: &mut Vec<u32>,
        tile_budget: usize,
    ) {
        self.classify_batch_kernel_into(rows, scratch, out, tile_budget, simd::kernel());
    }

    /// [`FrozenDD::classify_batch_into_tiled`] with an explicit SIMD
    /// kernel — the hook benches and conformance tests use to pin every
    /// kernel against the scalar walk (and what the `frozen-simd` /
    /// `frozen-scalar` bench series run). Kernels the host cannot execute
    /// are downgraded via [`simd::Kernel::supported`], never trapped on.
    pub fn classify_batch_kernel_into(
        &self,
        rows: RowMatrix<'_>,
        scratch: &mut BatchScratch,
        out: &mut Vec<u32>,
        tile_budget: usize,
        kernel: simd::Kernel,
    ) {
        out.clear();
        out.resize(rows.n_rows(), 0);
        let budget = if tile_budget == 0 {
            tile_bytes()
        } else {
            tile_budget
        };
        self.sweep_dispatch::<false, false>(rows, scratch, out, &mut [], budget, kernel.supported(), None);
    }

    /// Steps-metered single-threaded sweep with an explicit tile budget
    /// (`0` = global) — conformance pins this against per-row walks.
    pub fn classify_batch_steps_into_tiled(
        &self,
        rows: RowMatrix<'_>,
        scratch: &mut BatchScratch,
        out: &mut Vec<u32>,
        steps: &mut Vec<u32>,
        tile_budget: usize,
    ) {
        self.classify_batch_steps_kernel_into(rows, scratch, out, steps, tile_budget, simd::kernel());
    }

    /// Steps-metered counterpart of
    /// [`FrozenDD::classify_batch_kernel_into`]: §6 step counts must
    /// survive every kernel bit-identically too.
    pub fn classify_batch_steps_kernel_into(
        &self,
        rows: RowMatrix<'_>,
        scratch: &mut BatchScratch,
        out: &mut Vec<u32>,
        steps: &mut Vec<u32>,
        tile_budget: usize,
        kernel: simd::Kernel,
    ) {
        out.clear();
        out.resize(rows.n_rows(), 0);
        steps.clear();
        steps.resize(rows.n_rows(), 0);
        let budget = if tile_budget == 0 {
            tile_bytes()
        } else {
            tile_budget
        };
        self.sweep_dispatch::<true, false>(rows, scratch, out, steps, budget, kernel.supported(), None);
    }

    /// Whether this diagram retains full vote distributions: word and
    /// vector terminals carry the complete payload; the majority
    /// abstraction (§4.2) collapsed it to one label at compile time.
    pub fn has_votes(&self) -> bool {
        !matches!(self.abstraction, Abstraction::Majority)
    }

    fn require_votes(&self) -> Result<()> {
        if self.has_votes() {
            Ok(())
        } else {
            Err(Error::invalid(
                "majority-abstracted frozen diagram has discarded vote distributions \
                 (freeze a word or vector diagram to keep them)",
            ))
        }
    }

    /// Per-class vote counts for one row — the full terminal payload the
    /// walk lands on, before any decision rule.
    pub fn votes(&self, x: &[f32]) -> Result<Vec<u32>> {
        self.require_votes()?;
        let (t, _) = with_hot!(self, hot, { walk(hot, &self.lo, &self.hi, self.root, x) });
        let mut v = vec![0u32; self.schema.n_classes()];
        self.terminals.counts_into(t, &mut v);
        Ok(v)
    }

    /// Per-class vote counts for a batch, flattened row-major with stride
    /// `|C|`. Runs the same tiled/SIMD sweeps as
    /// [`FrozenDD::classify_batch`] — sharded across the worker pool — in
    /// raw terminal-index mode, then expands each row's terminal payload:
    /// the distribution comes from exactly the sweep whose argmax the
    /// classification path reports, so the two can never drift.
    pub fn votes_batch(&self, rows: RowMatrix<'_>) -> Result<Vec<u32>> {
        self.require_votes()?;
        let tile = tile_bytes();
        let kernel = simd::kernel();
        let mut terms = vec![0u32; rows.n_rows()];
        let sharded = rows.n_rows() >= PAR_MIN_ROWS
            && pool::run_sharded(rows, &mut terms, PAR_ROWS_PER_SHARD, |shard, out_chunk| {
                SCRATCH.with(|s| {
                    self.sweep_dispatch::<false, true>(
                        shard,
                        &mut s.borrow_mut(),
                        out_chunk,
                        &mut [],
                        tile,
                        kernel,
                        None,
                    )
                });
            });
        if !sharded {
            SCRATCH.with(|s| {
                self.sweep_dispatch::<false, true>(
                    rows,
                    &mut s.borrow_mut(),
                    &mut terms,
                    &mut [],
                    tile,
                    kernel,
                    None,
                )
            });
        }
        Ok(self.expand_terms(&terms))
    }

    /// Kernel- and tile-pinned batch distributions (single-threaded) —
    /// the hook conformance uses to pin every SIMD kernel × tile budget
    /// against the per-row walks, mirroring
    /// [`FrozenDD::classify_batch_kernel_into`].
    pub fn votes_batch_kernel(
        &self,
        rows: RowMatrix<'_>,
        scratch: &mut BatchScratch,
        tile_budget: usize,
        kernel: simd::Kernel,
    ) -> Result<Vec<u32>> {
        self.require_votes()?;
        let budget = if tile_budget == 0 {
            tile_bytes()
        } else {
            tile_budget
        };
        let mut terms = vec![0u32; rows.n_rows()];
        self.sweep_dispatch::<false, true>(
            rows,
            scratch,
            &mut terms,
            &mut [],
            budget,
            kernel.supported(),
            None,
        );
        Ok(self.expand_terms(&terms))
    }

    /// Expand swept terminal indices into flat per-row vote vectors.
    fn expand_terms(&self, terms: &[u32]) -> Vec<u32> {
        let k = self.schema.n_classes();
        let mut out = vec![0u32; terms.len() * k];
        for (i, &t) in terms.iter().enumerate() {
            self.terminals
                .counts_into(t as usize, &mut out[i * k..(i + 1) * k]);
        }
        out
    }

    /// Monomorphise the sweep over the hot-plane encoding. `RAW` switches
    /// the output from decided classes to raw terminal *indices* (the
    /// vote-distribution path reads the full payload afterwards).
    #[allow(clippy::too_many_arguments)]
    fn sweep_dispatch<const STEPS: bool, const RAW: bool>(
        &self,
        rows: RowMatrix<'_>,
        scratch: &mut BatchScratch,
        out: &mut [u32],
        steps: &mut [u32],
        tile_budget: usize,
        kernel: simd::Kernel,
        deadline: Option<Instant>,
    ) {
        with_hot!(self, hot, {
            self.sweep_into::<_, STEPS, RAW>(
                hot,
                rows,
                scratch,
                out,
                steps,
                tile_budget,
                kernel,
                deadline,
            )
        })
    }

    /// The batch sweep front door: pick per-row walks (small batches),
    /// the round-based counting scatter (diagram fits the tile budget) or
    /// the cache-tiled chain sweep (diagram larger than the budget).
    /// Every path writes identical classes — or identical terminal
    /// indices when `RAW` — (and, when `STEPS`, identical §6 step
    /// counts); only the memory traffic differs.
    #[allow(clippy::too_many_arguments)]
    fn sweep_into<H: HotRec, const STEPS: bool, const RAW: bool>(
        &self,
        hot: &[H],
        rows: RowMatrix<'_>,
        scratch: &mut BatchScratch,
        out: &mut [u32],
        steps: &mut [u32],
        tile_budget: usize,
        kernel: simd::Kernel,
        deadline: Option<Instant>,
    ) {
        debug_assert_eq!(out.len(), rows.n_rows());
        debug_assert!(!STEPS || steps.len() == rows.n_rows());
        if rows.is_empty() {
            return;
        }
        if STEPS {
            steps.fill(0);
        }
        let term_class = &self.term_class[..];
        let term_agg = &self.term_agg_reads[..];
        if self.root & TERM_BIT != 0 {
            let t = (self.root & !TERM_BIT) as usize;
            out.fill(if RAW { t as u32 } else { u32::from(term_class[t]) });
            if STEPS {
                steps.fill(term_agg[t]);
            }
            return;
        }
        let n_nodes = hot.len();
        if rows.n_rows().saturating_mul(WALK_FALLBACK_FACTOR) < n_nodes {
            // Small batches walk the raw rows directly: no packing copy,
            // no scratch traffic — the per-row walk is latency-bound.
            let lo = &self.lo[..];
            let hi = &self.hi[..];
            for (i, r) in rows.iter().enumerate() {
                let (t, s) = walk(hot, lo, hi, self.root, r);
                out[i] = if RAW { t as u32 } else { u32::from(term_class[t]) };
                if STEPS {
                    steps[i] = s + term_agg[t];
                }
            }
            return;
        }
        // The batch sweeps gather feature cells by flat index. When the
        // snapshot carries a freeze-time feature permutation, copy the
        // shard's rows into the scratch's packed matrix once (hot columns
        // adjacent → the lane gathers share cache lines) and translate
        // node feature ids through `rank`. `mem::take` sidesteps the
        // scratch borrow while the sweeps hold `&mut scratch`; capacity
        // is preserved, so the warm path stays allocation-free.
        let nf = rows.n_features();
        let mut packed = std::mem::take(&mut scratch.packed);
        let (cells, rank): (&[f32], Option<&[u32]>) = match &self.pack {
            Some(p) => {
                pack_rows(rows, &p.rank, &mut packed);
                (&packed[..], Some(&p.rank[..]))
            }
            None => (rows.data(), None),
        };
        let tile_nodes = tile_span::<H>(tile_budget);
        if tile_nodes >= n_nodes {
            self.rounds_sweep::<H, STEPS, RAW>(
                hot, rows, cells, nf, rank, scratch, out, steps, kernel, deadline,
            );
        } else {
            self.tiled_sweep::<H, STEPS, RAW>(
                hot, rows, cells, nf, rank, scratch, out, steps, tile_nodes, kernel, deadline,
            );
        }
        scratch.packed = packed;
    }

    /// The round-based node-ordered sweep for diagrams whose hot planes
    /// fit the tile budget: each round routes every parked row one step,
    /// reading the touched node span in ascending (sequential) order.
    ///
    /// Parking uses the scratch's counting scatter: routing a round
    /// counts arrivals per destination node, a prefix sum turns counts
    /// into segment offsets, and a stable scatter packs the surviving
    /// rows into one flat slot array for the next round. No per-node
    /// `Vec`s, no allocation once the scratch is warm.
    #[allow(clippy::too_many_arguments)]
    fn rounds_sweep<H: HotRec, const STEPS: bool, const RAW: bool>(
        &self,
        hot: &[H],
        rows: RowMatrix<'_>,
        cells: &[f32],
        nf: usize,
        rank: Option<&[u32]>,
        scratch: &mut BatchScratch,
        out: &mut [u32],
        steps: &mut [u32],
        kernel: simd::Kernel,
        deadline: Option<Instant>,
    ) {
        let lo_arr = &self.lo[..];
        let hi_arr = &self.hi[..];
        let term_class = &self.term_class[..];
        let term_agg = &self.term_agg_reads[..];
        scratch.ensure_rounds(hot.len(), rows.n_rows());
        let BatchScratch {
            count_a,
            count_b,
            off_a,
            off_b,
            slots_a,
            slots_b,
            pending,
            dest,
            ..
        } = scratch;
        // Round 0: every row parked at the root (node 0).
        count_a[0] = rows.n_rows() as u32;
        off_a[0] = rows.n_rows() as u32; // segment *end* offset
        for (i, slot) in slots_a[..rows.n_rows()].iter_mut().enumerate() {
            *slot = i as u32;
        }
        let (mut lo, mut hi) = (0usize, 0usize);
        loop {
            pending.clear();
            dest.clear();
            let (mut next_lo, mut next_hi) = (usize::MAX, 0usize);
            // Route the round node-by-node (ascending = sequential reads
            // of the hot records), counting arrivals per destination.
            for node in lo..=hi {
                let c = count_a[node] as usize;
                if c == 0 {
                    continue;
                }
                count_a[node] = 0; // restore the all-zero invariant
                let end = off_a[node] as usize;
                let rec = hot[node];
                let col = match rank {
                    Some(rk) => rk[rec.feat_ix()] as usize,
                    None => rec.feat_ix(),
                };
                let thresh = rec.threshold();
                let (lo_w, hi_w) = (lo_arr[node], hi_arr[node]);
                let seg = &slots_a[end - c..end];
                // Park or finish one routed row given its stored child
                // word — shared by the lane path and the scalar tail so
                // both write through the exact same bookkeeping.
                macro_rules! route {
                    ($r:expr, $stored:expr) => {{
                        let stored: u32 = $stored;
                        if stored & TERM_BIT != 0 {
                            let t = (stored & !TERM_BIT) as usize;
                            out[$r as usize] =
                                if RAW { t as u32 } else { u32::from(term_class[t]) };
                            if STEPS {
                                steps[$r as usize] += term_agg[t];
                            }
                        } else {
                            let next = node + stored as usize; // delta decode
                            pending.push($r);
                            dest.push(next as u32);
                            count_b[next] += 1;
                            next_lo = next_lo.min(next);
                            next_hi = next_hi.max(next);
                        }
                    }};
                }
                if kernel != simd::Kernel::Scalar {
                    // Lane path: gather LANES parked rows' feature cells,
                    // compare+blend the raw lo/hi words branchlessly, then
                    // route each selected word. The ordered `<` compare is
                    // false on NaN in every kernel, so the selected word —
                    // and therefore the class and step count — is
                    // bit-identical to the scalar walk.
                    let mut chunks = seg.chunks_exact(simd::LANES);
                    for chunk in &mut chunks {
                        let mut xs = [0f32; simd::LANES];
                        for (x, &r) in xs.iter_mut().zip(chunk) {
                            *x = cells[r as usize * nf + col];
                            if STEPS {
                                steps[r as usize] += 1;
                            }
                        }
                        let mut sel = [0u32; simd::LANES];
                        simd::select_deltas(kernel, thresh, lo_w, hi_w, &xs, &mut sel);
                        for (&r, &stored) in chunk.iter().zip(sel.iter()) {
                            route!(r, stored);
                        }
                    }
                    for &r in chunks.remainder() {
                        if STEPS {
                            steps[r as usize] += 1;
                        }
                        let x = cells[r as usize * nf + col];
                        route!(r, if x < thresh { hi_w } else { lo_w });
                    }
                } else {
                    for &r in seg {
                        if STEPS {
                            steps[r as usize] += 1;
                        }
                        let x = cells[r as usize * nf + col];
                        route!(r, if x < thresh { hi_w } else { lo_w });
                    }
                }
            }
            if pending.is_empty() {
                return;
            }
            // Prefix-sum the arrival counts into segment start offsets …
            let mut running = 0u32;
            for node in next_lo..=next_hi {
                off_b[node] = running;
                running += count_b[node];
            }
            // … and stable-scatter the survivors into the flat slot
            // array. After the scatter `off_b` holds segment *end*
            // offsets — exactly the form the next round reads.
            for (&r, &d) in pending.iter().zip(dest.iter()) {
                slots_b[off_b[d as usize] as usize] = r;
                off_b[d as usize] += 1;
            }
            std::mem::swap(count_a, count_b);
            std::mem::swap(off_a, off_b);
            std::mem::swap(slots_a, slots_b);
            lo = next_lo;
            hi = next_hi;
            // Deadline check between rounds: an expired request stops
            // consuming cores. Restore the all-zero count invariant so
            // the scratch stays reusable; the partial output is
            // discarded by the caller (504).
            if deadline.is_some_and(|d| Instant::now() >= d) {
                for node in lo..=hi {
                    count_a[node] = 0;
                }
                return;
            }
        }
    }

    /// The cache-tiled sweep for diagrams larger than the tile budget:
    /// nodes are cut into contiguous topological tiles of `tile_nodes`,
    /// processed in ascending order (children sit strictly after parents,
    /// so each tile is visited exactly once per batch). A row walks as
    /// far as the resident tile allows — every hot record it touches fits
    /// the LLC budget — then parks on the destination tile's intrusive
    /// chain (`head`/`next` in the scratch, O(1) insert, no counting
    /// pass). The working set per tile is one tile of node data plus the
    /// parked rows' features, instead of the whole diagram per round.
    #[allow(clippy::too_many_arguments)]
    fn tiled_sweep<H: HotRec, const STEPS: bool, const RAW: bool>(
        &self,
        hot: &[H],
        rows: RowMatrix<'_>,
        cells: &[f32],
        nf: usize,
        rank: Option<&[u32]>,
        scratch: &mut BatchScratch,
        out: &mut [u32],
        steps: &mut [u32],
        tile_nodes: usize,
        kernel: simd::Kernel,
        deadline: Option<Instant>,
    ) {
        let lo_arr = &self.lo[..];
        let hi_arr = &self.hi[..];
        let term_class = &self.term_class[..];
        let term_agg = &self.term_agg_reads[..];
        let n_nodes = hot.len();
        let n_tiles = n_nodes.div_ceil(tile_nodes);
        let n_rows = rows.n_rows();
        scratch.ensure_tiles(n_tiles, n_rows);
        let BatchScratch {
            head,
            slots_a: next,
            slots_b: node_of,
            ..
        } = scratch;
        // Park every row at the root (node 0, tile 0), chained in row
        // order for feature-buffer locality on the first tile.
        for r in 0..n_rows {
            next[r] = if r + 1 < n_rows {
                (r + 1) as u32
            } else {
                CHAIN_END
            };
            node_of[r] = 0;
        }
        head[0] = 0;
        for k in 0..n_tiles {
            // Deadline check between tiles: a dead request's sweep bails
            // instead of streaming the remaining tiles through cache.
            // Restore the all-empty chain invariant before returning so
            // the scratch stays reusable (output is discarded: 504).
            if k > 0 && deadline.is_some_and(|d| Instant::now() >= d) {
                for h in head[k..n_tiles].iter_mut() {
                    *h = CHAIN_END;
                }
                return;
            }
            let mut r = head[k];
            head[k] = CHAIN_END; // restore the all-empty invariant
            let tile_end = ((k + 1) * tile_nodes).min(n_nodes);
            while r != CHAIN_END {
                let row = r as usize;
                let follow = next[row];
                // Software prefetch: while this row walks the resident
                // tile, pull the *next* chained row's parked node data
                // and feature cells toward L1 — the chain order is the
                // one access pattern the hardware prefetcher cannot see.
                if kernel != simd::Kernel::Scalar && follow != CHAIN_END {
                    let nrow = follow as usize;
                    let pn = node_of[nrow] as usize;
                    simd::prefetch(&hot[pn]);
                    simd::prefetch(&lo_arr[pn]);
                    simd::prefetch(&hi_arr[pn]);
                    simd::prefetch(&cells[nrow * nf]);
                }
                let mut n = node_of[row] as usize;
                let x = &cells[row * nf..row * nf + nf];
                loop {
                    let h = hot[n];
                    if STEPS {
                        steps[row] += 1;
                    }
                    let col = match rank {
                        Some(rk) => rk[h.feat_ix()] as usize,
                        None => h.feat_ix(),
                    };
                    let stored = if x[col] < h.threshold() {
                        hi_arr[n]
                    } else {
                        lo_arr[n]
                    };
                    if stored & TERM_BIT != 0 {
                        let t = (stored & !TERM_BIT) as usize;
                        out[row] = if RAW { t as u32 } else { u32::from(term_class[t]) };
                        if STEPS {
                            steps[row] += term_agg[t];
                        }
                        break;
                    }
                    n += stored as usize;
                    if n >= tile_end {
                        // Park on the destination tile's chain; it will be
                        // routed when that tile becomes resident.
                        let j = n / tile_nodes;
                        node_of[row] = n as u32;
                        next[row] = head[j];
                        head[j] = r;
                        break;
                    }
                }
                r = follow;
            }
        }
    }
}

/// Reusable state of the frozen batch sweeps.
///
/// The round-based sweep uses two (count, offset) array pairs — one for
/// the round being routed, one for the round being built, swapped each
/// round — plus the flat row-slot arrays and the routing-order survivor
/// buffers; counts are kept all-zero between rounds and between calls.
/// The tiled sweep reuses the slot arrays as its `next`/`node` chain
/// links plus a per-tile `head` array kept all-`CHAIN_END` between
/// calls. A warm scratch can therefore be reused across batches, across
/// diagrams, *and across sweep strategies* (buffers only ever grow).
/// `packed` holds the feature-permuted copy of the shard's row matrix
/// when the frozen snapshot carries a freeze-time column packing.
#[derive(Debug, Default)]
pub struct BatchScratch {
    count_a: Vec<u32>,
    count_b: Vec<u32>,
    off_a: Vec<u32>,
    off_b: Vec<u32>,
    slots_a: Vec<u32>,
    slots_b: Vec<u32>,
    pending: Vec<u32>,
    dest: Vec<u32>,
    head: Vec<u32>,
    packed: Vec<f32>,
}

impl BatchScratch {
    /// Empty scratch (buffers grow on first use, then stay).
    pub fn new() -> BatchScratch {
        BatchScratch::default()
    }

    fn ensure_rounds(&mut self, n_nodes: usize, n_rows: usize) {
        if self.count_a.len() < n_nodes {
            self.count_a.resize(n_nodes, 0);
            self.count_b.resize(n_nodes, 0);
            self.off_a.resize(n_nodes, 0);
            self.off_b.resize(n_nodes, 0);
        }
        if self.slots_a.len() < n_rows {
            self.slots_a.resize(n_rows, 0);
            self.slots_b.resize(n_rows, 0);
        }
    }

    fn ensure_tiles(&mut self, n_tiles: usize, n_rows: usize) {
        if self.head.len() < n_tiles {
            self.head.resize(n_tiles, CHAIN_END);
        }
        if self.slots_a.len() < n_rows {
            self.slots_a.resize(n_rows, 0);
            self.slots_b.resize(n_rows, 0);
        }
    }
}

/// Copy a shard's rows into `packed` with columns reordered by `rank`
/// (original feature id → packed slot): hot features land adjacent, so
/// the sweeps' cell gathers share cache lines. `clear` + `resize` keep
/// a warm buffer allocation-free.
fn pack_rows(rows: RowMatrix<'_>, rank: &[u32], packed: &mut Vec<f32>) {
    let nf = rows.n_features();
    packed.clear();
    packed.resize(rows.n_rows() * nf, 0.0);
    for (r, row) in rows.iter().enumerate() {
        let dst = &mut packed[r * nf..(r + 1) * nf];
        for (f, &v) in row.iter().enumerate() {
            dst[rank[f] as usize] = v;
        }
    }
}

thread_local! {
    /// Per-thread sweep scratch: serving threads and pool workers each
    /// reuse their own buffers across batches (and across models), so the
    /// steady-state sweep allocates nothing.
    static SCRATCH: RefCell<BatchScratch> = RefCell::new(BatchScratch::new());
}

/// The deployment backend: the paper's diagram in its flat serving form.
/// Same predictions and step counts as [`BackendKind::Dd`], different
/// memory layout and startup story.
impl Classifier for FrozenDD {
    fn info(&self) -> ClassifierInfo {
        ClassifierInfo {
            backend: BackendKind::Frozen,
            label: self.label(),
            n_features: self.schema.n_features(),
            n_classes: self.schema.n_classes(),
            size_nodes: self.size().total(),
            cost: CostModel {
                // One decision per distinct predicate level at most, plus
                // the abstraction's runtime aggregation reads.
                max_steps: Some(self.n_preds() + self.aggregation_reads()),
                aggregation_reads: self.aggregation_reads(),
                // The frozen walk is allocation-free and microseconds-fast:
                // coalescing single requests through the dynamic batcher
                // would cost more than the node-array pass saves. Explicit
                // batches still hit the native pass via `classify_batch`.
                preferred_batch: 1,
            },
        }
    }

    fn classify_with_steps(&self, x: &[f32]) -> Result<(u32, Option<usize>)> {
        fault::fire_eval_points();
        let (class, steps) = FrozenDD::classify_with_steps(self, x);
        Ok((class, Some(steps)))
    }

    fn classify_batch(&self, rows: RowMatrix<'_>) -> Result<Vec<u32>> {
        let deadline = crate::obs::trace::eval_deadline();
        self.classify_batch_guarded(rows, deadline)
    }

    fn classify_batch_with_steps(
        &self,
        rows: RowMatrix<'_>,
    ) -> Result<(Vec<u32>, Option<Vec<u32>>)> {
        let deadline = crate::obs::trace::eval_deadline();
        let (classes, steps) = self.classify_batch_steps_guarded(rows, deadline)?;
        Ok((classes, Some(steps)))
    }

    fn votes(&self, x: &[f32]) -> Result<Vec<u32>> {
        FrozenDD::votes(self, x)
    }

    fn task_values(&self) -> Option<Vec<f32>> {
        self.schema.values().map(<[f32]>::to_vec)
    }

    fn votes_batch(&self, rows: RowMatrix<'_>) -> Result<Vec<u32>> {
        FrozenDD::votes_batch(self, rows)
    }

    fn as_any(&self) -> Option<&dyn std::any::Any> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compile::{CompileOptions, ForestCompiler};
    use crate::data::datasets;
    use crate::forest::ForestLearner;

    fn frozen_iris(abstraction: Abstraction) -> (crate::data::Dataset, crate::compile::CompiledDD) {
        let ds = datasets::iris();
        let forest = ForestLearner::default().trees(10).seed(21).fit(&ds);
        let dd = ForestCompiler::new(CompileOptions {
            abstraction,
            ..Default::default()
        })
        .compile(&forest)
        .unwrap();
        (ds, dd)
    }

    #[test]
    fn freeze_is_bit_identical_to_the_live_diagram() {
        for abstraction in [Abstraction::Word, Abstraction::Vector, Abstraction::Majority] {
            let (ds, dd) = frozen_iris(abstraction);
            let frozen = dd.freeze();
            assert_eq!(frozen.abstraction(), abstraction);
            assert_eq!(frozen.size(), dd.size(), "{abstraction:?}");
            assert_eq!(frozen.feat_width(), FeatWidth::U16);
            assert!(!frozen.mapped());
            for i in 0..ds.n_rows() {
                assert_eq!(
                    frozen.classify_with_steps(ds.row(i)),
                    dd.classify_with_steps(ds.row(i)),
                    "{abstraction:?} row {i}"
                );
            }
        }
    }

    #[test]
    fn batch_pass_matches_single_row_walks() {
        let (ds, dd) = frozen_iris(Abstraction::Majority);
        let frozen = dd.freeze();
        let rows = ds.matrix();
        let batch = frozen.classify_batch(rows);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(batch[i], frozen.classify(row), "row {i}");
        }
        assert!(frozen.classify_batch(RowMatrix::empty()).is_empty());
        // Tiny batches take the per-row fallback; answers must not change.
        assert_eq!(
            frozen.classify_batch(rows.slice(0, 1)),
            vec![frozen.classify(rows.row(0))]
        );
    }

    #[test]
    fn sweep_counting_scatter_and_sharded_path_match_walks() {
        let (ds, dd) = frozen_iris(Abstraction::Majority);
        let frozen = dd.freeze();
        // Tile the dataset far past both the walk-fallback and the
        // parallel crossover so the counting-scatter sweep and the
        // sharded path genuinely run.
        let tiled = crate::bench_support::tile_rows(&ds, 4096, 7);
        let rows = tiled.as_matrix();
        let want: Vec<u32> = rows.iter().map(|r| frozen.classify(r)).collect();

        // explicit-scratch single-threaded sweep
        let mut scratch = BatchScratch::new();
        assert_eq!(frozen.classify_batch_with(rows, &mut scratch), want);
        // warm-scratch reuse (the zero-invariant must survive a batch) …
        let mut out = Vec::new();
        frozen.classify_batch_into(rows, &mut scratch, &mut out);
        assert_eq!(out, want);
        // … and reuse across a *different* diagram
        let (ds2, dd2) = frozen_iris(Abstraction::Word);
        let frozen2 = dd2.freeze();
        frozen2.classify_batch_into(ds2.matrix(), &mut scratch, &mut out);
        let want2: Vec<u32> = ds2.matrix().iter().map(|r| frozen2.classify(r)).collect();
        assert_eq!(out, want2);
        // the auto path (possibly sharded across the pool) is bit-identical
        assert_eq!(frozen.classify_batch(rows), want);
    }

    #[test]
    fn tiled_sweep_matches_walks_at_every_tile_size() {
        let (ds, dd) = frozen_iris(Abstraction::Majority);
        let frozen = dd.freeze();
        let tiled = crate::bench_support::tile_rows(&ds, 4096, 5);
        let rows = tiled.as_matrix();
        let want: Vec<u32> = rows.iter().map(|r| frozen.classify(r)).collect();
        let want_steps: Vec<u32> = rows
            .iter()
            .map(|r| frozen.classify_with_steps(r).1 as u32)
            .collect();
        let mut scratch = BatchScratch::new();
        let mut out = Vec::new();
        let mut steps = Vec::new();
        // budget 1 forces MIN_TILE_NODES-sized tiles (the chain sweep);
        // larger budgets cross back into the round sweep; 0 = global.
        for tile_budget in [1usize, 600, 4096, 1 << 20, 0] {
            frozen.classify_batch_into_tiled(rows, &mut scratch, &mut out, tile_budget);
            assert_eq!(out, want, "tile budget {tile_budget}");
            frozen.classify_batch_steps_into_tiled(
                rows,
                &mut scratch,
                &mut out,
                &mut steps,
                tile_budget,
            );
            assert_eq!(out, want, "steps classes, tile budget {tile_budget}");
            assert_eq!(steps, want_steps, "steps, tile budget {tile_budget}");
        }
        // the sharded steps API agrees too
        let (classes, steps) = frozen.classify_batch_steps(rows);
        assert_eq!(classes, want);
        assert_eq!(steps, want_steps);
        // Global tile budget configuration round-trips. Set and restore
        // back-to-back: the budget is process-wide and other tests run
        // concurrently (any budget still yields identical answers, so
        // the brief window only shifts which sweep they exercise).
        assert_eq!(configure_tile_bytes(123), 123);
        assert_eq!(configure_tile_bytes(0), DEFAULT_TILE_BYTES);
    }

    #[test]
    fn u32_escape_hatch_matches_u16_encoding() {
        use crate::data::{Feature, FeatureKind};
        let schema = Schema {
            features: vec![
                Feature {
                    name: "x0".into(),
                    kind: FeatureKind::Numeric,
                },
                Feature {
                    name: "x1".into(),
                    kind: FeatureKind::Numeric,
                },
            ],
            classes: vec!["a".into(), "b".into()],
            task: crate::data::Task::Classification,
        };
        let raw = || RawFrozen {
            schema: schema.clone(),
            abstraction: Abstraction::Majority,
            unsat_elim: true,
            n_trees: 3,
            pred_feature: vec![0, 1],
            pred_threshold: vec![0.5, 0.5],
            node_level: vec![0, 1],
            node_lo: vec![1, TERM_BIT],
            node_hi: vec![TERM_BIT, TERM_BIT | 1],
            root: 0,
            terminals: FrozenTerminals::Majority {
                classes: vec![0, 1],
            },
        };
        let narrow = FrozenDD::from_raw(raw()).unwrap();
        let wide = FrozenDD::from_raw_with_width(raw(), Some(FeatWidth::U32)).unwrap();
        assert_eq!(narrow.feat_width(), FeatWidth::U16);
        assert_eq!(wide.feat_width(), FeatWidth::U32);
        for x in [[0.4f32, 0.9], [0.6, 0.4], [0.6, 0.9]] {
            assert_eq!(narrow.classify_with_steps(&x), wide.classify_with_steps(&x));
        }
        // both encodings survive a snapshot round-trip with their width
        let back = FrozenDD::from_bytes(&wide.to_bytes()).unwrap();
        assert_eq!(back.feat_width(), FeatWidth::U32);
        assert_eq!(back.to_bytes(), wide.to_bytes());
    }

    #[test]
    fn classifier_trait_reports_frozen_backend() {
        let (ds, dd) = frozen_iris(Abstraction::Majority);
        let frozen = dd.freeze();
        let info = Classifier::info(&frozen);
        assert_eq!(info.backend, BackendKind::Frozen);
        assert_eq!(info.label, "Most frequent class DD* [frozen]");
        assert_eq!(info.size_nodes, dd.size().total());
        assert_eq!(info.cost.aggregation_reads, 0);
        assert_eq!(info.cost.preferred_batch, 1);
        let c: &dyn Classifier = &frozen;
        let (class, steps) = c.classify_with_steps(ds.row(0)).unwrap();
        assert_eq!((class, steps.unwrap()), dd.classify_with_steps(ds.row(0)));
        // the trait's metered batch path reports the same steps
        let (classes, batch_steps) = c.classify_batch_with_steps(ds.matrix()).unwrap();
        let batch_steps = batch_steps.unwrap();
        for (i, row) in ds.matrix().iter().enumerate() {
            let (want_c, want_s) = dd.classify_with_steps(row);
            assert_eq!(classes[i], want_c, "row {i}");
            assert_eq!(batch_steps[i] as usize, want_s, "row {i}");
        }
    }

    #[test]
    fn word_and_vector_keep_their_aggregation_reads() {
        let (_, word) = frozen_iris(Abstraction::Word);
        let (_, vector) = frozen_iris(Abstraction::Vector);
        assert_eq!(word.freeze().aggregation_reads(), 10);
        assert_eq!(vector.freeze().aggregation_reads(), 3);
        assert_eq!(word.freeze().n_trees(), 10);
    }

    #[test]
    fn single_terminal_diagram_freezes() {
        // A one-tree forest on a trivial dataset can collapse to a single
        // terminal after the majority abstraction; the frozen form must
        // handle a TERM_BIT-tagged root.
        let ds = datasets::lenses();
        let forest = ForestLearner::default().trees(1).max_depth(1).seed(3).fit(&ds);
        let dd = ForestCompiler::new(CompileOptions::default())
            .compile(&forest)
            .unwrap();
        let frozen = dd.freeze();
        let rows = ds.matrix();
        let batch = frozen.classify_batch(rows);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(frozen.classify_with_steps(row), dd.classify_with_steps(row));
            assert_eq!(batch[i], dd.classify(row));
        }
        // a single-terminal diagram must also survive the scratch path
        let mut scratch = BatchScratch::new();
        assert_eq!(frozen.classify_batch_with(rows, &mut scratch), batch);
        // … and the steps variant
        let (classes, steps) = frozen.classify_batch_steps(rows);
        assert_eq!(classes, batch);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(steps[i] as usize, frozen.classify_with_steps(row).1, "row {i}");
        }
    }

    #[test]
    fn votes_match_the_forest_across_every_sweep_strategy() {
        let ds = datasets::iris();
        let forest = ForestLearner::default().trees(10).seed(21).fit(&ds);
        for abstraction in [Abstraction::Word, Abstraction::Vector] {
            let frozen = ForestCompiler::new(CompileOptions {
                abstraction,
                ..Default::default()
            })
            .compile(&forest)
            .unwrap()
            .freeze();
            // single-row walks
            for i in (0..ds.n_rows()).step_by(13) {
                assert_eq!(
                    frozen.votes(ds.row(i)).unwrap(),
                    forest.votes(ds.row(i)),
                    "{abstraction:?} row {i}"
                );
            }
            // batch path past the walk-fallback and parallel crossovers
            let tiled = crate::bench_support::tile_rows(&ds, 4096, 9);
            let rows = tiled.as_matrix();
            let want: Vec<u32> = rows.iter().flat_map(|r| forest.votes(r)).collect();
            assert_eq!(frozen.votes_batch(rows).unwrap(), want, "{abstraction:?}");
            // every kernel × tile budget produces the same bits
            let mut scratch = BatchScratch::new();
            for kernel in simd::available() {
                for tile_budget in [1usize, 4096, 0] {
                    assert_eq!(
                        frozen
                            .votes_batch_kernel(rows, &mut scratch, tile_budget, kernel)
                            .unwrap(),
                        want,
                        "{abstraction:?} {} tile {tile_budget}",
                        kernel.name()
                    );
                }
            }
        }
        // the majority freeze refuses: the payload is gone
        let mv = ForestCompiler::new(CompileOptions::default())
            .compile(&forest)
            .unwrap()
            .freeze();
        assert!(!mv.has_votes());
        assert!(mv.votes(ds.row(0)).is_err());
        assert!(mv.votes_batch(ds.matrix()).is_err());
    }

    #[test]
    fn single_terminal_diagram_votes() {
        // One depth-1 tree on pure-class rows collapses to a single
        // terminal; the TERM_BIT-tagged-root path must expand payloads too.
        let ds = datasets::iris();
        let rows: Vec<usize> = (0..50).collect(); // pure setosa
        let pure = ds.select(&rows);
        let forest = ForestLearner::default().trees(3).max_depth(1).seed(0).fit(&pure);
        let frozen = ForestCompiler::new(CompileOptions {
            abstraction: Abstraction::Vector,
            ..Default::default()
        })
        .compile(&forest)
        .unwrap()
        .freeze();
        let want = forest.votes(pure.row(0));
        assert_eq!(frozen.votes(pure.row(0)).unwrap(), want);
        let flat = frozen.votes_batch(pure.matrix()).unwrap();
        assert_eq!(flat.len(), pure.n_rows() * pure.n_classes());
        assert_eq!(&flat[..pure.n_classes()], &want[..]);
    }

    #[test]
    fn terminal_majority_ties_break_low() {
        let mut raw = FrozenTerminals::empty_vector(3);
        raw.push_vector(&[2, 2, 1]);
        raw.push_vector(&[0, 1, 1]);
        assert_eq!(raw.infer_trees(), 5);
        let t = TermPlanes::from_raw(raw);
        let mut counts = Vec::new();
        assert_eq!(
            t.class_of_with(0, 3, &mut counts),
            0,
            "tie must break to the lowest class"
        );
        assert_eq!(t.class_of_with(1, 3, &mut counts), 1);
        assert_eq!(t.agg_reads_of(0, 3), 3);
        let mut raw = FrozenTerminals::empty_word();
        raw.push_word(&[1, 0, 1]);
        raw.push_word(&[]);
        assert_eq!(raw.len(), 2);
        assert_eq!(raw.infer_trees(), 3);
        let w = TermPlanes::from_raw(raw);
        assert_eq!(w.len(), 2);
        assert_eq!(w.class_of_with(0, 2, &mut counts), 1);
        assert_eq!(
            w.class_of_with(1, 2, &mut counts),
            0,
            "empty word votes for class 0"
        );
        assert_eq!(w.agg_reads_of(0, 2), 3);
        assert_eq!(w.agg_reads_of(1, 2), 0);
    }

    /// Big NaN-bearing row block: iris tiled past both the walk-fallback
    /// and parallel crossovers, with a sprinkling of NaN cells (which must
    /// route to `lo` in every kernel — ordered `<` is false on NaN).
    fn nan_bearing_rows(ds: &crate::data::Dataset) -> Vec<f32> {
        let nf = ds.n_features();
        let mut data = Vec::with_capacity(4096 * nf);
        for i in 0..4096 {
            data.extend_from_slice(ds.row(i % ds.n_rows()));
            if i % 17 == 0 {
                let cell = data.len() - 1 - (i % nf);
                data[cell] = f32::NAN;
            }
        }
        data
    }

    #[test]
    fn every_available_kernel_matches_the_scalar_walk() {
        let (ds, dd) = frozen_iris(Abstraction::Majority);
        let frozen = dd.freeze();
        let data = nan_bearing_rows(&ds);
        let rows = RowMatrix::new(&data, ds.n_features()).unwrap();
        let want: Vec<u32> = rows.iter().map(|r| frozen.classify(r)).collect();
        let want_steps: Vec<u32> = rows
            .iter()
            .map(|r| frozen.classify_with_steps(r).1 as u32)
            .collect();
        let mut scratch = BatchScratch::new();
        let (mut out, mut steps) = (Vec::new(), Vec::new());
        for kernel in simd::available() {
            for tile_budget in [1usize, 4096, 0] {
                frozen.classify_batch_kernel_into(rows, &mut scratch, &mut out, tile_budget, kernel);
                assert_eq!(out, want, "{} classes, tile budget {tile_budget}", kernel.name());
                frozen.classify_batch_steps_kernel_into(
                    rows,
                    &mut scratch,
                    &mut out,
                    &mut steps,
                    tile_budget,
                    kernel,
                );
                assert_eq!(out, want, "{} steps classes, {tile_budget}", kernel.name());
                assert_eq!(steps, want_steps, "{} steps, {tile_budget}", kernel.name());
            }
        }
        // Unsupported kernel requests downgrade instead of trapping.
        frozen.classify_batch_kernel_into(rows, &mut scratch, &mut out, 0, simd::Kernel::Avx2);
        assert_eq!(out, want);
    }

    #[test]
    fn quantized_freeze_is_bit_identical_and_roundtrips() {
        for abstraction in [Abstraction::Word, Abstraction::Vector, Abstraction::Majority] {
            let (ds, dd) = frozen_iris(abstraction);
            let plain = dd.freeze();
            let q = dd
                .freeze()
                .apply_freeze_opts(FreezeOpts {
                    quantize_f16: true,
                    ..Default::default()
                })
                .unwrap();
            assert_eq!(q.thresh_quant(), ThreshQuant::F16);
            assert_eq!(q.feat_width(), FeatWidth::U16);
            for i in 0..ds.n_rows() {
                assert_eq!(
                    q.classify_with_steps(ds.row(i)),
                    plain.classify_with_steps(ds.row(i)),
                    "{abstraction:?} row {i}"
                );
            }
            // snapshot round-trip keeps the quantised plane byte-identical
            let bytes = q.to_bytes();
            let back = FrozenDD::from_bytes(&bytes).unwrap();
            assert_eq!(back.thresh_quant(), ThreshQuant::F16);
            assert_eq!(back.to_bytes(), bytes);
            assert_eq!(back.classify(ds.row(0)), plain.classify(ds.row(0)));
        }
    }

    #[test]
    fn packed_freeze_is_bit_identical_and_roundtrips() {
        let (ds, dd) = frozen_iris(Abstraction::Majority);
        let plain = dd.freeze();
        let packed = dd
            .freeze()
            .apply_freeze_opts(FreezeOpts {
                pack_features: true,
                quantize_f16: true,
            })
            .unwrap();
        assert!(packed.packed_features());
        let data = nan_bearing_rows(&ds);
        let rows = RowMatrix::new(&data, ds.n_features()).unwrap();
        // single-row walks, batch sweeps (all strategies) and §6 steps all
        // agree with the unpacked freeze
        let want: Vec<u32> = rows.iter().map(|r| plain.classify(r)).collect();
        let mut scratch = BatchScratch::new();
        let (mut out, mut steps) = (Vec::new(), Vec::new());
        for tile_budget in [1usize, 4096, 0] {
            packed.classify_batch_into_tiled(rows, &mut scratch, &mut out, tile_budget);
            assert_eq!(out, want, "tile budget {tile_budget}");
            packed.classify_batch_steps_into_tiled(rows, &mut scratch, &mut out, &mut steps, tile_budget);
            assert_eq!(out, want, "steps classes, tile budget {tile_budget}");
        }
        for (i, r) in rows.iter().enumerate().take(64) {
            assert_eq!(
                packed.classify_with_steps(r),
                plain.classify_with_steps(r),
                "row {i}"
            );
        }
        assert_eq!(packed.classify_batch(rows), want); // sharded path
        // snapshot round-trip preserves the permutation section
        let bytes = packed.to_bytes();
        let back = FrozenDD::from_bytes(&bytes).unwrap();
        assert!(back.packed_features());
        assert_eq!(back.to_bytes(), bytes);
        back.classify_batch_into(rows, &mut scratch, &mut out);
        assert_eq!(out, want);
        // an unpacked freeze writes no permutation section at all
        assert!(!plain.packed_features());
    }

    #[test]
    fn quantize_rejects_unsafe_thresholds() {
        use crate::data::{Feature, FeatureKind};
        let schema = Schema {
            features: vec![Feature {
                name: "x0".into(),
                kind: FeatureKind::Numeric,
            }],
            classes: vec!["a".into(), "b".into()],
            task: crate::data::Task::Classification,
        };
        let raw = |t0: f32, t1: f32| RawFrozen {
            schema: schema.clone(),
            abstraction: Abstraction::Majority,
            unsat_elim: true,
            n_trees: 3,
            pred_feature: vec![0, 0],
            pred_threshold: vec![t0, t1],
            node_level: vec![0, 1],
            node_lo: vec![1, TERM_BIT],
            node_hi: vec![TERM_BIT, TERM_BIT | 1],
            root: 0,
            terminals: FrozenTerminals::Majority {
                classes: vec![0, 1],
            },
        };
        let quantize = |t0: f32, t1: f32| {
            FrozenDD::from_raw(raw(t0, t1))
                .unwrap()
                .apply_freeze_opts(FreezeOpts {
                    quantize_f16: true,
                    ..Default::default()
                })
        };
        // out-of-f16-range threshold
        assert!(quantize(1.0e9, 0.5).is_err());
        // two distinct thresholds on one feature that collide in f16
        assert!(quantize(1.0, 1.000_01).is_err());
        // distinct-but-representable thresholds are fine
        assert!(quantize(1.0, 1.5).is_ok());
        // the wide feature encoding cannot be quantised
        let wide = FrozenDD::from_raw_with_width(raw(0.5, 0.25), Some(FeatWidth::U32)).unwrap();
        assert!(wide
            .apply_freeze_opts(FreezeOpts {
                quantize_f16: true,
                ..Default::default()
            })
            .is_err());
    }
}
