//! FrozenDD: the flat, immutable serving form of a compiled diagram.
//!
//! A [`CompiledDD`](crate::compile::CompiledDD) lives in a hash-consed
//! arena ([`add::Manager`](crate::add::Manager)) — ideal for aggregation,
//! but every evaluation pays pointer-chasing through node ids, a predicate
//! pool indirection per decision, and JSON parsing at replica startup.
//! Post-compilation the diagram never changes, so the serving fleet runs
//! this frozen rendering instead:
//!
//! - **Struct-of-arrays node storage** in topological order (the root is
//!   node 0; every child sits at a strictly greater index), with the
//!   predicate's feature index and threshold inlined per node — one
//!   16-byte record per decision, no pool lookup on the walk.
//! - **Terminals inlined per abstraction** (class words, vote vectors, or
//!   bare labels), with the majority class and the §6 aggregation reads
//!   precomputed per terminal, so evaluation never allocates.
//! - **A true batch path** ([`FrozenDD::classify_batch`]): a node-ordered
//!   sweep moves every row of a [`RowMatrix`] batch through the diagram
//!   together, loading each node once per round instead of once per row.
//!   Row parking is a reusable two-pass counting scatter ([`BatchScratch`]:
//!   count arrivals per node → prefix-sum offsets → stable scatter into
//!   one flat `Vec<u32>`), so steady-state batches allocate nothing, and
//!   large batches are sharded across the evaluation worker pool
//!   ([`crate::runtime::pool`]) behind a size-crossover heuristic.
//! - **A binary snapshot** ([`snapshot`], format `forest-add/fdd-v1`)
//!   that writes and reloads the whole structure with a single contiguous
//!   read — replicas start from a pre-compiled artifact in milliseconds.
//!
//! Predictions and §6 step counts are bit-identical to the source
//! `CompiledDD` (enforced by `tests/conformance.rs`): freezing is a
//! memory-layout change, never a semantic one.

pub mod snapshot;

pub(crate) mod builder;
mod validate;

use crate::add::terminal::argmax;
use crate::add::SizeStats;
use crate::batch::RowMatrix;
use crate::classifier::{BackendKind, Classifier, ClassifierInfo, CostModel};
use crate::compile::Abstraction;
use crate::data::Schema;
use crate::error::Result;
use crate::runtime::pool;
use std::cell::RefCell;

/// Batches with fewer rows than `nodes / WALK_FALLBACK_FACTOR` take
/// per-row walks instead of the node-ordered sweep (the sweep's cost is
/// dominated by the node span it touches, not the row count).
const WALK_FALLBACK_FACTOR: usize = 32;

/// Minimum batch size before the sweep is sharded across the worker pool.
const PAR_MIN_ROWS: usize = 512;

/// Minimum rows per parallel shard (below this, fan-out overhead eats
/// the multi-core win).
const PAR_ROWS_PER_SHARD: usize = 256;

/// High bit of a child reference: set ⇒ the remaining bits index the
/// terminal arrays, clear ⇒ they index the node arrays. Mirrors the
/// [`add::NodeId`](crate::add::NodeId) tagging convention.
pub const TERM_BIT: u32 = 1 << 31;

/// One decision node in the frozen layout: the predicate `x[feat] <
/// thresh` inlined, plus the two child references.
#[derive(Debug, Clone, Copy, PartialEq)]
struct FrozenNode {
    /// Feature column tested.
    feat: u32,
    /// Strict upper-bound threshold.
    thresh: f32,
    /// Child when the predicate fails.
    lo: u32,
    /// Child when the predicate holds.
    hi: u32,
}

/// Terminal storage, one variant per [`Abstraction`]. Payloads are kept
/// verbatim (not just the precomputed class) so snapshots remain
/// information-complete and `inspect` can show what a terminal carries.
#[derive(Debug, Clone, PartialEq, Eq)]
pub(crate) enum FrozenTerminals {
    /// Class words: terminal `i` is `symbols[offsets[i]..offsets[i + 1]]`.
    Word { offsets: Vec<u32>, symbols: Vec<u16> },
    /// Vote vectors: terminal `i` is `counts[i * stride..(i + 1) * stride]`.
    Vector { stride: u32, counts: Vec<u32> },
    /// Bare class labels.
    Majority { classes: Vec<u16> },
}

impl FrozenTerminals {
    pub(crate) fn empty_word() -> FrozenTerminals {
        FrozenTerminals::Word {
            offsets: vec![0],
            symbols: Vec::new(),
        }
    }

    pub(crate) fn empty_vector(n_classes: usize) -> FrozenTerminals {
        FrozenTerminals::Vector {
            stride: n_classes as u32,
            counts: Vec::new(),
        }
    }

    pub(crate) fn empty_majority() -> FrozenTerminals {
        FrozenTerminals::Majority {
            classes: Vec::new(),
        }
    }

    pub(crate) fn push_word(&mut self, word: &[u16]) {
        match self {
            FrozenTerminals::Word { offsets, symbols } => {
                symbols.extend_from_slice(word);
                offsets.push(symbols.len() as u32);
            }
            _ => panic!("terminal kind mismatch: expected word storage"),
        }
    }

    pub(crate) fn push_vector(&mut self, row: &[u32]) {
        match self {
            FrozenTerminals::Vector { stride, counts } => {
                assert_eq!(row.len(), *stride as usize, "vote vector arity");
                counts.extend_from_slice(row);
            }
            _ => panic!("terminal kind mismatch: expected vector storage"),
        }
    }

    pub(crate) fn push_class(&mut self, class: u16) {
        match self {
            FrozenTerminals::Majority { classes } => classes.push(class),
            _ => panic!("terminal kind mismatch: expected majority storage"),
        }
    }

    /// Number of terminals stored.
    pub(crate) fn len(&self) -> usize {
        match self {
            FrozenTerminals::Word { offsets, .. } => offsets.len() - 1,
            FrozenTerminals::Vector { stride, counts } => {
                if *stride == 0 {
                    0
                } else {
                    counts.len() / *stride as usize
                }
            }
            FrozenTerminals::Majority { classes } => classes.len(),
        }
    }

    /// The abstraction this storage belongs to.
    pub(crate) fn abstraction(&self) -> Abstraction {
        match self {
            FrozenTerminals::Word { .. } => Abstraction::Word,
            FrozenTerminals::Vector { .. } => Abstraction::Vector,
            FrozenTerminals::Majority { .. } => Abstraction::Majority,
        }
    }

    /// Majority class of terminal `i`, via the crate's one `argmax`
    /// (ties break to the lowest class index, like every other layout).
    fn class_of(&self, i: usize, n_classes: usize) -> u16 {
        match self {
            FrozenTerminals::Word { offsets, symbols } => {
                let mut counts = vec![0u32; n_classes];
                for &s in &symbols[offsets[i] as usize..offsets[i + 1] as usize] {
                    counts[s as usize] += 1;
                }
                argmax(&counts)
            }
            FrozenTerminals::Vector { stride, counts } => {
                let s = *stride as usize;
                argmax(&counts[i * s..(i + 1) * s])
            }
            FrozenTerminals::Majority { classes } => classes[i],
        }
    }

    /// §6 aggregation reads still paid at runtime when terminal `i` is
    /// reached: the word length for class words, `|C|` for vote vectors,
    /// zero after the majority abstraction.
    fn agg_reads_of(&self, i: usize, n_classes: usize) -> u32 {
        match self {
            FrozenTerminals::Word { offsets, .. } => offsets[i + 1] - offsets[i],
            FrozenTerminals::Vector { .. } => n_classes as u32,
            FrozenTerminals::Majority { .. } => 0,
        }
    }

    /// Best-effort forest size recovered from the payloads (word length /
    /// vote total), for diagrams whose compile stats were not persisted.
    fn infer_trees(&self) -> u32 {
        match self {
            FrozenTerminals::Word { offsets, .. } => offsets
                .windows(2)
                .map(|w| w[1] - w[0])
                .max()
                .unwrap_or(0),
            FrozenTerminals::Vector { stride, counts } => {
                if *stride == 0 {
                    0
                } else {
                    counts
                        .chunks_exact(*stride as usize)
                        .map(|row| row.iter().sum())
                        .max()
                        .unwrap_or(0)
                }
            }
            FrozenTerminals::Majority { .. } => 0,
        }
    }
}

/// The raw (serialisable) fields of a [`FrozenDD`], before validation and
/// derivation of the evaluation arrays. Built by [`builder::freeze_cone`]
/// and by the [`snapshot`] loader.
pub(crate) struct RawFrozen {
    pub schema: Schema,
    pub abstraction: Abstraction,
    pub unsat_elim: bool,
    pub n_trees: u32,
    /// Predicate tables, indexed by level (the global variable order).
    pub pred_feature: Vec<u32>,
    pub pred_threshold: Vec<f32>,
    /// Node arrays in topological order (root first, children strictly
    /// after parents).
    pub node_level: Vec<u32>,
    pub node_lo: Vec<u32>,
    pub node_hi: Vec<u32>,
    /// Root reference ([`TERM_BIT`]-tagged when the diagram is a single
    /// terminal; otherwise always node 0).
    pub root: u32,
    pub terminals: FrozenTerminals,
}

/// An immutable, cache-friendly snapshot of a compiled decision diagram.
///
/// Built with [`CompiledDD::freeze`](crate::compile::CompiledDD::freeze)
/// (or loaded from an `fdd-v1` snapshot via [`FrozenDD::load`]) and served
/// through the [`Classifier`] trait as [`BackendKind::Frozen`].
#[derive(Debug, Clone)]
pub struct FrozenDD {
    schema: Schema,
    abstraction: Abstraction,
    unsat_elim: bool,
    n_trees: u32,
    pred_feature: Vec<u32>,
    pred_threshold: Vec<f32>,
    node_level: Vec<u32>,
    root: u32,
    terminals: FrozenTerminals,
    /// Derived at build/load time, never serialised: the walk-ready node
    /// records (predicate inlined) …
    nodes: Vec<FrozenNode>,
    /// … and the per-terminal majority class / §6 aggregation reads.
    term_class: Vec<u16>,
    term_agg_reads: Vec<u32>,
}

impl FrozenDD {
    /// Validate raw fields and derive the evaluation arrays.
    pub(crate) fn from_raw(raw: RawFrozen) -> Result<FrozenDD> {
        validate::validate(&raw)?;
        let RawFrozen {
            schema,
            abstraction,
            unsat_elim,
            n_trees,
            pred_feature,
            pred_threshold,
            node_level,
            node_lo,
            node_hi,
            root,
            terminals,
        } = raw;
        let nodes = node_level
            .iter()
            .zip(node_lo.iter().zip(&node_hi))
            .map(|(&level, (&lo, &hi))| FrozenNode {
                feat: pred_feature[level as usize],
                thresh: pred_threshold[level as usize],
                lo,
                hi,
            })
            .collect();
        let n_classes = schema.n_classes();
        let term_class = (0..terminals.len())
            .map(|i| terminals.class_of(i, n_classes))
            .collect();
        let term_agg_reads = (0..terminals.len())
            .map(|i| terminals.agg_reads_of(i, n_classes))
            .collect();
        Ok(FrozenDD {
            schema,
            abstraction,
            unsat_elim,
            n_trees,
            pred_feature,
            pred_threshold,
            node_level,
            root,
            terminals,
            nodes,
            term_class,
            term_agg_reads,
        })
    }

    /// Schema of the training data.
    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    /// Which abstraction the terminals carry.
    pub fn abstraction(&self) -> Abstraction {
        self.abstraction
    }

    /// Whether unsatisfiable-path elimination was applied at compile time.
    pub fn unsat_elim(&self) -> bool {
        self.unsat_elim
    }

    /// Forest size the diagram was compiled from (`0` when unknown).
    pub fn n_trees(&self) -> usize {
        self.n_trees as usize
    }

    /// Number of distinct predicates (= diagram levels).
    pub fn n_preds(&self) -> usize {
        self.pred_feature.len()
    }

    /// Series label, paper style plus the layout tag
    /// (e.g. `Most frequent class DD* [frozen]`).
    pub fn label(&self) -> String {
        format!("{} [frozen]", self.abstraction.label(self.unsat_elim))
    }

    /// Diagram size (same Fig. 7 / Table 2 measure as
    /// [`CompiledDD::size`](crate::compile::CompiledDD::size)).
    pub fn size(&self) -> SizeStats {
        SizeStats {
            internal: self.nodes.len(),
            terminals: self.terminals.len(),
        }
    }

    /// §6 aggregation reads per classification (`n` for class words,
    /// `|C|` for vote vectors, `0` after the majority abstraction).
    pub fn aggregation_reads(&self) -> usize {
        match self.abstraction {
            Abstraction::Word => self.n_trees as usize,
            Abstraction::Vector => self.schema.n_classes(),
            Abstraction::Majority => 0,
        }
    }

    /// Classify one row (majority-vote semantics in every abstraction).
    pub fn classify(&self, x: &[f32]) -> u32 {
        self.classify_with_steps(x).0
    }

    /// Classify with the §6 step metric — bit-identical to
    /// [`CompiledDD::classify_with_steps`](crate::compile::CompiledDD::classify_with_steps)
    /// on the source diagram.
    pub fn classify_with_steps(&self, x: &[f32]) -> (u32, usize) {
        let mut id = self.root;
        let mut steps = 0usize;
        while id & TERM_BIT == 0 {
            let n = &self.nodes[id as usize];
            steps += 1;
            // One 16-byte record per decision; the compare feeds a select,
            // not a data-dependent pointer chase through an arena.
            id = if x[n.feat as usize] < n.thresh {
                n.hi
            } else {
                n.lo
            };
        }
        let t = (id & !TERM_BIT) as usize;
        (
            u32::from(self.term_class[t]),
            steps + self.term_agg_reads[t] as usize,
        )
    }

    /// Classify a batch through the node-ordered sweep, sharding large
    /// batches across the evaluation worker pool.
    ///
    /// Shards are contiguous row ranges with disjoint output slices, so
    /// the result is bit-identical to the single-threaded sweep (and to
    /// per-row walks) regardless of thread count.
    pub fn classify_batch(&self, rows: RowMatrix<'_>) -> Vec<u32> {
        let mut out = vec![0u32; rows.n_rows()];
        let sharded = rows.n_rows() >= PAR_MIN_ROWS
            && pool::run_sharded(rows, &mut out, PAR_ROWS_PER_SHARD, |shard, out_chunk| {
                SCRATCH.with(|s| self.sweep_into(shard, &mut s.borrow_mut(), out_chunk));
            });
        if !sharded {
            SCRATCH.with(|s| self.sweep_into(rows, &mut s.borrow_mut(), &mut out));
        }
        out
    }

    /// Single-threaded batch classification with an explicit, reusable
    /// [`BatchScratch`].
    pub fn classify_batch_with(&self, rows: RowMatrix<'_>, scratch: &mut BatchScratch) -> Vec<u32> {
        let mut out = vec![0u32; rows.n_rows()];
        self.sweep_into(rows, scratch, &mut out);
        out
    }

    /// Single-threaded batch classification into a caller-owned output
    /// vector — with a warm `scratch` and `out`, the steady state
    /// allocates nothing (asserted by `tests/alloc_frozen.rs`).
    pub fn classify_batch_into(
        &self,
        rows: RowMatrix<'_>,
        scratch: &mut BatchScratch,
        out: &mut Vec<u32>,
    ) {
        out.clear();
        out.resize(rows.n_rows(), 0);
        self.sweep_into(rows, scratch, out);
    }

    /// The node-ordered sweep over one shard: nodes are stored
    /// topologically (children strictly after parents), so rows parked at
    /// node `i` only ever move to a node `> i` or to a terminal, and an
    /// ascending pass over the touched node span completes every row —
    /// each node record is loaded once per round instead of once per row.
    ///
    /// Parking uses the scratch's counting scatter: routing a round
    /// counts arrivals per destination node, a prefix sum turns counts
    /// into segment offsets, and a stable scatter packs the surviving
    /// rows into one flat slot array for the next round. No per-node
    /// `Vec`s, no allocation once the scratch is warm.
    fn sweep_into(&self, rows: RowMatrix<'_>, scratch: &mut BatchScratch, out: &mut [u32]) {
        debug_assert_eq!(out.len(), rows.n_rows());
        if rows.is_empty() {
            return;
        }
        if self.root & TERM_BIT != 0 {
            out.fill(u32::from(self.term_class[(self.root & !TERM_BIT) as usize]));
            return;
        }
        if rows.n_rows().saturating_mul(WALK_FALLBACK_FACTOR) < self.nodes.len() {
            for (i, r) in rows.iter().enumerate() {
                out[i] = self.classify(r);
            }
            return;
        }
        scratch.ensure(self.nodes.len(), rows.n_rows());
        let BatchScratch {
            count_a,
            count_b,
            off_a,
            off_b,
            slots_a,
            slots_b,
            pending,
            dest,
        } = scratch;
        // Round 0: every row parked at the root (node 0).
        count_a[0] = rows.n_rows() as u32;
        off_a[0] = rows.n_rows() as u32; // segment *end* offset
        for (i, slot) in slots_a[..rows.n_rows()].iter_mut().enumerate() {
            *slot = i as u32;
        }
        let (mut lo, mut hi) = (0usize, 0usize);
        loop {
            pending.clear();
            dest.clear();
            let (mut next_lo, mut next_hi) = (usize::MAX, 0usize);
            // Route the round node-by-node (ascending = sequential reads
            // of the node records), counting arrivals per destination.
            for node in lo..=hi {
                let c = count_a[node] as usize;
                if c == 0 {
                    continue;
                }
                count_a[node] = 0; // restore the all-zero invariant
                let end = off_a[node] as usize;
                let rec = self.nodes[node];
                for &r in &slots_a[end - c..end] {
                    let x = rows.row(r as usize);
                    let next = if x[rec.feat as usize] < rec.thresh {
                        rec.hi
                    } else {
                        rec.lo
                    };
                    if next & TERM_BIT != 0 {
                        out[r as usize] =
                            u32::from(self.term_class[(next & !TERM_BIT) as usize]);
                    } else {
                        pending.push(r);
                        dest.push(next);
                        count_b[next as usize] += 1;
                        next_lo = next_lo.min(next as usize);
                        next_hi = next_hi.max(next as usize);
                    }
                }
            }
            if pending.is_empty() {
                return;
            }
            // Prefix-sum the arrival counts into segment start offsets …
            let mut running = 0u32;
            for node in next_lo..=next_hi {
                off_b[node] = running;
                running += count_b[node];
            }
            // … and stable-scatter the survivors into the flat slot
            // array. After the scatter `off_b` holds segment *end*
            // offsets — exactly the form the next round reads.
            for (&r, &d) in pending.iter().zip(dest.iter()) {
                slots_b[off_b[d as usize] as usize] = r;
                off_b[d as usize] += 1;
            }
            std::mem::swap(count_a, count_b);
            std::mem::swap(off_a, off_b);
            std::mem::swap(slots_a, slots_b);
            lo = next_lo;
            hi = next_hi;
        }
    }
}

/// Reusable state of the frozen batch sweep's counting scatter.
///
/// Two (count, offset) array pairs — one for the round being routed, one
/// for the round being built, swapped each round — plus the flat row-slot
/// arrays and the routing-order survivor buffers. Counts are kept
/// all-zero between rounds and between calls, so a warm scratch can be
/// reused across batches *and across diagrams* (buffers only ever grow).
#[derive(Debug, Default)]
pub struct BatchScratch {
    count_a: Vec<u32>,
    count_b: Vec<u32>,
    off_a: Vec<u32>,
    off_b: Vec<u32>,
    slots_a: Vec<u32>,
    slots_b: Vec<u32>,
    pending: Vec<u32>,
    dest: Vec<u32>,
}

impl BatchScratch {
    /// Empty scratch (buffers grow on first use, then stay).
    pub fn new() -> BatchScratch {
        BatchScratch::default()
    }

    fn ensure(&mut self, n_nodes: usize, n_rows: usize) {
        if self.count_a.len() < n_nodes {
            self.count_a.resize(n_nodes, 0);
            self.count_b.resize(n_nodes, 0);
            self.off_a.resize(n_nodes, 0);
            self.off_b.resize(n_nodes, 0);
        }
        if self.slots_a.len() < n_rows {
            self.slots_a.resize(n_rows, 0);
            self.slots_b.resize(n_rows, 0);
        }
    }
}

thread_local! {
    /// Per-thread sweep scratch: serving threads and pool workers each
    /// reuse their own buffers across batches (and across models), so the
    /// steady-state sweep allocates nothing.
    static SCRATCH: RefCell<BatchScratch> = RefCell::new(BatchScratch::new());
}

/// The deployment backend: the paper's diagram in its flat serving form.
/// Same predictions and step counts as [`BackendKind::Dd`], different
/// memory layout and startup story.
impl Classifier for FrozenDD {
    fn info(&self) -> ClassifierInfo {
        ClassifierInfo {
            backend: BackendKind::Frozen,
            label: self.label(),
            n_features: self.schema.n_features(),
            n_classes: self.schema.n_classes(),
            size_nodes: self.size().total(),
            cost: CostModel {
                // One decision per distinct predicate level at most, plus
                // the abstraction's runtime aggregation reads.
                max_steps: Some(self.n_preds() + self.aggregation_reads()),
                aggregation_reads: self.aggregation_reads(),
                // The frozen walk is allocation-free and microseconds-fast:
                // coalescing single requests through the dynamic batcher
                // would cost more than the node-array pass saves. Explicit
                // batches still hit the native pass via `classify_batch`.
                preferred_batch: 1,
            },
        }
    }

    fn classify_with_steps(&self, x: &[f32]) -> Result<(u32, Option<usize>)> {
        let (class, steps) = FrozenDD::classify_with_steps(self, x);
        Ok((class, Some(steps)))
    }

    fn classify_batch(&self, rows: RowMatrix<'_>) -> Result<Vec<u32>> {
        Ok(FrozenDD::classify_batch(self, rows))
    }

    fn as_any(&self) -> Option<&dyn std::any::Any> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compile::{CompileOptions, ForestCompiler};
    use crate::data::datasets;
    use crate::forest::ForestLearner;

    fn frozen_iris(abstraction: Abstraction) -> (crate::data::Dataset, crate::compile::CompiledDD) {
        let ds = datasets::iris();
        let forest = ForestLearner::default().trees(10).seed(21).fit(&ds);
        let dd = ForestCompiler::new(CompileOptions {
            abstraction,
            ..Default::default()
        })
        .compile(&forest)
        .unwrap();
        (ds, dd)
    }

    #[test]
    fn freeze_is_bit_identical_to_the_live_diagram() {
        for abstraction in [Abstraction::Word, Abstraction::Vector, Abstraction::Majority] {
            let (ds, dd) = frozen_iris(abstraction);
            let frozen = dd.freeze();
            assert_eq!(frozen.abstraction(), abstraction);
            assert_eq!(frozen.size(), dd.size(), "{abstraction:?}");
            for i in 0..ds.n_rows() {
                assert_eq!(
                    frozen.classify_with_steps(ds.row(i)),
                    dd.classify_with_steps(ds.row(i)),
                    "{abstraction:?} row {i}"
                );
            }
        }
    }

    #[test]
    fn batch_pass_matches_single_row_walks() {
        let (ds, dd) = frozen_iris(Abstraction::Majority);
        let frozen = dd.freeze();
        let rows = ds.matrix();
        let batch = frozen.classify_batch(rows);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(batch[i], frozen.classify(row), "row {i}");
        }
        assert!(frozen.classify_batch(RowMatrix::empty()).is_empty());
        // Tiny batches take the per-row fallback; answers must not change.
        assert_eq!(
            frozen.classify_batch(rows.slice(0, 1)),
            vec![frozen.classify(rows.row(0))]
        );
    }

    #[test]
    fn sweep_counting_scatter_and_sharded_path_match_walks() {
        let (ds, dd) = frozen_iris(Abstraction::Majority);
        let frozen = dd.freeze();
        // Tile the dataset far past both the walk-fallback and the
        // parallel crossover so the counting-scatter sweep and the
        // sharded path genuinely run.
        let tiled = crate::bench_support::tile_rows(&ds, 4096, 7);
        let rows = tiled.as_matrix();
        let want: Vec<u32> = rows.iter().map(|r| frozen.classify(r)).collect();

        // explicit-scratch single-threaded sweep
        let mut scratch = BatchScratch::new();
        assert_eq!(frozen.classify_batch_with(rows, &mut scratch), want);
        // warm-scratch reuse (the zero-invariant must survive a batch) …
        let mut out = Vec::new();
        frozen.classify_batch_into(rows, &mut scratch, &mut out);
        assert_eq!(out, want);
        // … and reuse across a *different* diagram
        let (ds2, dd2) = frozen_iris(Abstraction::Word);
        let frozen2 = dd2.freeze();
        frozen2.classify_batch_into(ds2.matrix(), &mut scratch, &mut out);
        let want2: Vec<u32> = ds2.matrix().iter().map(|r| frozen2.classify(r)).collect();
        assert_eq!(out, want2);
        // the auto path (possibly sharded across the pool) is bit-identical
        assert_eq!(frozen.classify_batch(rows), want);
    }

    #[test]
    fn classifier_trait_reports_frozen_backend() {
        let (ds, dd) = frozen_iris(Abstraction::Majority);
        let frozen = dd.freeze();
        let info = Classifier::info(&frozen);
        assert_eq!(info.backend, BackendKind::Frozen);
        assert_eq!(info.label, "Most frequent class DD* [frozen]");
        assert_eq!(info.size_nodes, dd.size().total());
        assert_eq!(info.cost.aggregation_reads, 0);
        assert_eq!(info.cost.preferred_batch, 1);
        let c: &dyn Classifier = &frozen;
        let (class, steps) = c.classify_with_steps(ds.row(0)).unwrap();
        assert_eq!((class, steps.unwrap()), dd.classify_with_steps(ds.row(0)));
    }

    #[test]
    fn word_and_vector_keep_their_aggregation_reads() {
        let (_, word) = frozen_iris(Abstraction::Word);
        let (_, vector) = frozen_iris(Abstraction::Vector);
        assert_eq!(word.freeze().aggregation_reads(), 10);
        assert_eq!(vector.freeze().aggregation_reads(), 3);
        assert_eq!(word.freeze().n_trees(), 10);
    }

    #[test]
    fn single_terminal_diagram_freezes() {
        // A one-tree forest on a trivial dataset can collapse to a single
        // terminal after the majority abstraction; the frozen form must
        // handle a TERM_BIT-tagged root.
        let ds = datasets::lenses();
        let forest = ForestLearner::default().trees(1).max_depth(1).seed(3).fit(&ds);
        let dd = ForestCompiler::new(CompileOptions::default())
            .compile(&forest)
            .unwrap();
        let frozen = dd.freeze();
        let rows = ds.matrix();
        let batch = frozen.classify_batch(rows);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(frozen.classify_with_steps(row), dd.classify_with_steps(row));
            assert_eq!(batch[i], dd.classify(row));
        }
        // a single-terminal diagram must also survive the scratch path
        let mut scratch = BatchScratch::new();
        assert_eq!(frozen.classify_batch_with(rows, &mut scratch), batch);
    }

    #[test]
    fn terminal_majority_ties_break_low() {
        let mut t = FrozenTerminals::empty_vector(3);
        t.push_vector(&[2, 2, 1]);
        t.push_vector(&[0, 1, 1]);
        assert_eq!(t.class_of(0, 3), 0, "tie must break to the lowest class");
        assert_eq!(t.class_of(1, 3), 1);
        assert_eq!(t.agg_reads_of(0, 3), 3);
        assert_eq!(t.infer_trees(), 5);
        let mut w = FrozenTerminals::empty_word();
        w.push_word(&[1, 0, 1]);
        w.push_word(&[]);
        assert_eq!(w.len(), 2);
        assert_eq!(w.class_of(0, 2), 1);
        assert_eq!(w.class_of(1, 2), 0, "empty word votes for class 0");
        assert_eq!(w.agg_reads_of(0, 2), 3);
        assert_eq!(w.agg_reads_of(1, 2), 0);
    }
}
