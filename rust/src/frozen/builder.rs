//! Freezing: flatten a live [`Manager`] cone into [`FrozenDD`] arrays.
//!
//! The builder walks the cone once, assigns node indices in reverse
//! post-order — a topological order of the DAG, so the root gets index 0
//! and every edge points to a strictly greater index — and interns
//! terminals in first-reference order. Indices are dense: the frozen
//! arrays contain exactly the live cone, never arena garbage.

use crate::add::{Manager, NodeId, Terminal};
use crate::compile::Abstraction;
use crate::data::Schema;
use crate::error::Result;
use crate::frozen::{FrozenDD, FrozenTerminals, RawFrozen, TERM_BIT};
use crate::util::fxhash::{FxHashMap, FxHashSet};

/// Intern a terminal id, returning its [`TERM_BIT`]-tagged reference.
fn term_ref(
    id: NodeId,
    ids: &mut Vec<NodeId>,
    index: &mut FxHashMap<NodeId, u32>,
) -> u32 {
    if let Some(&t) = index.get(&id) {
        return t | TERM_BIT;
    }
    let t = ids.len() as u32;
    ids.push(id);
    index.insert(id, t);
    t | TERM_BIT
}

/// Flatten the cone under `root` into a [`FrozenDD`].
///
/// `terms` must be the empty [`FrozenTerminals`] variant matching
/// `abstraction`; `encode` appends one terminal payload per distinct
/// terminal node, in the interned order. `n_trees` comes from the compile
/// stats (`0` = unknown; the builder then recovers it from the payloads
/// where the abstraction preserves it).
#[allow(clippy::too_many_arguments)]
pub(crate) fn freeze_cone<T: Terminal>(
    mgr: &Manager<T>,
    root: NodeId,
    schema: &Schema,
    abstraction: Abstraction,
    unsat_elim: bool,
    n_trees: usize,
    mut terms: FrozenTerminals,
    encode: &mut dyn FnMut(&T, &mut FrozenTerminals),
) -> Result<FrozenDD> {
    // Post-order over the internal nodes of the cone …
    let mut post: Vec<NodeId> = Vec::new();
    let mut seen: FxHashSet<NodeId> = FxHashSet::default();
    if !root.is_terminal() {
        let mut stack = vec![(root, false)];
        while let Some((id, expanded)) = stack.pop() {
            if expanded {
                post.push(id);
                continue;
            }
            if !seen.insert(id) {
                continue;
            }
            let n = mgr.internal(id);
            stack.push((id, true));
            if !n.hi.is_terminal() {
                stack.push((n.hi, false));
            }
            if !n.lo.is_terminal() {
                stack.push((n.lo, false));
            }
        }
    }
    // … reversed = topological: parents strictly before children, root
    // first. This is what lets the batch path sweep the arrays in order.
    let order: Vec<NodeId> = post.into_iter().rev().collect();
    let index: FxHashMap<NodeId, u32> = order
        .iter()
        .enumerate()
        .map(|(i, &id)| (id, i as u32))
        .collect();

    let mut term_ids: Vec<NodeId> = Vec::new();
    let mut term_index: FxHashMap<NodeId, u32> = FxHashMap::default();
    let mut node_level = Vec::with_capacity(order.len());
    let mut node_lo = Vec::with_capacity(order.len());
    let mut node_hi = Vec::with_capacity(order.len());
    for &id in &order {
        let n = mgr.internal(id);
        node_level.push(n.level);
        node_lo.push(if n.lo.is_terminal() {
            term_ref(n.lo, &mut term_ids, &mut term_index)
        } else {
            index[&n.lo]
        });
        node_hi.push(if n.hi.is_terminal() {
            term_ref(n.hi, &mut term_ids, &mut term_index)
        } else {
            index[&n.hi]
        });
    }
    let root_ref = if root.is_terminal() {
        term_ref(root, &mut term_ids, &mut term_index)
    } else {
        0
    };
    for &id in &term_ids {
        encode(mgr.terminal_value(id), &mut terms);
    }
    let n_trees = if n_trees == 0 {
        terms.infer_trees()
    } else {
        n_trees as u32
    };

    let pool = mgr.pool();
    let mut pred_feature = Vec::with_capacity(pool.len());
    let mut pred_threshold = Vec::with_capacity(pool.len());
    for level in 0..pool.len() as u32 {
        let p = pool.pred(level);
        pred_feature.push(p.feature);
        pred_threshold.push(p.threshold);
    }

    FrozenDD::from_raw(RawFrozen {
        schema: schema.clone(),
        abstraction,
        unsat_elim,
        n_trees,
        pred_feature,
        pred_threshold,
        node_level,
        node_lo,
        node_hi,
        root: root_ref,
        terminals: terms,
    })
}

/// Feature-column packing order for `freeze --pack-features`: original
/// feature ids sorted by descending node-test frequency (ties break on
/// the lower id, so the order — and the snapshot — is deterministic).
/// `perm[slot]` is the original feature served by packed column `slot`;
/// features the diagram never tests sort last but are still present, so
/// the result is always a true permutation of `0..n_features`.
pub(crate) fn feature_permutation(
    n_features: usize,
    node_feats: impl Iterator<Item = usize>,
) -> Vec<u32> {
    let mut freq = vec![0u64; n_features];
    for f in node_feats {
        freq[f] += 1;
    }
    let mut perm: Vec<u32> = (0..n_features as u32).collect();
    perm.sort_by_key(|&f| (std::cmp::Reverse(freq[f as usize]), f));
    perm
}

// Freezing is exercised end-to-end (against the live diagram, across all
// abstractions and datasets) in `frozen::tests` and
// `tests/conformance.rs`.

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feature_permutation_orders_by_frequency_then_id() {
        // feature 2 tested 3×, feature 0 tested 1×, features 1 and 3
        // untested (tie → id order).
        let perm = feature_permutation(4, [2, 0, 2, 2].into_iter());
        assert_eq!(perm, vec![2, 0, 1, 3]);
        assert_eq!(feature_permutation(0, std::iter::empty()), Vec::<u32>::new());
    }
}
