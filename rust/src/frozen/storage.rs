//! Borrowed-or-owned backing storage for the frozen runtime.
//!
//! An `fdd-v2` snapshot is laid out so its section bytes *are* the
//! runtime arrays: little-endian, natural element layout, every section
//! 64-byte aligned. This module provides the three pieces that make the
//! zero-copy boot work:
//!
//! - [`SnapshotBuf`] — the one backing buffer behind a loaded snapshot:
//!   either an mmap of the artifact file ([`crate::runtime::mmap`], the
//!   replica-boot path) or an 8-byte-aligned owned copy ([`AlignedBuf`],
//!   the `from_bytes` / non-unix fallback).
//! - [`Plane<T>`] — one typed array of a [`FrozenDD`]: either a `Vec<T>`
//!   built by the freezer, or a bounds- and alignment-checked view into a
//!   shared [`SnapshotBuf`]. Evaluation only ever sees `&[T]` (via
//!   `Deref`), so the two origins are indistinguishable on the hot path.
//! - [`Pod`] — the little-endian byte contract each plane element obeys,
//!   used by the snapshot writer (canonical bytes), by the big-endian
//!   fallback parser, and as the witness that viewing the bytes in place
//!   is sound on little-endian hosts.
//!
//! The hot walk records live here too: [`Hot16`] (6 bytes: `u16` feature
//! + `f32` threshold, `repr(C, packed)`), the [`Hot32`] escape hatch
//! for schemas with more than 65 536 features (8 bytes), and [`HotQ16`]
//! (4 bytes: `u16` feature + IEEE-754 binary16 threshold bits, written by
//! `freeze --quantize-f16`). All keep the bytes touched per decision at
//! or under 8 — half the 16-byte AoS node this layout replaced; the
//! quantised record halves the `Hot16` plane again.
//!
//! [`FrozenDD`]: crate::frozen::FrozenDD

use crate::error::{Error, Result};
use std::fmt;
use std::marker::PhantomData;
use std::sync::Arc;

/// Feature-index width of the hot plane, chosen at freeze time against
/// the schema (`u16` unless the schema cannot fit it) and recorded in the
/// snapshot META section.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FeatWidth {
    /// 2-byte feature indices (schemas up to 65 536 features).
    U16,
    /// 4-byte escape hatch for wider schemas.
    U32,
}

impl FeatWidth {
    /// Narrowest width that can index every feature of an `n_features`
    /// schema.
    pub fn for_features(n_features: usize) -> FeatWidth {
        if n_features <= (u16::MAX as usize) + 1 {
            FeatWidth::U16
        } else {
            FeatWidth::U32
        }
    }

    /// Byte width of one feature index (the META encoding of the width).
    pub fn bytes(self) -> u8 {
        match self {
            FeatWidth::U16 => 2,
            FeatWidth::U32 => 4,
        }
    }

    /// Decode the META byte.
    pub fn from_bytes_code(code: u8) -> Result<FeatWidth> {
        match code {
            2 => Ok(FeatWidth::U16),
            4 => Ok(FeatWidth::U32),
            other => Err(Error::parse(format!(
                "fdd snapshot: unknown feature width code {other}"
            ))),
        }
    }
}

/// Threshold encoding of the hot plane, chosen at freeze time
/// (`freeze --quantize-f16`) and recorded in the snapshot META section.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ThreshQuant {
    /// Full-precision `f32` thresholds (the default; META code 0, which
    /// is also what every pre-quantisation snapshot carries in the byte).
    F32,
    /// IEEE-754 binary16 thresholds (META code 1). Only valid together
    /// with [`FeatWidth::U16`]; halves the hot plane to 4 bytes/node.
    F16,
}

impl ThreshQuant {
    /// The META encoding of this quantisation mode.
    pub fn code(self) -> u8 {
        match self {
            ThreshQuant::F32 => 0,
            ThreshQuant::F16 => 1,
        }
    }

    /// Decode the META byte.
    pub fn from_code(code: u8) -> Result<ThreshQuant> {
        match code {
            0 => Ok(ThreshQuant::F32),
            1 => Ok(ThreshQuant::F16),
            other => Err(Error::parse(format!(
                "fdd snapshot: unknown threshold quantisation code {other}"
            ))),
        }
    }
}

/// Largest finite IEEE-754 binary16 magnitude (quantisation range guard).
pub(crate) const F16_MAX: f32 = 65504.0;

/// Decode IEEE-754 binary16 bits to `f32` (exact: every f16 value is
/// representable as an f32).
pub(crate) fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign // ±0
        } else {
            // subnormal: normalise the mantissa into f32 range
            let shift = man.leading_zeros() - 21;
            let exp32 = 113 - shift;
            let man32 = (man << shift) & 0x3ff;
            sign | (exp32 << 23) | (man32 << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (man << 13) // ±inf / NaN
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// Encode `f32` to IEEE-754 binary16 bits, rounding to nearest with ties
/// away from zero. Values past f16 range encode as ±inf; callers that
/// need lossless-for-classification quantisation must guard the range
/// and collision cases themselves (see `frozen::FreezeOpts`).
pub(crate) fn f32_to_f16_bits(v: f32) -> u16 {
    let bits = v.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x7f_ffff;
    if exp == 0xff {
        // inf / NaN: preserve the class (and a non-zero payload for NaN)
        let payload = if man == 0 {
            0
        } else {
            0x200 | ((man >> 13) & 0x3ff) as u16
        };
        return sign | 0x7c00 | payload;
    }
    let e = exp - 112; // f16 biased exponent before rounding
    if e >= 0x1f {
        return sign | 0x7c00; // overflow → ±inf
    }
    if e <= 0 {
        // subnormal (or underflow-to-zero) target
        if e < -10 {
            return sign; // magnitude below half the smallest subnormal
        }
        let man = man | 0x80_0000; // restore the implicit leading 1
        let shift = (14 - e) as u32;
        let half = 1u32 << (shift - 1);
        let q = (man + half) >> shift; // ties round away from zero
        return sign | q as u16;
    }
    // normal target: round the 13 dropped mantissa bits, ties away
    let q = man + 0x1000;
    if q & 0x80_0000 != 0 {
        // mantissa carry bumps the exponent (may reach inf at e == 0x1e)
        let e = e + 1;
        if e >= 0x1f {
            return sign | 0x7c00;
        }
        return sign | ((e as u16) << 10);
    }
    sign | ((e as u16) << 10) | ((q >> 13) & 0x3ff) as u16
}

/// A plane element: fixed-size, alignment ≤ 8, and a little-endian byte
/// layout that matches its in-memory layout on little-endian hosts (which
/// is what makes the in-place view sound there).
pub(crate) trait Pod: Copy + 'static {
    /// Serialized (= in-memory) size in bytes.
    const SIZE: usize;

    /// Decode one element from exactly `Self::SIZE` little-endian bytes.
    fn from_le(bytes: &[u8]) -> Self;

    /// Append the canonical little-endian bytes of `self`.
    fn write_le(self, out: &mut Vec<u8>);
}

impl Pod for u16 {
    const SIZE: usize = 2;

    fn from_le(bytes: &[u8]) -> Self {
        u16::from_le_bytes(bytes.try_into().unwrap())
    }

    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
}

impl Pod for u32 {
    const SIZE: usize = 4;

    fn from_le(bytes: &[u8]) -> Self {
        u32::from_le_bytes(bytes.try_into().unwrap())
    }

    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
}

impl Pod for f32 {
    const SIZE: usize = 4;

    fn from_le(bytes: &[u8]) -> Self {
        f32::from_le_bytes(bytes.try_into().unwrap())
    }

    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
}

/// One hot walk record, `u16` encoding: the predicate `x[feat] < thresh`
/// in 6 bytes. `repr(C, packed)` so six on-disk bytes per node view
/// directly as one record — the layout/size test pins `size_of == 6`.
#[derive(Clone, Copy)]
#[repr(C, packed)]
pub(crate) struct Hot16 {
    pub(crate) feat: u16,
    pub(crate) thresh: f32,
}

impl fmt::Debug for Hot16 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // copy out of the packed struct before formatting (no unaligned
        // references)
        let feat = self.feat;
        let thresh = self.thresh;
        write!(f, "Hot16(x[{feat}] < {thresh})")
    }
}

impl Pod for Hot16 {
    const SIZE: usize = 6;

    fn from_le(bytes: &[u8]) -> Self {
        Hot16 {
            feat: u16::from_le_bytes(bytes[0..2].try_into().unwrap()),
            thresh: f32::from_le_bytes(bytes[2..6].try_into().unwrap()),
        }
    }

    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.feat.to_le_bytes());
        out.extend_from_slice(&self.thresh.to_le_bytes());
    }
}

/// The `u32` escape-hatch walk record (schemas past 65 536 features):
/// 8 bytes, naturally aligned.
#[derive(Clone, Copy)]
#[repr(C)]
pub(crate) struct Hot32 {
    pub(crate) feat: u32,
    pub(crate) thresh: f32,
}

impl fmt::Debug for Hot32 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Hot32(x[{}] < {})", self.feat, self.thresh)
    }
}

impl Pod for Hot32 {
    const SIZE: usize = 8;

    fn from_le(bytes: &[u8]) -> Self {
        Hot32 {
            feat: u32::from_le_bytes(bytes[0..4].try_into().unwrap()),
            thresh: f32::from_le_bytes(bytes[4..8].try_into().unwrap()),
        }
    }

    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.feat.to_le_bytes());
        out.extend_from_slice(&self.thresh.to_le_bytes());
    }
}

/// The f16-quantised walk record (`freeze --quantize-f16`): 4 bytes,
/// naturally aligned, threshold stored as IEEE-754 binary16 bits and
/// widened back to `f32` per visit (one shift-or on the hot path).
#[derive(Clone, Copy)]
#[repr(C)]
pub(crate) struct HotQ16 {
    pub(crate) feat: u16,
    pub(crate) qthresh: u16,
}

impl fmt::Debug for HotQ16 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "HotQ16(x[{}] < {})",
            self.feat,
            f16_bits_to_f32(self.qthresh)
        )
    }
}

impl Pod for HotQ16 {
    const SIZE: usize = 4;

    fn from_le(bytes: &[u8]) -> Self {
        HotQ16 {
            feat: u16::from_le_bytes(bytes[0..2].try_into().unwrap()),
            qthresh: u16::from_le_bytes(bytes[2..4].try_into().unwrap()),
        }
    }

    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.feat.to_le_bytes());
        out.extend_from_slice(&self.qthresh.to_le_bytes());
    }
}

/// The walk-record contract shared by [`Hot16`], [`Hot32`] and
/// [`HotQ16`]: the
/// single-row walk and the batch sweeps are generic over it, so all
/// encodings share one (monomorphised) evaluator.
pub(crate) trait HotRec: Pod {
    fn feat_ix(self) -> usize;
    fn threshold(self) -> f32;
}

impl HotRec for Hot16 {
    #[inline(always)]
    fn feat_ix(self) -> usize {
        self.feat as usize
    }

    #[inline(always)]
    fn threshold(self) -> f32 {
        self.thresh
    }
}

impl HotRec for Hot32 {
    #[inline(always)]
    fn feat_ix(self) -> usize {
        self.feat as usize
    }

    #[inline(always)]
    fn threshold(self) -> f32 {
        self.thresh
    }
}

impl HotRec for HotQ16 {
    #[inline(always)]
    fn feat_ix(self) -> usize {
        self.feat as usize
    }

    #[inline(always)]
    fn threshold(self) -> f32 {
        f16_bits_to_f32(self.qthresh)
    }
}

/// An owned byte buffer with 8-byte base alignment (a `Vec<u8>` from
/// `fs::read` only guarantees alignment 1, which would make typed views
/// unsound). Used by `FrozenDD::from_bytes` and as the mmap fallback.
pub(crate) struct AlignedBuf {
    words: Vec<u64>,
    len: usize,
}

impl AlignedBuf {
    pub(crate) fn from_bytes(bytes: &[u8]) -> AlignedBuf {
        let mut words = vec![0u64; bytes.len().div_ceil(8)];
        for (i, chunk) in bytes.chunks(8).enumerate() {
            let mut tmp = [0u8; 8];
            tmp[..chunk.len()].copy_from_slice(chunk);
            // native-endian round-trips the bytes exactly
            words[i] = u64::from_ne_bytes(tmp);
        }
        AlignedBuf {
            words,
            len: bytes.len(),
        }
    }

    pub(crate) fn as_bytes(&self) -> &[u8] {
        // SAFETY: the Vec owns at least `len` initialised bytes and u64
        // storage is valid to reinterpret as bytes.
        unsafe { std::slice::from_raw_parts(self.words.as_ptr() as *const u8, self.len) }
    }
}

impl fmt::Debug for AlignedBuf {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "AlignedBuf({} bytes)", self.len)
    }
}

/// The backing storage of a loaded snapshot: mapped (zero-copy replica
/// boot) or an aligned owned copy (in-memory bytes / non-unix fallback).
pub(crate) enum SnapshotBuf {
    Owned(AlignedBuf),
    #[cfg(all(unix, target_pointer_width = "64"))]
    Mapped(crate::runtime::mmap::Mmap),
}

impl SnapshotBuf {
    /// Open a snapshot file: `mmap` where enabled (falling back to a
    /// buffered read if the map fails or `FOREST_ADD_NO_MMAP` is set),
    /// `fs::read` elsewhere.
    pub(crate) fn open(path: &str) -> Result<SnapshotBuf> {
        #[cfg(all(unix, target_pointer_width = "64"))]
        if crate::runtime::mmap::enabled() {
            match crate::runtime::mmap::Mmap::map(path) {
                Ok(m) => return Ok(SnapshotBuf::Mapped(m)),
                Err(e) => {
                    crate::log_debug!("frozen: mmap of '{path}' failed ({e}); reading instead");
                }
            }
        }
        Ok(SnapshotBuf::Owned(AlignedBuf::from_bytes(&std::fs::read(
            path,
        )?)))
    }

    /// Forward `MADV_WILLNEED` to a mapped buffer (no-op for owned
    /// storage, whose bytes are resident by construction).
    pub(crate) fn advise_willneed(&self) {
        #[cfg(all(unix, target_pointer_width = "64"))]
        if let SnapshotBuf::Mapped(m) = self {
            m.advise_willneed();
        }
    }

    /// Whether this buffer is a file mapping (diagnostics).
    pub(crate) fn is_mapped(&self) -> bool {
        match self {
            SnapshotBuf::Owned(_) => false,
            #[cfg(all(unix, target_pointer_width = "64"))]
            SnapshotBuf::Mapped(_) => true,
        }
    }

    pub(crate) fn as_bytes(&self) -> &[u8] {
        match self {
            SnapshotBuf::Owned(b) => b.as_bytes(),
            #[cfg(all(unix, target_pointer_width = "64"))]
            SnapshotBuf::Mapped(m) => m.as_bytes(),
        }
    }
}

impl fmt::Debug for SnapshotBuf {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "SnapshotBuf({} bytes, {})",
            self.as_bytes().len(),
            if self.is_mapped() { "mapped" } else { "owned" }
        )
    }
}

/// One typed array of a frozen diagram: a `Vec<T>` when built live, or a
/// validated view into the shared snapshot buffer when loaded. `Deref`s
/// to `&[T]` so evaluation code never distinguishes the two.
#[derive(Clone)]
pub(crate) enum Plane<T: Pod> {
    Owned(Vec<T>),
    View {
        buf: Arc<SnapshotBuf>,
        /// Byte offset of element 0 within `buf`.
        off: usize,
        /// Element count.
        n: usize,
        _marker: PhantomData<T>,
    },
}

impl<T: Pod> Plane<T> {
    /// A plane over `n` elements of `buf` starting at byte `off`:
    /// zero-copy on little-endian hosts, parsed into an owned `Vec` on
    /// big-endian ones. Rejects out-of-bounds and misaligned ranges.
    pub(crate) fn from_section(buf: &Arc<SnapshotBuf>, off: usize, n: usize) -> Result<Plane<T>> {
        debug_assert_eq!(T::SIZE, std::mem::size_of::<T>());
        let byte_len = n
            .checked_mul(T::SIZE)
            .ok_or_else(|| Error::parse("fdd snapshot: plane length overflows"))?;
        let end = off
            .checked_add(byte_len)
            .filter(|&e| e <= buf.as_bytes().len())
            .ok_or_else(|| Error::parse("fdd snapshot: plane out of bounds"))?;
        if off % std::mem::align_of::<T>() != 0 {
            return Err(Error::parse("fdd snapshot: misaligned plane"));
        }
        if cfg!(target_endian = "little") {
            Ok(Plane::View {
                buf: buf.clone(),
                off,
                n,
                _marker: PhantomData,
            })
        } else {
            // Big-endian fallback: parse element-wise; byte-for-byte
            // identical semantics, one copy.
            let bytes = &buf.as_bytes()[off..end];
            Ok(Plane::Owned(
                bytes.chunks_exact(T::SIZE).map(T::from_le).collect(),
            ))
        }
    }

    /// Append the canonical little-endian bytes of every element.
    pub(crate) fn write_le(&self, out: &mut Vec<u8>) {
        for &v in self.iter() {
            v.write_le(out);
        }
    }
}

impl<T: Pod> std::ops::Deref for Plane<T> {
    type Target = [T];

    fn deref(&self) -> &[T] {
        match self {
            Plane::Owned(v) => v,
            Plane::View { buf, off, n, .. } => {
                // SAFETY: `from_section` checked bounds and alignment, the
                // buffer is immutable and kept alive by the Arc, and `Pod`
                // guarantees the byte layout matches `T` on this (little-
                // endian) host — the View variant is never constructed on
                // big-endian ones.
                unsafe {
                    std::slice::from_raw_parts(
                        buf.as_bytes().as_ptr().add(*off) as *const T,
                        *n,
                    )
                }
            }
        }
    }
}

impl<T: Pod> fmt::Debug for Plane<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Plane::Owned(v) => write!(f, "Plane::Owned[{}]", v.len()),
            Plane::View { n, off, .. } => write!(f, "Plane::View[{n} @ {off}]"),
        }
    }
}

impl<T: Pod> Default for Plane<T> {
    fn default() -> Self {
        Plane::Owned(Vec::new())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hot_record_layout_is_narrow() {
        // The acceptance bar: hot bytes per decision node ≤ 8 (u16
        // encoding is 6, the u32 escape hatch exactly 8, the quantised
        // record 4) — down from the 16-byte AoS node of the previous
        // layout.
        assert_eq!(std::mem::size_of::<Hot16>(), 6);
        assert_eq!(std::mem::align_of::<Hot16>(), 1);
        assert_eq!(std::mem::size_of::<Hot32>(), 8);
        assert_eq!(std::mem::size_of::<HotQ16>(), 4);
        assert_eq!(std::mem::align_of::<HotQ16>(), 2);
        assert!(std::mem::size_of::<Hot16>() <= 8);
        assert!(std::mem::size_of::<Hot32>() <= 8);
    }

    #[test]
    fn thresh_quant_codes() {
        assert_eq!(ThreshQuant::F32.code(), 0);
        assert_eq!(ThreshQuant::F16.code(), 1);
        assert_eq!(ThreshQuant::from_code(0).unwrap(), ThreshQuant::F32);
        assert_eq!(ThreshQuant::from_code(1).unwrap(), ThreshQuant::F16);
        assert!(ThreshQuant::from_code(7).is_err());
    }

    #[test]
    fn f16_decode_covers_every_class() {
        assert_eq!(f16_bits_to_f32(0x0000), 0.0);
        assert!(f16_bits_to_f32(0x8000).is_sign_negative());
        assert_eq!(f16_bits_to_f32(0x3c00), 1.0);
        assert_eq!(f16_bits_to_f32(0xc000), -2.0);
        assert_eq!(f16_bits_to_f32(0x7bff), 65504.0); // f16::MAX
        assert_eq!(f16_bits_to_f32(0x0400), 6.103_515_6e-5); // min normal
        assert_eq!(f16_bits_to_f32(0x0001), 5.960_464_5e-8); // min subnormal
        assert_eq!(f16_bits_to_f32(0x03ff), 6.097_555_2e-5); // max subnormal
        assert_eq!(f16_bits_to_f32(0x7c00), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(0xfc00), f32::NEG_INFINITY);
        assert!(f16_bits_to_f32(0x7e00).is_nan());
    }

    #[test]
    fn f16_encode_rounds_to_nearest_ties_away() {
        // exactly representable values round-trip bit-exactly
        for &h in &[0x0000u16, 0x8000, 0x3c00, 0xc000, 0x7bff, 0x0400, 0x0001, 0x03ff] {
            assert_eq!(f32_to_f16_bits(f16_bits_to_f32(h)), h, "bits {h:#06x}");
        }
        // every representable f16 round-trips through f32 (exhaustive
        // over finite non-NaN space: 2^16 values is cheap)
        for h in 0..=u16::MAX {
            let v = f16_bits_to_f32(h);
            if v.is_nan() {
                assert!(f16_bits_to_f32(f32_to_f16_bits(v)).is_nan());
            } else {
                assert_eq!(f32_to_f16_bits(v), h, "bits {h:#06x}");
            }
        }
        // midpoints round away from zero
        let mid = (f16_bits_to_f32(0x3c00) + f16_bits_to_f32(0x3c01)) / 2.0;
        assert_eq!(f32_to_f16_bits(mid), 0x3c01);
        assert_eq!(f32_to_f16_bits(-mid), 0xbc01);
        // non-midpoints go to the nearest neighbour
        assert_eq!(f32_to_f16_bits(1.0001), 0x3c00);
        // overflow → ±inf, tiny → ±0
        assert_eq!(f32_to_f16_bits(1.0e9), 0x7c00);
        assert_eq!(f32_to_f16_bits(-1.0e9), 0xfc00);
        assert_eq!(f32_to_f16_bits(1.0e-12), 0x0000);
        assert_eq!(f32_to_f16_bits(-1.0e-12), 0x8000);
        // half the smallest subnormal is a tie → rounds away to it
        let half_min_sub = f16_bits_to_f32(0x0001) / 2.0;
        assert_eq!(f32_to_f16_bits(half_min_sub), 0x0001);
        // just above f16::MAX but below the rounding cliff still overflows
        // the exponent and must yield inf, not garbage
        assert_eq!(f32_to_f16_bits(65520.0), 0x7c00);
    }

    #[test]
    fn feat_width_chooser_and_codes() {
        assert_eq!(FeatWidth::for_features(0), FeatWidth::U16);
        assert_eq!(FeatWidth::for_features(65_536), FeatWidth::U16);
        assert_eq!(FeatWidth::for_features(65_537), FeatWidth::U32);
        assert_eq!(FeatWidth::U16.bytes(), 2);
        assert_eq!(FeatWidth::U32.bytes(), 4);
        assert_eq!(FeatWidth::from_bytes_code(2).unwrap(), FeatWidth::U16);
        assert_eq!(FeatWidth::from_bytes_code(4).unwrap(), FeatWidth::U32);
        assert!(FeatWidth::from_bytes_code(3).is_err());
    }

    #[test]
    fn pod_roundtrips() {
        let mut out = Vec::new();
        Hot16 {
            feat: 7,
            thresh: 1.25,
        }
        .write_le(&mut out);
        assert_eq!(out.len(), 6);
        let back = Hot16::from_le(&out);
        assert_eq!(back.feat_ix(), 7);
        assert_eq!(back.threshold(), 1.25);
        let mut out = Vec::new();
        Hot32 {
            feat: 70_000,
            thresh: -2.5,
        }
        .write_le(&mut out);
        assert_eq!(out.len(), 8);
        let back = Hot32::from_le(&out);
        assert_eq!(back.feat_ix(), 70_000);
        assert_eq!(back.threshold(), -2.5);
    }

    #[test]
    fn planes_view_aligned_buffers() {
        // 8 bytes: two u32 values, little-endian
        let bytes: Vec<u8> = [1u32, 2u32]
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .collect();
        let buf = Arc::new(SnapshotBuf::Owned(AlignedBuf::from_bytes(&bytes)));
        let p: Plane<u32> = Plane::from_section(&buf, 0, 2).unwrap();
        assert_eq!(&p[..], &[1, 2]);
        // out of bounds and misaligned ranges are rejected
        assert!(Plane::<u32>::from_section(&buf, 0, 3).is_err());
        assert!(Plane::<u32>::from_section(&buf, 2, 1).is_err());
        // Hot16 views tolerate any offset (align 1)
        let p: Plane<Hot16> = Plane::from_section(&buf, 2, 1).unwrap();
        assert_eq!(p.len(), 1);
        // owned planes behave identically
        let o: Plane<u32> = Plane::Owned(vec![1, 2]);
        assert_eq!(&o[..], &[1, 2]);
    }
}
