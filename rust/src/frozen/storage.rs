//! Borrowed-or-owned backing storage for the frozen runtime.
//!
//! An `fdd-v2` snapshot is laid out so its section bytes *are* the
//! runtime arrays: little-endian, natural element layout, every section
//! 64-byte aligned. This module provides the three pieces that make the
//! zero-copy boot work:
//!
//! - [`SnapshotBuf`] — the one backing buffer behind a loaded snapshot:
//!   either an mmap of the artifact file ([`crate::runtime::mmap`], the
//!   replica-boot path) or an 8-byte-aligned owned copy ([`AlignedBuf`],
//!   the `from_bytes` / non-unix fallback).
//! - [`Plane<T>`] — one typed array of a [`FrozenDD`]: either a `Vec<T>`
//!   built by the freezer, or a bounds- and alignment-checked view into a
//!   shared [`SnapshotBuf`]. Evaluation only ever sees `&[T]` (via
//!   `Deref`), so the two origins are indistinguishable on the hot path.
//! - [`Pod`] — the little-endian byte contract each plane element obeys,
//!   used by the snapshot writer (canonical bytes), by the big-endian
//!   fallback parser, and as the witness that viewing the bytes in place
//!   is sound on little-endian hosts.
//!
//! The hot walk records live here too: [`Hot16`] (6 bytes: `u16` feature
//! + `f32` threshold, `repr(C, packed)`) and the [`Hot32`] escape hatch
//! for schemas with more than 65 536 features (8 bytes). Both keep the
//! bytes touched per decision at or under 8 — half the 16-byte AoS node
//! this layout replaced.
//!
//! [`FrozenDD`]: crate::frozen::FrozenDD

use crate::error::{Error, Result};
use std::fmt;
use std::marker::PhantomData;
use std::sync::Arc;

/// Feature-index width of the hot plane, chosen at freeze time against
/// the schema (`u16` unless the schema cannot fit it) and recorded in the
/// snapshot META section.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FeatWidth {
    /// 2-byte feature indices (schemas up to 65 536 features).
    U16,
    /// 4-byte escape hatch for wider schemas.
    U32,
}

impl FeatWidth {
    /// Narrowest width that can index every feature of an `n_features`
    /// schema.
    pub fn for_features(n_features: usize) -> FeatWidth {
        if n_features <= (u16::MAX as usize) + 1 {
            FeatWidth::U16
        } else {
            FeatWidth::U32
        }
    }

    /// Byte width of one feature index (the META encoding of the width).
    pub fn bytes(self) -> u8 {
        match self {
            FeatWidth::U16 => 2,
            FeatWidth::U32 => 4,
        }
    }

    /// Decode the META byte.
    pub fn from_bytes_code(code: u8) -> Result<FeatWidth> {
        match code {
            2 => Ok(FeatWidth::U16),
            4 => Ok(FeatWidth::U32),
            other => Err(Error::parse(format!(
                "fdd snapshot: unknown feature width code {other}"
            ))),
        }
    }
}

/// A plane element: fixed-size, alignment ≤ 8, and a little-endian byte
/// layout that matches its in-memory layout on little-endian hosts (which
/// is what makes the in-place view sound there).
pub(crate) trait Pod: Copy + 'static {
    /// Serialized (= in-memory) size in bytes.
    const SIZE: usize;

    /// Decode one element from exactly `Self::SIZE` little-endian bytes.
    fn from_le(bytes: &[u8]) -> Self;

    /// Append the canonical little-endian bytes of `self`.
    fn write_le(self, out: &mut Vec<u8>);
}

impl Pod for u16 {
    const SIZE: usize = 2;

    fn from_le(bytes: &[u8]) -> Self {
        u16::from_le_bytes(bytes.try_into().unwrap())
    }

    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
}

impl Pod for u32 {
    const SIZE: usize = 4;

    fn from_le(bytes: &[u8]) -> Self {
        u32::from_le_bytes(bytes.try_into().unwrap())
    }

    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
}

impl Pod for f32 {
    const SIZE: usize = 4;

    fn from_le(bytes: &[u8]) -> Self {
        f32::from_le_bytes(bytes.try_into().unwrap())
    }

    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
}

/// One hot walk record, `u16` encoding: the predicate `x[feat] < thresh`
/// in 6 bytes. `repr(C, packed)` so six on-disk bytes per node view
/// directly as one record — the layout/size test pins `size_of == 6`.
#[derive(Clone, Copy)]
#[repr(C, packed)]
pub(crate) struct Hot16 {
    pub(crate) feat: u16,
    pub(crate) thresh: f32,
}

impl fmt::Debug for Hot16 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // copy out of the packed struct before formatting (no unaligned
        // references)
        let feat = self.feat;
        let thresh = self.thresh;
        write!(f, "Hot16(x[{feat}] < {thresh})")
    }
}

impl Pod for Hot16 {
    const SIZE: usize = 6;

    fn from_le(bytes: &[u8]) -> Self {
        Hot16 {
            feat: u16::from_le_bytes(bytes[0..2].try_into().unwrap()),
            thresh: f32::from_le_bytes(bytes[2..6].try_into().unwrap()),
        }
    }

    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.feat.to_le_bytes());
        out.extend_from_slice(&self.thresh.to_le_bytes());
    }
}

/// The `u32` escape-hatch walk record (schemas past 65 536 features):
/// 8 bytes, naturally aligned.
#[derive(Clone, Copy)]
#[repr(C)]
pub(crate) struct Hot32 {
    pub(crate) feat: u32,
    pub(crate) thresh: f32,
}

impl fmt::Debug for Hot32 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Hot32(x[{}] < {})", self.feat, self.thresh)
    }
}

impl Pod for Hot32 {
    const SIZE: usize = 8;

    fn from_le(bytes: &[u8]) -> Self {
        Hot32 {
            feat: u32::from_le_bytes(bytes[0..4].try_into().unwrap()),
            thresh: f32::from_le_bytes(bytes[4..8].try_into().unwrap()),
        }
    }

    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.feat.to_le_bytes());
        out.extend_from_slice(&self.thresh.to_le_bytes());
    }
}

/// The walk-record contract shared by [`Hot16`] and [`Hot32`]: the
/// single-row walk and the batch sweeps are generic over it, so both
/// encodings share one (monomorphised) evaluator.
pub(crate) trait HotRec: Pod {
    fn feat_ix(self) -> usize;
    fn threshold(self) -> f32;
}

impl HotRec for Hot16 {
    #[inline(always)]
    fn feat_ix(self) -> usize {
        self.feat as usize
    }

    #[inline(always)]
    fn threshold(self) -> f32 {
        self.thresh
    }
}

impl HotRec for Hot32 {
    #[inline(always)]
    fn feat_ix(self) -> usize {
        self.feat as usize
    }

    #[inline(always)]
    fn threshold(self) -> f32 {
        self.thresh
    }
}

/// An owned byte buffer with 8-byte base alignment (a `Vec<u8>` from
/// `fs::read` only guarantees alignment 1, which would make typed views
/// unsound). Used by `FrozenDD::from_bytes` and as the mmap fallback.
pub(crate) struct AlignedBuf {
    words: Vec<u64>,
    len: usize,
}

impl AlignedBuf {
    pub(crate) fn from_bytes(bytes: &[u8]) -> AlignedBuf {
        let mut words = vec![0u64; bytes.len().div_ceil(8)];
        for (i, chunk) in bytes.chunks(8).enumerate() {
            let mut tmp = [0u8; 8];
            tmp[..chunk.len()].copy_from_slice(chunk);
            // native-endian round-trips the bytes exactly
            words[i] = u64::from_ne_bytes(tmp);
        }
        AlignedBuf {
            words,
            len: bytes.len(),
        }
    }

    pub(crate) fn as_bytes(&self) -> &[u8] {
        // SAFETY: the Vec owns at least `len` initialised bytes and u64
        // storage is valid to reinterpret as bytes.
        unsafe { std::slice::from_raw_parts(self.words.as_ptr() as *const u8, self.len) }
    }
}

impl fmt::Debug for AlignedBuf {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "AlignedBuf({} bytes)", self.len)
    }
}

/// The backing storage of a loaded snapshot: mapped (zero-copy replica
/// boot) or an aligned owned copy (in-memory bytes / non-unix fallback).
pub(crate) enum SnapshotBuf {
    Owned(AlignedBuf),
    #[cfg(all(unix, target_pointer_width = "64"))]
    Mapped(crate::runtime::mmap::Mmap),
}

impl SnapshotBuf {
    /// Open a snapshot file: `mmap` where enabled (falling back to a
    /// buffered read if the map fails or `FOREST_ADD_NO_MMAP` is set),
    /// `fs::read` elsewhere.
    pub(crate) fn open(path: &str) -> Result<SnapshotBuf> {
        #[cfg(all(unix, target_pointer_width = "64"))]
        if crate::runtime::mmap::enabled() {
            match crate::runtime::mmap::Mmap::map(path) {
                Ok(m) => return Ok(SnapshotBuf::Mapped(m)),
                Err(e) => {
                    crate::log_debug!("frozen: mmap of '{path}' failed ({e}); reading instead");
                }
            }
        }
        Ok(SnapshotBuf::Owned(AlignedBuf::from_bytes(&std::fs::read(
            path,
        )?)))
    }

    /// Forward `MADV_WILLNEED` to a mapped buffer (no-op for owned
    /// storage, whose bytes are resident by construction).
    pub(crate) fn advise_willneed(&self) {
        #[cfg(all(unix, target_pointer_width = "64"))]
        if let SnapshotBuf::Mapped(m) = self {
            m.advise_willneed();
        }
    }

    /// Whether this buffer is a file mapping (diagnostics).
    pub(crate) fn is_mapped(&self) -> bool {
        match self {
            SnapshotBuf::Owned(_) => false,
            #[cfg(all(unix, target_pointer_width = "64"))]
            SnapshotBuf::Mapped(_) => true,
        }
    }

    pub(crate) fn as_bytes(&self) -> &[u8] {
        match self {
            SnapshotBuf::Owned(b) => b.as_bytes(),
            #[cfg(all(unix, target_pointer_width = "64"))]
            SnapshotBuf::Mapped(m) => m.as_bytes(),
        }
    }
}

impl fmt::Debug for SnapshotBuf {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "SnapshotBuf({} bytes, {})",
            self.as_bytes().len(),
            if self.is_mapped() { "mapped" } else { "owned" }
        )
    }
}

/// One typed array of a frozen diagram: a `Vec<T>` when built live, or a
/// validated view into the shared snapshot buffer when loaded. `Deref`s
/// to `&[T]` so evaluation code never distinguishes the two.
#[derive(Clone)]
pub(crate) enum Plane<T: Pod> {
    Owned(Vec<T>),
    View {
        buf: Arc<SnapshotBuf>,
        /// Byte offset of element 0 within `buf`.
        off: usize,
        /// Element count.
        n: usize,
        _marker: PhantomData<T>,
    },
}

impl<T: Pod> Plane<T> {
    /// A plane over `n` elements of `buf` starting at byte `off`:
    /// zero-copy on little-endian hosts, parsed into an owned `Vec` on
    /// big-endian ones. Rejects out-of-bounds and misaligned ranges.
    pub(crate) fn from_section(buf: &Arc<SnapshotBuf>, off: usize, n: usize) -> Result<Plane<T>> {
        debug_assert_eq!(T::SIZE, std::mem::size_of::<T>());
        let byte_len = n
            .checked_mul(T::SIZE)
            .ok_or_else(|| Error::parse("fdd snapshot: plane length overflows"))?;
        let end = off
            .checked_add(byte_len)
            .filter(|&e| e <= buf.as_bytes().len())
            .ok_or_else(|| Error::parse("fdd snapshot: plane out of bounds"))?;
        if off % std::mem::align_of::<T>() != 0 {
            return Err(Error::parse("fdd snapshot: misaligned plane"));
        }
        if cfg!(target_endian = "little") {
            Ok(Plane::View {
                buf: buf.clone(),
                off,
                n,
                _marker: PhantomData,
            })
        } else {
            // Big-endian fallback: parse element-wise; byte-for-byte
            // identical semantics, one copy.
            let bytes = &buf.as_bytes()[off..end];
            Ok(Plane::Owned(
                bytes.chunks_exact(T::SIZE).map(T::from_le).collect(),
            ))
        }
    }

    /// Append the canonical little-endian bytes of every element.
    pub(crate) fn write_le(&self, out: &mut Vec<u8>) {
        for &v in self.iter() {
            v.write_le(out);
        }
    }
}

impl<T: Pod> std::ops::Deref for Plane<T> {
    type Target = [T];

    fn deref(&self) -> &[T] {
        match self {
            Plane::Owned(v) => v,
            Plane::View { buf, off, n, .. } => {
                // SAFETY: `from_section` checked bounds and alignment, the
                // buffer is immutable and kept alive by the Arc, and `Pod`
                // guarantees the byte layout matches `T` on this (little-
                // endian) host — the View variant is never constructed on
                // big-endian ones.
                unsafe {
                    std::slice::from_raw_parts(
                        buf.as_bytes().as_ptr().add(*off) as *const T,
                        *n,
                    )
                }
            }
        }
    }
}

impl<T: Pod> fmt::Debug for Plane<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Plane::Owned(v) => write!(f, "Plane::Owned[{}]", v.len()),
            Plane::View { n, off, .. } => write!(f, "Plane::View[{n} @ {off}]"),
        }
    }
}

impl<T: Pod> Default for Plane<T> {
    fn default() -> Self {
        Plane::Owned(Vec::new())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hot_record_layout_is_narrow() {
        // The acceptance bar: hot bytes per decision node ≤ 8 (u16
        // encoding is 6, the u32 escape hatch exactly 8) — down from the
        // 16-byte AoS node of the previous layout.
        assert_eq!(std::mem::size_of::<Hot16>(), 6);
        assert_eq!(std::mem::align_of::<Hot16>(), 1);
        assert_eq!(std::mem::size_of::<Hot32>(), 8);
        assert!(std::mem::size_of::<Hot16>() <= 8);
        assert!(std::mem::size_of::<Hot32>() <= 8);
    }

    #[test]
    fn feat_width_chooser_and_codes() {
        assert_eq!(FeatWidth::for_features(0), FeatWidth::U16);
        assert_eq!(FeatWidth::for_features(65_536), FeatWidth::U16);
        assert_eq!(FeatWidth::for_features(65_537), FeatWidth::U32);
        assert_eq!(FeatWidth::U16.bytes(), 2);
        assert_eq!(FeatWidth::U32.bytes(), 4);
        assert_eq!(FeatWidth::from_bytes_code(2).unwrap(), FeatWidth::U16);
        assert_eq!(FeatWidth::from_bytes_code(4).unwrap(), FeatWidth::U32);
        assert!(FeatWidth::from_bytes_code(3).is_err());
    }

    #[test]
    fn pod_roundtrips() {
        let mut out = Vec::new();
        Hot16 {
            feat: 7,
            thresh: 1.25,
        }
        .write_le(&mut out);
        assert_eq!(out.len(), 6);
        let back = Hot16::from_le(&out);
        assert_eq!(back.feat_ix(), 7);
        assert_eq!(back.threshold(), 1.25);
        let mut out = Vec::new();
        Hot32 {
            feat: 70_000,
            thresh: -2.5,
        }
        .write_le(&mut out);
        assert_eq!(out.len(), 8);
        let back = Hot32::from_le(&out);
        assert_eq!(back.feat_ix(), 70_000);
        assert_eq!(back.threshold(), -2.5);
    }

    #[test]
    fn planes_view_aligned_buffers() {
        // 8 bytes: two u32 values, little-endian
        let bytes: Vec<u8> = [1u32, 2u32]
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .collect();
        let buf = Arc::new(SnapshotBuf::Owned(AlignedBuf::from_bytes(&bytes)));
        let p: Plane<u32> = Plane::from_section(&buf, 0, 2).unwrap();
        assert_eq!(&p[..], &[1, 2]);
        // out of bounds and misaligned ranges are rejected
        assert!(Plane::<u32>::from_section(&buf, 0, 3).is_err());
        assert!(Plane::<u32>::from_section(&buf, 2, 1).is_err());
        // Hot16 views tolerate any offset (align 1)
        let p: Plane<Hot16> = Plane::from_section(&buf, 2, 1).unwrap();
        assert_eq!(p.len(), 1);
        // owned planes behave identically
        let o: Plane<u32> = Plane::Owned(vec![1, 2]);
        assert_eq!(&o[..], &[1, 2]);
    }
}
