//! The `forest-add/fdd-v1` binary snapshot format.
//!
//! A snapshot is the deployable artifact of the frozen runtime: replicas
//! `fs::read` one file (a single contiguous read), verify the checksum,
//! and bulk-convert the sections into the [`FrozenDD`] arrays — no JSON
//! parsing, no per-node allocation, no training. Writing is fully
//! deterministic, so `write → load → re-write` is byte-identical (the
//! conformance tests and the checked-in fixture both pin this).
//!
//! All integers are **little-endian**. Layout:
//!
//! ```text
//! Header (40 bytes)
//!   [0..8)    magic            b"FADD.FDD"
//!   [8..12)   version          u32 = 1
//!   [12..16)  section_count    u32
//!   [16..24)  payload_len      u64   (= file length - 40)
//!   [24..32)  checksum         u64   FNV-1a 64 over bytes [40..end)
//!   [32..40)  reserved         u64 = 0
//! Section table (section_count × 24 bytes, ascending id)
//!   id u32, reserved u32 = 0, offset u64 (absolute), len u64
//! Sections (each 8-byte aligned, zero padding between):
//!   1 META (36 bytes): abstraction u8 (0 word / 1 vector / 2 majority),
//!     unsat_elim u8, reserved u16, n_trees u32, n_features u32,
//!     n_classes u32, n_preds u32, n_nodes u32, n_terminals u32,
//!     root u32 (bit 31 = terminal), reserved u32
//!   2 SCHEMA: n_classes × str, then n_features × { name str, kind u8
//!     (0 numeric / 1 categorical), categorical: count u32 + count × str }
//!     where str = len u32 + UTF-8 bytes
//!   3 PREDS: n_preds × u32 feature, then n_preds × f32 threshold
//!   4 NODES (struct-of-arrays, topological order, root first):
//!     n_nodes × u32 level, n_nodes × u32 lo, n_nodes × u32 hi
//!   5 TERMS: word → (n_terminals + 1) × u32 offsets + symbols × u16;
//!     vector → n_terminals × n_classes × u32; majority → n_terminals × u16
//! ```
//!
//! Unknown section ids are ignored (a v1 reader skips what it does not
//! know); an unknown `version` is rejected outright. The checked-in
//! fixture under `tests/fixtures/` trips on any accidental change to this
//! layout.

use crate::compile::Abstraction;
use crate::data::{Feature, FeatureKind, Schema};
use crate::error::{Error, Result};
use crate::frozen::{FrozenDD, FrozenTerminals, RawFrozen};

/// Human-readable format name (CLI `inspect` output).
pub const FORMAT_NAME: &str = "forest-add/fdd-v1";

const MAGIC: [u8; 8] = *b"FADD.FDD";
const VERSION: u32 = 1;
const HEADER_LEN: usize = 40;
const TABLE_ENTRY_LEN: usize = 24;

const SEC_META: u32 = 1;
const SEC_SCHEMA: u32 = 2;
const SEC_PREDS: u32 = 3;
const SEC_NODES: u32 = 4;
const SEC_TERMS: u32 = 5;

fn err(msg: impl Into<String>) -> Error {
    Error::parse(format!("fdd snapshot: {}", msg.into()))
}

/// FNV-1a 64 over a byte slice (dependency-free integrity check).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn abstraction_code(a: Abstraction) -> u8 {
    match a {
        Abstraction::Word => 0,
        Abstraction::Vector => 1,
        Abstraction::Majority => 2,
    }
}

fn abstraction_from_code(c: u8) -> Result<Abstraction> {
    match c {
        0 => Ok(Abstraction::Word),
        1 => Ok(Abstraction::Vector),
        2 => Ok(Abstraction::Majority),
        other => Err(err(format!("unknown abstraction code {other}"))),
    }
}

// ---------------------------------------------------------------- writing

fn push_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_str(buf: &mut Vec<u8>, s: &str) {
    push_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn meta_bytes(dd: &FrozenDD) -> Vec<u8> {
    let mut b = Vec::with_capacity(36);
    b.push(abstraction_code(dd.abstraction));
    b.push(u8::from(dd.unsat_elim));
    push_u16(&mut b, 0);
    push_u32(&mut b, dd.n_trees);
    push_u32(&mut b, dd.schema.n_features() as u32);
    push_u32(&mut b, dd.schema.n_classes() as u32);
    push_u32(&mut b, dd.pred_feature.len() as u32);
    push_u32(&mut b, dd.nodes.len() as u32);
    push_u32(&mut b, dd.terminals.len() as u32);
    push_u32(&mut b, dd.root);
    push_u32(&mut b, 0);
    b
}

fn schema_bytes(schema: &Schema) -> Vec<u8> {
    let mut b = Vec::new();
    for class in &schema.classes {
        push_str(&mut b, class);
    }
    for f in &schema.features {
        push_str(&mut b, &f.name);
        match &f.kind {
            FeatureKind::Numeric => b.push(0),
            FeatureKind::Categorical { values } => {
                b.push(1);
                push_u32(&mut b, values.len() as u32);
                for v in values {
                    push_str(&mut b, v);
                }
            }
        }
    }
    b
}

fn preds_bytes(dd: &FrozenDD) -> Vec<u8> {
    let mut b = Vec::with_capacity(dd.pred_feature.len() * 8);
    for &f in &dd.pred_feature {
        push_u32(&mut b, f);
    }
    for &t in &dd.pred_threshold {
        push_u32(&mut b, t.to_bits());
    }
    b
}

fn nodes_bytes(dd: &FrozenDD) -> Vec<u8> {
    let mut b = Vec::with_capacity(dd.nodes.len() * 12);
    for &level in &dd.node_level {
        push_u32(&mut b, level);
    }
    for n in &dd.nodes {
        push_u32(&mut b, n.lo);
    }
    for n in &dd.nodes {
        push_u32(&mut b, n.hi);
    }
    b
}

fn terms_bytes(terminals: &FrozenTerminals) -> Vec<u8> {
    let mut b = Vec::new();
    match terminals {
        FrozenTerminals::Word { offsets, symbols } => {
            for &o in offsets {
                push_u32(&mut b, o);
            }
            for &s in symbols {
                push_u16(&mut b, s);
            }
        }
        FrozenTerminals::Vector { counts, .. } => {
            for &c in counts {
                push_u32(&mut b, c);
            }
        }
        FrozenTerminals::Majority { classes } => {
            for &c in classes {
                push_u16(&mut b, c);
            }
        }
    }
    b
}

/// Serialise to the canonical `fdd-v1` byte sequence.
pub(crate) fn to_bytes(dd: &FrozenDD) -> Vec<u8> {
    let sections = [
        (SEC_META, meta_bytes(dd)),
        (SEC_SCHEMA, schema_bytes(&dd.schema)),
        (SEC_PREDS, preds_bytes(dd)),
        (SEC_NODES, nodes_bytes(dd)),
        (SEC_TERMS, terms_bytes(&dd.terminals)),
    ];
    // Payload = section table + 8-aligned section data; offsets absolute.
    let mut payload = vec![0u8; sections.len() * TABLE_ENTRY_LEN];
    let mut table = Vec::with_capacity(sections.len());
    for (id, bytes) in &sections {
        while (HEADER_LEN + payload.len()) % 8 != 0 {
            payload.push(0);
        }
        table.push((*id, (HEADER_LEN + payload.len()) as u64, bytes.len() as u64));
        payload.extend_from_slice(bytes);
    }
    let mut entry = Vec::with_capacity(sections.len() * TABLE_ENTRY_LEN);
    for (id, offset, len) in table {
        push_u32(&mut entry, id);
        push_u32(&mut entry, 0);
        push_u64(&mut entry, offset);
        push_u64(&mut entry, len);
    }
    payload[..entry.len()].copy_from_slice(&entry);

    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC);
    push_u32(&mut out, VERSION);
    push_u32(&mut out, sections.len() as u32);
    push_u64(&mut out, payload.len() as u64);
    push_u64(&mut out, fnv1a64(&payload));
    push_u64(&mut out, 0);
    out.extend_from_slice(&payload);
    out
}

// ---------------------------------------------------------------- reading

/// Bounds-checked little-endian cursor over a byte slice.
struct Cur<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Cur<'a> {
        Cur { b, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| err("truncated section"))?;
        let s = &self.b[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| err("string is not UTF-8"))
    }

    fn u16_array(&mut self, n: usize) -> Result<Vec<u16>> {
        let bytes = self.take(n.checked_mul(2).ok_or_else(|| err("array too large"))?)?;
        Ok(bytes
            .chunks_exact(2)
            .map(|c| u16::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn u32_array(&mut self, n: usize) -> Result<Vec<u32>> {
        let bytes = self.take(n.checked_mul(4).ok_or_else(|| err("array too large"))?)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn done(&self) -> Result<()> {
        if self.pos == self.b.len() {
            Ok(())
        } else {
            Err(err("trailing bytes in section"))
        }
    }
}

/// Parsed META section.
struct Meta {
    abstraction: Abstraction,
    unsat_elim: bool,
    n_trees: u32,
    n_features: u32,
    n_classes: u32,
    n_preds: u32,
    n_nodes: u32,
    n_terminals: u32,
    root: u32,
}

fn parse_meta(bytes: &[u8]) -> Result<Meta> {
    let mut c = Cur::new(bytes);
    let abstraction = abstraction_from_code(c.u8()?)?;
    let unsat_elim = c.u8()? != 0;
    let _reserved = c.u16()?;
    let meta = Meta {
        abstraction,
        unsat_elim,
        n_trees: c.u32()?,
        n_features: c.u32()?,
        n_classes: c.u32()?,
        n_preds: c.u32()?,
        n_nodes: c.u32()?,
        n_terminals: c.u32()?,
        root: c.u32()?,
    };
    let _reserved = c.u32()?;
    c.done()?;
    Ok(meta)
}

fn parse_schema(bytes: &[u8], meta: &Meta) -> Result<Schema> {
    // META counts are untrusted until the section bytes back them up:
    // grow these vectors as strings actually parse instead of
    // preallocating from a (possibly crafted) count — a bogus
    // n_features/n_classes then dies as "truncated section", not as a
    // giant allocation.
    let mut c = Cur::new(bytes);
    let mut classes = Vec::new();
    for _ in 0..meta.n_classes {
        classes.push(c.str()?);
    }
    let mut features = Vec::new();
    for _ in 0..meta.n_features {
        let name = c.str()?;
        let kind = match c.u8()? {
            0 => FeatureKind::Numeric,
            1 => {
                let n = c.u32()? as usize;
                FeatureKind::Categorical {
                    values: (0..n).map(|_| c.str()).collect::<Result<Vec<_>>>()?,
                }
            }
            other => return Err(err(format!("unknown feature kind {other}"))),
        };
        features.push(Feature { name, kind });
    }
    c.done()?;
    Ok(Schema { features, classes })
}

/// Verify the envelope (magic, version, length, checksum) and return the
/// section table as `(id, offset, len)` triples.
fn parse_envelope(bytes: &[u8]) -> Result<Vec<(u32, usize, usize)>> {
    if bytes.len() < HEADER_LEN {
        return Err(err("file shorter than the header"));
    }
    if bytes[..8] != MAGIC {
        return Err(err("bad magic (not an fdd snapshot)"));
    }
    let mut c = Cur::new(&bytes[8..HEADER_LEN]);
    let version = c.u32()?;
    if version != VERSION {
        return Err(err(format!(
            "unsupported version {version} (this build reads fdd-v{VERSION})"
        )));
    }
    let section_count = c.u32()? as usize;
    let payload_len = c.u64()? as usize;
    let checksum = c.u64()?;
    if payload_len != bytes.len() - HEADER_LEN {
        return Err(err("payload length does not match the file size"));
    }
    if checksum != fnv1a64(&bytes[HEADER_LEN..]) {
        return Err(err("checksum mismatch (corrupt or truncated snapshot)"));
    }
    if c.u64()? != 0 {
        return Err(err("reserved header bytes must be zero in fdd-v1"));
    }
    let table_len = section_count
        .checked_mul(TABLE_ENTRY_LEN)
        .filter(|&l| HEADER_LEN + l <= bytes.len())
        .ok_or_else(|| err("section table out of bounds"))?;
    let mut t = Cur::new(&bytes[HEADER_LEN..HEADER_LEN + table_len]);
    let mut sections = Vec::with_capacity(section_count);
    for _ in 0..section_count {
        let id = t.u32()?;
        let _reserved = t.u32()?;
        let offset = t.u64()? as usize;
        let len = t.u64()? as usize;
        let end = offset
            .checked_add(len)
            .ok_or_else(|| err(format!("section {id} out of bounds")))?;
        if end > bytes.len() || offset < HEADER_LEN + table_len {
            return Err(err(format!("section {id} out of bounds")));
        }
        sections.push((id, offset, len));
    }
    Ok(sections)
}

fn section<'a>(
    bytes: &'a [u8],
    table: &[(u32, usize, usize)],
    id: u32,
) -> Result<&'a [u8]> {
    table
        .iter()
        .find(|(i, _, _)| *i == id)
        .map(|&(_, off, len)| &bytes[off..off + len])
        .ok_or_else(|| err(format!("missing section {id}")))
}

/// Deserialise an `fdd-v1` byte sequence (see [`FrozenDD::from_bytes`]).
pub(crate) fn from_bytes(bytes: &[u8]) -> Result<FrozenDD> {
    let table = parse_envelope(bytes)?;
    let meta = parse_meta(section(bytes, &table, SEC_META)?)?;
    let schema = parse_schema(section(bytes, &table, SEC_SCHEMA)?, &meta)?;
    if schema.n_features() != meta.n_features as usize
        || schema.n_classes() != meta.n_classes as usize
    {
        return Err(err("schema section disagrees with META counts"));
    }

    // Array reads go through `Cur::take` first, so a crafted count fails
    // as a bounds error before anything is allocated.
    let mut c = Cur::new(section(bytes, &table, SEC_PREDS)?);
    let pred_feature = c.u32_array(meta.n_preds as usize)?;
    let pred_threshold = c
        .u32_array(meta.n_preds as usize)?
        .into_iter()
        .map(f32::from_bits)
        .collect();
    c.done()?;

    let mut c = Cur::new(section(bytes, &table, SEC_NODES)?);
    let n_nodes = meta.n_nodes as usize;
    let node_level = c.u32_array(n_nodes)?;
    let node_lo = c.u32_array(n_nodes)?;
    let node_hi = c.u32_array(n_nodes)?;
    c.done()?;

    let mut c = Cur::new(section(bytes, &table, SEC_TERMS)?);
    let n_terms = meta.n_terminals as usize;
    let terminals = match meta.abstraction {
        Abstraction::Word => {
            let offsets = c.u32_array(n_terms + 1)?;
            let total = *offsets.last().unwrap_or(&0) as usize;
            let symbols = c.u16_array(total)?;
            FrozenTerminals::Word { offsets, symbols }
        }
        Abstraction::Vector => FrozenTerminals::Vector {
            stride: meta.n_classes,
            counts: c.u32_array(n_terms * meta.n_classes as usize)?,
        },
        Abstraction::Majority => FrozenTerminals::Majority {
            classes: c.u16_array(n_terms)?,
        },
    };
    c.done()?;

    FrozenDD::from_raw(RawFrozen {
        schema,
        abstraction: meta.abstraction,
        unsat_elim: meta.unsat_elim,
        n_trees: meta.n_trees,
        pred_feature,
        pred_threshold,
        node_level,
        node_lo,
        node_hi,
        root: meta.root,
        terminals,
    })
}

impl FrozenDD {
    /// Serialise to the canonical `fdd-v1` byte sequence. Deterministic:
    /// the same diagram always produces the same bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        to_bytes(self)
    }

    /// Deserialise from `fdd-v1` bytes (checksum-verified, then fully
    /// structurally validated).
    pub fn from_bytes(bytes: &[u8]) -> Result<FrozenDD> {
        from_bytes(bytes)
    }

    /// Write a snapshot file.
    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_bytes())?;
        Ok(())
    }

    /// Load a snapshot file — the replica-startup path: one contiguous
    /// read, checksum verification, bulk array conversion.
    pub fn load(path: &str) -> Result<FrozenDD> {
        Self::from_bytes(&std::fs::read(path)?)
    }
}

/// Parsed header/section overview of a snapshot (CLI `inspect`).
#[derive(Debug, Clone)]
pub struct SnapshotSummary {
    /// Format version (always 1 for documents this build reads).
    pub version: u32,
    /// Total file length in bytes.
    pub file_len: usize,
    /// Verified FNV-1a 64 payload checksum.
    pub checksum: u64,
    /// `(name, offset, len)` per section, in table order.
    pub sections: Vec<(&'static str, usize, usize)>,
    /// META fields.
    pub abstraction: Abstraction,
    pub unsat_elim: bool,
    pub n_trees: u32,
    pub n_features: u32,
    pub n_classes: u32,
    pub n_preds: u32,
    pub n_nodes: u32,
    pub n_terminals: u32,
}

/// Summarise a snapshot's envelope and META without building a
/// [`FrozenDD`] (the checksum is still verified).
pub fn summarize(bytes: &[u8]) -> Result<SnapshotSummary> {
    let table = parse_envelope(bytes)?;
    let meta = parse_meta(section(bytes, &table, SEC_META)?)?;
    let name_of = |id: u32| match id {
        SEC_META => "meta",
        SEC_SCHEMA => "schema",
        SEC_PREDS => "predicates",
        SEC_NODES => "nodes",
        SEC_TERMS => "terminals",
        _ => "unknown",
    };
    Ok(SnapshotSummary {
        version: VERSION,
        file_len: bytes.len(),
        checksum: fnv1a64(&bytes[HEADER_LEN..]),
        sections: table
            .iter()
            .map(|&(id, off, len)| (name_of(id), off, len))
            .collect(),
        abstraction: meta.abstraction,
        unsat_elim: meta.unsat_elim,
        n_trees: meta.n_trees,
        n_features: meta.n_features,
        n_classes: meta.n_classes,
        n_preds: meta.n_preds,
        n_nodes: meta.n_nodes,
        n_terminals: meta.n_terminals,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compile::{CompileOptions, ForestCompiler};
    use crate::data::datasets;
    use crate::forest::ForestLearner;

    fn frozen(abstraction: Abstraction) -> (crate::data::Dataset, FrozenDD) {
        let ds = datasets::lenses();
        let forest = ForestLearner::default().trees(9).seed(5).fit(&ds);
        let dd = ForestCompiler::new(CompileOptions {
            abstraction,
            ..Default::default()
        })
        .compile(&forest)
        .unwrap();
        (ds, dd.freeze())
    }

    #[test]
    fn roundtrip_is_byte_identical_for_all_abstractions() {
        for abstraction in [Abstraction::Word, Abstraction::Vector, Abstraction::Majority] {
            let (ds, dd) = frozen(abstraction);
            let bytes = dd.to_bytes();
            let back = FrozenDD::from_bytes(&bytes).unwrap();
            assert_eq!(back.to_bytes(), bytes, "{abstraction:?}");
            assert_eq!(back.abstraction(), abstraction);
            assert_eq!(back.size(), dd.size());
            assert_eq!(back.schema(), dd.schema());
            for i in 0..ds.n_rows() {
                assert_eq!(
                    back.classify_with_steps(ds.row(i)),
                    dd.classify_with_steps(ds.row(i)),
                    "{abstraction:?} row {i}"
                );
            }
        }
    }

    #[test]
    fn file_save_load() {
        let (ds, dd) = frozen(Abstraction::Majority);
        let path = std::env::temp_dir().join(format!("fdd-snap-{}.fdd", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        dd.save(&path).unwrap();
        let back = FrozenDD::load(&path).unwrap();
        for i in 0..ds.n_rows() {
            assert_eq!(back.classify(ds.row(i)), dd.classify(ds.row(i)));
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn every_corrupted_byte_is_detected() {
        let (_, dd) = frozen(Abstraction::Majority);
        let bytes = dd.to_bytes();
        // Flipping any payload byte must fail the checksum; flipping the
        // magic or version must fail the envelope. (Stride 7 keeps the
        // test fast while touching every region of the file.)
        for i in (0..bytes.len()).step_by(7) {
            let mut bad = bytes.clone();
            bad[i] ^= 0xff;
            assert!(
                FrozenDD::from_bytes(&bad).is_err(),
                "flipping byte {i} went unnoticed"
            );
        }
        assert!(FrozenDD::from_bytes(&bytes[..bytes.len() - 1]).is_err());
        assert!(FrozenDD::from_bytes(b"not a snapshot").is_err());
    }

    #[test]
    fn summarize_reports_the_layout() {
        let (_, dd) = frozen(Abstraction::Vector);
        let bytes = dd.to_bytes();
        let s = summarize(&bytes).unwrap();
        assert_eq!(s.version, 1);
        assert_eq!(s.file_len, bytes.len());
        assert_eq!(s.abstraction, Abstraction::Vector);
        assert_eq!(s.n_classes, 3);
        assert_eq!(s.n_nodes as usize, dd.size().internal);
        assert_eq!(s.n_terminals as usize, dd.size().terminals);
        let names: Vec<&str> = s.sections.iter().map(|(n, _, _)| *n).collect();
        assert_eq!(
            names,
            vec!["meta", "schema", "predicates", "nodes", "terminals"]
        );
        // sections are 8-aligned and in-bounds
        for &(_, off, len) in &s.sections {
            assert_eq!(off % 8, 0);
            assert!(off + len <= bytes.len());
        }
    }

    #[test]
    fn future_versions_are_rejected_cleanly() {
        let (_, dd) = frozen(Abstraction::Majority);
        let mut bytes = dd.to_bytes();
        bytes[8..12].copy_from_slice(&2u32.to_le_bytes());
        let e = FrozenDD::from_bytes(&bytes).unwrap_err();
        assert!(e.to_string().contains("unsupported version 2"), "{e}");
    }
}
