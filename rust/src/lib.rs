//! # forest-add
//!
//! Reproduction of **"Large Random Forests: Optimisation for Rapid
//! Evaluation"** (Gossen & Steffen, 2019): aggregation of Random Forests
//! into a single Algebraic Decision Diagram (ADD) for classification that is
//! orders of magnitude faster and smaller, packaged as a three-layer
//! Rust + JAX/Pallas serving system.
//!
//! Architecture (see `DESIGN.md`):
//! - **L3 (this crate)**: the paper's entire algorithm — random-forest
//!   training substrate, the ADD library, feasibility solvers,
//!   unsatisfiable-path elimination, the forest→DD compiler — plus a
//!   production-style serving coordinator (router, dynamic batcher, HTTP).
//! - **L2/L1 (`python/compile/`)**: a tensorised batched forest evaluator
//!   (JAX + Pallas) AOT-lowered to HLO text, executed from Rust via PJRT
//!   (`runtime`). Python never runs on the request path.
//!
//! Quickstart (see `examples/quickstart.rs`):
//! ```no_run
//! use forest_add::data::datasets;
//! use forest_add::forest::ForestLearner;
//! use forest_add::compile::{CompileOptions, ForestCompiler};
//!
//! let data = datasets::load("iris").unwrap();
//! let forest = ForestLearner::default().trees(100).seed(7).fit(&data);
//! let dd = ForestCompiler::new(CompileOptions::default()).compile(&forest).unwrap();
//! let pred = dd.classify(data.row(0));
//! # let _ = pred;
//! ```

pub mod add;
pub mod bench_support;
pub mod cli;
pub mod compile;
pub mod data;
pub mod error;
pub mod feas;
pub mod forest;
pub mod predicate;
pub mod runtime;
pub mod serve;
pub mod tree;
pub mod util;

pub use error::{Error, Result};

/// CLI entrypoint (see [`cli`]).
pub fn run_cli(args: Vec<String>) -> Result<()> {
    cli::run(args)
}
