//! # forest-add
//!
//! Reproduction of **"Large Random Forests: Optimisation for Rapid
//! Evaluation"** (Gossen & Steffen, 2019): aggregation of Random Forests
//! into a single Algebraic Decision Diagram (ADD) for classification that is
//! orders of magnitude faster and smaller, packaged as a three-layer
//! Rust + JAX/Pallas serving system.
//!
//! Architecture (see `DESIGN.md`):
//! - **L3 (this crate)**: the paper's entire algorithm — random-forest
//!   training substrate, the ADD library, feasibility solvers,
//!   unsatisfiable-path elimination, the forest→DD compiler — plus a
//!   production-style serving coordinator (router, dynamic batcher, HTTP).
//! - **L2/L1 (`python/compile/`)**: a tensorised batched forest evaluator
//!   (JAX + Pallas) AOT-lowered to HLO text, executed from Rust via PJRT
//!   (`runtime`). Python never runs on the request path.
//!
//! ## The unified API
//!
//! Every evaluator — the naive forest walker, the compiled ADD in all
//! three abstractions, its frozen struct-of-arrays serving form
//! ([`frozen::FrozenDD`]), and the XLA/PJRT batch engine — implements the
//! [`classifier::Classifier`] trait, and the [`engine::Engine`] facade
//! owns a [`engine::ModelRegistry`] of named, versioned models with
//! atomic hot-swap. The serving router, the CLI, and the benches all
//! dispatch through the registry; no caller hard-codes a backend.
//!
//! Quickstart (see `examples/quickstart.rs` for the full tour):
//! ```no_run
//! use forest_add::classifier::BackendKind;
//! use forest_add::engine::Engine;
//!
//! // Train a forest, compile the paper's `Most frequent class DD*`, and
//! // register both backends as the model "default" (version 1).
//! let data = forest_add::data::datasets::load("iris").unwrap();
//! let engine = Engine::builder()
//!     .dataset(data.clone())
//!     .trees(100)
//!     .seed(7)
//!     .build()
//!     .unwrap();
//!
//! // Classify on the default backend (the compiled diagram) …
//! let class = engine.classify(None, None, data.row(0)).unwrap();
//! // … and on the baseline forest walker: same answer, guaranteed.
//! let rf = engine
//!     .classify(None, Some(BackendKind::Forest), data.row(0))
//!     .unwrap();
//! assert_eq!(class, rf);
//! ```
//!
//! ## Batches: one flat matrix, zero copies, every core
//!
//! Batch evaluation everywhere takes a [`batch::RowMatrix`] — a borrowed
//! row-major `&[f32]` plus an `n_features` stride. No layer of the
//! pipeline allocates per row: the HTTP layer parses straight into a
//! [`batch::RowMatrixBuf`], [`data::Dataset::matrix`] views a whole
//! dataset for free, and worker shards are pointer-arithmetic slices.
//!
//! ```no_run
//! # let data = forest_add::data::datasets::load("iris").unwrap();
//! # let engine = forest_add::engine::Engine::builder()
//! #     .dataset(data.clone()).trees(20).seed(7).build().unwrap();
//! // Classify the entire dataset as one zero-copy batch.
//! let classes = engine.classify_batch(None, None, data.matrix()).unwrap();
//! // Or build a batch cell-by-cell (what the HTTP layer does).
//! let mut buf = forest_add::batch::RowMatrixBuf::new(4);
//! buf.push_row(&[6.1, 2.9, 4.7, 1.4]).unwrap();
//! let one = engine.classify_batch(None, None, buf.as_matrix()).unwrap();
//! # let _ = (classes, one);
//! ```
//!
//! Two crossovers govern how a batch executes:
//!
//! - **batch-vs-walk**: the frozen node-ordered sweep costs what the
//!   diagram costs, not what the batch costs, so batches smaller than
//!   `nodes / 32` fall back to plain per-row walks — identical answers,
//!   better latency.
//! - **multi-core sharding**: batches past a few hundred rows are cut
//!   into contiguous shards across a spawn-once worker pool
//!   ([`runtime::pool`]); parallelism defaults to
//!   [`std::thread::available_parallelism`] and is configurable with
//!   `ServeConfig::eval_threads` / `forest-add serve --eval-threads`.
//!   Shards write disjoint output ranges, so results are bit-identical
//!   to the single-threaded path at any thread count.
//!
//! ## Snapshots: compile once, serve from a frozen artifact
//!
//! Compilation is expensive; serving should not be. The frozen runtime
//! ([`frozen`]) splits the two: compile → freeze → ship the `fdd-v1`
//! binary snapshot, and every replica starts by loading it with a single
//! contiguous read — no JSON parsing, no re-training, identical
//! predictions (bit-for-bit, steps included). The same flow is available
//! on the command line as `forest-add freeze` (or `compile --format fdd`),
//! `forest-add inspect`, and `forest-add serve --snapshot <path>`.
//!
//! ```no_run
//! use forest_add::compile::{CompileOptions, ForestCompiler};
//! use forest_add::engine::Engine;
//! use forest_add::forest::ForestLearner;
//!
//! // Offline, once: train, compile the paper's DD*, freeze.
//! let data = forest_add::data::datasets::load("iris").unwrap();
//! let forest = ForestLearner::default().trees(100).seed(7).fit(&data);
//! let dd = ForestCompiler::new(CompileOptions::default())
//!     .compile(&forest)
//!     .unwrap();
//! dd.freeze().save("iris.fdd").unwrap();
//!
//! // On every replica: register the snapshot and serve.
//! let engine = Engine::new();
//! engine.register_snapshot("iris", "iris.fdd").unwrap();
//! let class = engine.classify(Some("iris"), None, data.row(0)).unwrap();
//! # let _ = class;
//! ```

pub mod add;
pub mod batch;
pub mod bench_support;
pub mod classifier;
pub mod cli;
pub mod compile;
pub mod data;
pub mod engine;
pub mod error;
pub mod feas;
pub mod forest;
pub mod frozen;
pub mod predicate;
pub mod runtime;
pub mod serve;
pub mod tree;
pub mod util;

pub use batch::{RowMatrix, RowMatrixBuf};
pub use classifier::{BackendKind, Classifier, ClassifierInfo, CostModel};
pub use engine::{Engine, ModelId, ModelRegistry};
pub use error::{Error, Result};

/// CLI entrypoint (see [`cli`]).
pub fn run_cli(args: Vec<String>) -> Result<()> {
    cli::run(args)
}
