//! # forest-add
//!
//! Reproduction of **"Large Random Forests: Optimisation for Rapid
//! Evaluation"** (Gossen & Steffen, 2019): aggregation of Random Forests
//! into a single Algebraic Decision Diagram (ADD) for classification that is
//! orders of magnitude faster and smaller, packaged as a three-layer
//! Rust + JAX/Pallas serving system.
//!
//! Architecture — the layer map and request lifecycle live in
//! `docs/ARCHITECTURE.md` at the repository root, the binary artifact
//! formats in `docs/FORMAT.md`, and the serving API in `docs/HTTP.md`:
//! - **L3 (this crate)**: the paper's entire algorithm — random-forest
//!   training substrate, the ADD library, feasibility solvers,
//!   unsatisfiable-path elimination, the forest→DD compiler — plus a
//!   production-style serving coordinator (router, dynamic batcher, HTTP).
//! - **L2/L1 (`python/compile/`)**: a tensorised batched forest evaluator
//!   (JAX + Pallas) AOT-lowered to HLO text, executed from Rust via PJRT
//!   (`runtime`). Python never runs on the request path.
//!
//! ## The unified API
//!
//! Every evaluator — the naive forest walker, the compiled ADD in all
//! three abstractions, its frozen struct-of-arrays serving form
//! ([`frozen::FrozenDD`]), and the XLA/PJRT batch engine — implements the
//! [`classifier::Classifier`] trait, and the [`engine::Engine`] facade
//! owns a [`engine::ModelRegistry`] of named, versioned models with
//! atomic hot-swap. The serving router, the CLI, and the benches all
//! dispatch through the registry; no caller hard-codes a backend.
//!
//! Quickstart (see `examples/quickstart.rs` for the full tour):
//! ```
//! use forest_add::classifier::BackendKind;
//! use forest_add::engine::Engine;
//!
//! // Train a forest, compile the paper's `Most frequent class DD*`, and
//! // register both backends as the model "default" (version 1).
//! let data = forest_add::data::datasets::load("iris").unwrap();
//! let engine = Engine::builder()
//!     .dataset(data.clone())
//!     .trees(20)
//!     .seed(7)
//!     .build()
//!     .unwrap();
//!
//! // Classify on the default backend (the compiled diagram) …
//! let class = engine.classify(None, None, data.row(0)).unwrap();
//! // … and on the baseline forest walker: same answer, guaranteed.
//! let rf = engine
//!     .classify(None, Some(BackendKind::Forest), data.row(0))
//!     .unwrap();
//! assert_eq!(class, rf);
//! ```
//!
//! ## Batches: one flat matrix, zero copies, every core
//!
//! Batch evaluation everywhere takes a [`batch::RowMatrix`] — a borrowed
//! row-major `&[f32]` plus an `n_features` stride. No layer of the
//! pipeline allocates per row: the HTTP layer parses straight into a
//! [`batch::RowMatrixBuf`], [`data::Dataset::matrix`] views a whole
//! dataset for free, and worker shards are pointer-arithmetic slices.
//!
//! ```
//! # let data = forest_add::data::datasets::load("iris").unwrap();
//! # let engine = forest_add::engine::Engine::builder()
//! #     .dataset(data.clone()).trees(20).seed(7).build().unwrap();
//! // Classify the entire dataset as one zero-copy batch.
//! let classes = engine.classify_batch(None, None, data.matrix()).unwrap();
//! // Or build a batch cell-by-cell (what the HTTP layer does).
//! let mut buf = forest_add::batch::RowMatrixBuf::new(4);
//! buf.push_row(&[6.1, 2.9, 4.7, 1.4]).unwrap();
//! let one = engine.classify_batch(None, None, buf.as_matrix()).unwrap();
//! # let _ = (classes, one);
//! ```
//!
//! Three crossovers govern how a batch executes on the frozen backend:
//!
//! - **batch-vs-walk**: a sweep costs what the diagram costs, not what
//!   the batch costs, so batches smaller than `nodes / 32` fall back to
//!   plain per-row walks — identical answers, better latency.
//! - **cache tiling**: diagrams whose hot node planes exceed the LLC
//!   budget (`ServeConfig::tile_bytes` / `serve --tile-bytes`, auto
//!   4 MiB) are swept in topological node *tiles*: rows walk as far as
//!   the resident tile allows, then park on the destination tile's
//!   chain, so each tile streams through cache once per batch instead of
//!   the whole diagram thrashing once per level. Smaller diagrams keep
//!   the round-based counting-scatter sweep.
//! - **multi-core sharding**: batches past a few hundred rows are cut
//!   into contiguous shards across a spawn-once worker pool
//!   ([`runtime::pool`]); parallelism defaults to
//!   [`std::thread::available_parallelism`] and is configurable with
//!   `ServeConfig::eval_threads` / `forest-add serve --eval-threads`.
//!   Shards write disjoint output ranges, so results are bit-identical
//!   to the single-threaded path at any thread count and tile size.
//!
//! §6 cost metering survives every batch path:
//! [`engine::Engine::classify_batch_steps`] (HTTP: `"steps": true` on
//! `POST /classify_batch`) returns the per-row step counts the single-row
//! walk would report, bit-identical.
//!
//! ## SIMD kernels: lanes across the batch, never across the tree
//!
//! Inside every batch sweep, each decision node routes its parked rows
//! through one predicate — so the data parallelism lies across *rows*,
//! not across the diagram. The frozen sweeps exploit that with explicit
//! `std::arch` kernels ([`runtime::simd`]): 4–8 parked rows compare
//! against the node's threshold with one masked ordered-`<` and
//! blend-select their lo/hi forward deltas branch-free (SSE2/AVX2 on
//! x86-64, NEON on aarch64, chosen once at startup by runtime feature
//! detection — no compile-time feature flags, one binary per
//! architecture). Ordered compares are false on NaN in both the lane and
//! scalar code, so missing values take the `lo` edge everywhere and
//! results stay **bit-identical** to the scalar walk — the conformance
//! suite pins every executable kernel × layout × tile budget. The
//! portable scalar sweep remains as the fallback and kill switch:
//! `FOREST_ADD_NO_SIMD=1`, `serve --no-simd`, or `ServeConfig::simd =
//! false` (the active kernel is exported as the `forest_simd_kernel`
//! gauge and the `simd_kernel` field of `GET /metrics`).
//!
//! Two freeze-time layout transforms feed those lanes
//! ([`frozen::FreezeOpts`], `forest-add freeze --pack-features
//! --quantize-f16`):
//!
//! - **Feature-column packing** reorders feature columns by descending
//!   node-test frequency, so the gathers that feed the lanes hit the
//!   same few cache lines. The permutation is a dedicated snapshot
//!   section applied transparently on load; single-row walks and old
//!   readers see original feature ids.
//! - **f16 threshold quantisation** stores thresholds as IEEE-754
//!   binary16, halving the hot plane to 4 bytes per node. Quantisation
//!   *widens* (rounds ties away from zero) and re-writes the predicate
//!   table to the decoded values, so every plane stays self-consistent;
//!   freezing fails loudly if a threshold falls outside f16 range or two
//!   thresholds of one feature would collide — accepted snapshots are
//!   bit-identical in answers, never approximately right.
//!
//! Both transforms are opt-in: default freezes write byte-identical
//! `fdd-v2` artifacts, and existing snapshots load unchanged.
//!
//! ## Snapshots: compile once, mmap everywhere
//!
//! Compilation is expensive; serving should not be. The frozen runtime
//! ([`frozen`]) splits the two: compile → freeze → ship the `fdd-v2`
//! binary snapshot. The artifact's sections are 64-byte-aligned
//! little-endian planes — the narrow hot walk records (6 bytes per
//! decision node: `u16` feature + `f32` threshold, with a `u32` escape
//! hatch past 65 536 features), forward-delta child arrays, and
//! precomputed terminal tables — so a replica `mmap`s the file and the
//! on-disk bytes *are* the runtime arrays: zero copies, zero per-node
//! allocations, checksum + full structural validation still enforced,
//! and the kernel shares the pages across every process serving the
//! same model. Hosts without `mmap` (or `FrozenDD::from_bytes`) pay one
//! aligned copy; legacy `fdd-v1` artifacts upgrade on load. Memory
//! footprint and encoding are reported by `forest-add inspect`
//! (bytes/node, per-section sizes, boot path). The same flow is
//! available on the command line as `forest-add freeze` (or
//! `compile --format fdd`), `forest-add inspect`, and
//! `forest-add serve --snapshot <path>`.
//!
//! ```no_run
//! use forest_add::compile::{CompileOptions, ForestCompiler};
//! use forest_add::engine::Engine;
//! use forest_add::forest::ForestLearner;
//!
//! // Offline, once: train, compile the paper's DD*, freeze.
//! let data = forest_add::data::datasets::load("iris").unwrap();
//! let forest = ForestLearner::default().trees(100).seed(7).fit(&data);
//! let dd = ForestCompiler::new(CompileOptions::default())
//!     .compile(&forest)
//!     .unwrap();
//! dd.freeze().save("iris.fdd").unwrap();
//!
//! // On every replica: register the snapshot and serve.
//! let engine = Engine::new();
//! engine.register_snapshot("iris", "iris.fdd").unwrap();
//! let class = engine.classify(Some("iris"), None, data.row(0)).unwrap();
//! # let _ = class;
//! ```
//!
//! ## Bundles: N models, one artifact, one mmap
//!
//! A fleet serves *many* models per process. The `fab-v1` bundle
//! ([`frozen::bundle`]) packs any number of `fdd` snapshots into one
//! file behind a checksummed manifest (per-entry name, version, shard
//! tag); [`engine::Engine::register_bundle`] maps the file **once**
//! (`MADV_WILLNEED`-hinted), boots every entry as a zero-copy
//! [`frozen::FrozenDD`] borrowing its slice of the shared mapping, and
//! lands all names + versions in the registry in one atomic hot-swap —
//! requests route into entries with the usual `model` field, and
//! `GET /models` reports each entry's bundle provenance. On the command
//! line: `forest-add bundle pack` / `bundle ls` /
//! `serve --bundle fleet.fab`.
//!
//! ```no_run
//! use forest_add::engine::Engine;
//!
//! // Build pipeline: pack every registered model into one artifact.
//! # let data = forest_add::data::datasets::load("iris").unwrap();
//! # let engine = forest_add::engine::Engine::builder()
//! #     .dataset(data.clone()).trees(20).seed(7).model_name("iris").build().unwrap();
//! engine.save_bundle(&[], "fleet.fab").unwrap();
//!
//! // Fleet replica: every model of the bundle, training-free, zero-copy.
//! let replica = Engine::new();
//! let ids = replica.register_bundle("fleet.fab").unwrap();
//! let class = replica.classify(Some("iris"), None, data.row(0)).unwrap();
//! # let _ = (ids, class);
//! ```
//!
//! ## Evented serving: one poller thread, thousands of connections
//!
//! The HTTP front-end has two interchangeable transports behind one
//! protocol layer ([`net::proto`]), selected with `ServeConfig::io_mode`
//! / `forest-add serve --io sync|evented` (auto-detected by default:
//! evented wherever [`net::poll::supported`] is true — linux epoll and
//! macos kqueue — sync thread-per-connection elsewhere). Both transports
//! share the parser and serialiser, so their responses are
//! **bit-identical** — an integration test drives 64 concurrent
//! keep-alive connections through both and compares byte-for-byte.
//!
//! The evented path ([`net::event_loop`]) multiplexes every connection
//! on one poller thread with HTTP/1.1 keep-alive and pipelining;
//! complete requests dispatch to a worker pool through a *bounded*
//! queue. When the queue (or the dynamic batcher behind
//! `POST /classify_batch`) is full, the request is shed immediately with
//! `429 Too Many Requests` + `Retry-After` — load spikes degrade into
//! fast rejections, never unbounded queueing. `GET /metrics` exports
//! end-to-end p50/p95/p99 request latency, open/total connection
//! gauges, and the `429` shed count.
//!
//! Feature rows can skip JSON entirely: `POST /classify_batch` with
//! `Content-Type: application/octet-stream` carries the compact binary
//! row frame, deserialised straight into a [`batch::RowMatrixBuf`]
//! (no JSON cell parsing on the hot path):
//!
//! | offset | size | field |
//! |--------|------|-------|
//! | 0 | 4 | `n_rows`, little-endian `u32` |
//! | 4 | 4 | `n_features`, little-endian `u32` |
//! | 8 | `4·n_rows·n_features` | row-major `f32` cells, little-endian |
//!
//! `POST /classify` accepts the same frame with `n_rows == 1`. Binary
//! requests put what the JSON body would carry in the query string
//! (`?backend=frozen&model=iris&steps=true`); responses are always the
//! JSON documents described above, so clients mix formats freely.
//!
//! ## Observability: trace every request, scrape every series
//!
//! The serving stack is instrumented end to end by the std-only [`obs`]
//! subsystem, with zero allocations on the hot path when tracing is off
//! (enforced by the counting-allocator test):
//!
//! - **Request ids.** Every response carries an `X-Request-Id` header —
//!   echoed verbatim when the client sent one, a generated 64-bit hex id
//!   otherwise — on both front-ends, so a request is greppable across
//!   client logs, server logs, and the trace ring.
//! - **Per-stage timing.** Each request records monotonic spans for
//!   `parse`, `admission`, `queue`, `eval`, `serialize`, and `write`
//!   (plus sampled per-shard eval timings on sharded batches). Add
//!   `"trace": true` to a JSON body (or `?trace=true` on binary
//!   requests) and the response embeds the breakdown inline; the last
//!   256 finished traces are always available from
//!   `GET /debug/trace?n=32` via a lock-free ring.
//! - **Prometheus exposition.** `GET /metrics` still serves the JSON
//!   snapshot; `GET /metrics?format=prometheus` renders every series in
//!   the text format — the log₂ latency histograms become proper
//!   cumulative `le` buckets with `_sum`/`_count`, alongside counters
//!   for bytes read/written, queue-depth gauges, and per-shard eval
//!   timing summaries. `GET /healthz` reports liveness plus the
//!   registered-model count for fleet readiness probes.
//! - **Structured logs.** The `log_*!` macros emit leveled records to
//!   stderr as text or JSON lines: `serve --log-level debug
//!   --log-json`, overridable with the `FOREST_ADD_LOG` environment
//!   variable (`error|warn|info|debug|trace`).
//!
//! ## Fault tolerance: degrade along the bit-identical chain
//!
//! The native backends answer every row identically (the conformance
//! suite proves it), which turns fault handling into pure routing:
//!
//! - **Panic quarantine.** Every eval runs behind a panic guard — in
//!   the sharded pool each shard is caught individually
//!   ([`runtime::pool`]), single rows inline in the router. A panic
//!   becomes an [`Error::EvalPanic`], counts in `eval_panics_total`,
//!   and the surviving backends re-evaluate the request.
//! - **Circuit breakers.** The router keeps one breaker per
//!   model-version × backend ([`serve::breaker`]). Repeated failures
//!   inside a sliding window open it; requests then route along the
//!   degradation chain `frozen → dd → forest` and announce the actual
//!   server with an `X-Served-By` header. After a cooldown a single
//!   half-open probe re-closes the breaker. `GET /readyz` fails (`503`)
//!   while any breaker is open, so balancers drain degraded replicas
//!   that healthy `/healthz` keeps alive.
//! - **Deadline propagation.** `ServeConfig::reply_timeout_ms` (or a
//!   client `X-Deadline-Ms` header, capped by it) rides the request as
//!   an absolute deadline: the batcher drops expired jobs before
//!   grouping, the frozen sweep checks it between tiles, and an
//!   expired request is a `504` counted in `deadline_dropped_total` —
//!   never a worker pinned on an answer nobody is waiting for.
//! - **Deterministic fault injection.** [`runtime::fault`] arms seeded
//!   failure points (`eval_shard_panic`, `eval_slow`, `conn_read_err`,
//!   `conn_write_short`, `snapshot_load`) via `serve
//!   --fault point:rate:seed[,…]` or `FOREST_ADD_FAULT`. The same spec
//!   replays the same fire sequence, so the chaos soak in
//!   `tests/integration_fault.rs` is reproducible; disarmed points cost
//!   one relaxed atomic load on the hot path.

// Public API documentation is part of the contract: every exported
// item carries rustdoc, and the byte formats / HTTP wire contract are
// additionally specified under docs/ at the repository root.
#![warn(missing_docs)]

pub mod add;
pub mod batch;
pub mod bench_support;
pub mod classifier;
pub mod cli;
pub mod compile;
pub mod data;
pub mod engine;
pub mod error;
pub mod feas;
pub mod forest;
pub mod frozen;
pub mod net;
pub mod obs;
pub mod predicate;
pub mod runtime;
pub mod serve;
pub mod tree;
pub mod util;

pub use batch::{RowMatrix, RowMatrixBuf};
pub use classifier::{BackendKind, Classifier, ClassifierInfo, CostModel};
pub use engine::{Engine, ModelId, ModelRegistry};
pub use error::{Error, Result};

/// CLI entrypoint (see [`cli`]).
pub fn run_cli(args: Vec<String>) -> Result<()> {
    cli::run(args)
}
