//! Library-wide error type.
//!
//! Every fallible public API in `forest_add` returns [`Result`] with this
//! error. `Display`/`Error` are hand-implemented because the crates.io
//! registry (and therefore `thiserror`) is unreachable in the build
//! environment.

use std::fmt;

/// Errors produced by the `forest_add` library.
#[derive(Debug)]
pub enum Error {
    /// Malformed input data (CSV/ARFF/JSON parse failures, bad values).
    Parse(String),

    /// A request, configuration, or argument violates a documented contract.
    InvalidArgument(String),

    /// Schema mismatch between a model and the data it is applied to.
    SchemaMismatch(String),

    /// A capacity or structural limit was exceeded (e.g. DD node budget).
    Capacity(String),

    /// The XLA/PJRT runtime reported an error.
    Runtime(String),

    /// The serving layer failed (queue closed, worker died, bad request).
    Serve(String),

    /// The server is at capacity right now and shed the request;
    /// retrying shortly is expected to succeed (HTTP: `429` +
    /// `Retry-After`, distinct from the hard failures above).
    Overloaded(String),

    /// The request's deadline expired before evaluation finished
    /// (HTTP: `504`). The work was dropped, not completed slowly.
    DeadlineExceeded(String),

    /// One or more evaluation shards panicked and were quarantined;
    /// the rest of the batch completed (HTTP: `500` when no fallback
    /// backend can re-serve the request). Carries the first failing
    /// shard index and its panic message.
    EvalPanic {
        /// Index of the first shard that panicked.
        shard: usize,
        /// Panic payload rendered to text (`&str`/`String` payloads).
        msg: String,
    },

    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Parse(msg) => write!(f, "parse error: {msg}"),
            Error::InvalidArgument(msg) => write!(f, "invalid argument: {msg}"),
            Error::SchemaMismatch(msg) => write!(f, "schema mismatch: {msg}"),
            Error::Capacity(msg) => write!(f, "capacity exceeded: {msg}"),
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::Serve(msg) => write!(f, "serving error: {msg}"),
            Error::Overloaded(msg) => write!(f, "overloaded: {msg}"),
            Error::DeadlineExceeded(msg) => write!(f, "deadline exceeded: {msg}"),
            Error::EvalPanic { shard, msg } => {
                write!(f, "eval shard {shard} panicked: {msg}")
            }
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Convenience constructor for parse errors.
    pub fn parse(msg: impl Into<String>) -> Self {
        Error::Parse(msg.into())
    }

    /// Convenience constructor for invalid arguments.
    pub fn invalid(msg: impl Into<String>) -> Self {
        Error::InvalidArgument(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::parse("line 3: expected number");
        assert_eq!(e.to_string(), "parse error: line 3: expected number");
        let e = Error::invalid("trees must be > 0");
        assert!(e.to_string().contains("trees must be > 0"));
    }

    #[test]
    fn fault_variants_name_the_failure() {
        let e = Error::DeadlineExceeded("expired 3ms before eval".into());
        assert_eq!(e.to_string(), "deadline exceeded: expired 3ms before eval");
        let e = Error::EvalPanic {
            shard: 2,
            msg: "index out of bounds".into(),
        };
        assert_eq!(e.to_string(), "eval shard 2 panicked: index out of bounds");
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
        assert!(std::error::Error::source(&e).is_some());
    }

    #[test]
    fn xla_error_converts_to_runtime() {
        let e: Error = xla::Error("pjrt gone".into()).into();
        assert!(matches!(e, Error::Runtime(_)));
        assert!(e.to_string().contains("pjrt gone"));
    }
}
