//! Library-wide error type.
//!
//! Every fallible public API in `forest_add` returns [`Result`] with this
//! error. Binaries and examples wrap it in `anyhow` at the edge.

use thiserror::Error;

/// Errors produced by the `forest_add` library.
#[derive(Debug, Error)]
pub enum Error {
    /// Malformed input data (CSV/ARFF/JSON parse failures, bad values).
    #[error("parse error: {0}")]
    Parse(String),

    /// A request, configuration, or argument violates a documented contract.
    #[error("invalid argument: {0}")]
    InvalidArgument(String),

    /// Schema mismatch between a model and the data it is applied to.
    #[error("schema mismatch: {0}")]
    SchemaMismatch(String),

    /// A capacity or structural limit was exceeded (e.g. DD node budget).
    #[error("capacity exceeded: {0}")]
    Capacity(String),

    /// The XLA/PJRT runtime reported an error.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// The serving layer failed (queue closed, worker died, bad request).
    #[error("serving error: {0}")]
    Serve(String),

    /// Underlying I/O failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Convenience constructor for parse errors.
    pub fn parse(msg: impl Into<String>) -> Self {
        Error::Parse(msg.into())
    }

    /// Convenience constructor for invalid arguments.
    pub fn invalid(msg: impl Into<String>) -> Self {
        Error::InvalidArgument(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::parse("line 3: expected number");
        assert_eq!(e.to_string(), "parse error: line 3: expected number");
        let e = Error::invalid("trees must be > 0");
        assert!(e.to_string().contains("trees must be > 0"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
