//! `forest-add` CLI — leader entrypoint (subcommands grow with the
//! library; `serve --io sync|evented` picks the socket front-end, and
//! `loadgen` drives a running server with concurrent keep-alive
//! traffic).

fn main() {
    if let Err(e) = forest_add::run_cli(std::env::args().skip(1).collect()) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
