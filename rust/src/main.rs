//! `forest-add` CLI — leader entrypoint (subcommands grow with the library).

fn main() {
    if let Err(e) = forest_add::run_cli(std::env::args().skip(1).collect()) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
