//! Shared harness for the `cargo bench` targets (criterion is unavailable
//! offline; each bench is a `harness = false` binary built on this module).
//!
//! Responsibilities: environment-tunable workload sizes, the paper's
//! log-spaced forest-size checkpoints, the per-variant sweep used by both
//! Fig. 6 (steps) and Fig. 7 (sizes), wall-clock measurement helpers, and
//! report output (aligned text to stdout + CSV/Markdown dumps under
//! `bench_results/`).

use crate::batch::RowMatrixBuf;
use crate::compile::{Abstraction, CompileOptions, CompiledDD, ForestCompiler};
use crate::data::Dataset;
use crate::forest::{ForestLearner, RandomForest};
use crate::util::table::Table;
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Tile a dataset into an owned flat batch of `rows` rows, taking row
/// `(i * step) % n_rows` for position `i` — the standard way benches and
/// tests build deterministic batches past the sweep/sharding crossovers.
pub fn tile_rows(data: &Dataset, rows: usize, step: usize) -> RowMatrixBuf {
    let mut buf = RowMatrixBuf::with_capacity(data.n_features(), rows);
    for i in 0..rows {
        buf.push_row(data.row((i * step) % data.n_rows()))
            .expect("dataset rows share one stride");
    }
    buf
}

/// Workload sizing, overridable via environment variables:
/// `FOREST_ADD_BENCH_MAX_TREES`, `FOREST_ADD_BENCH_TABLE_TREES`,
/// `FOREST_ADD_BENCH_BUDGET`, `FOREST_ADD_BENCH_SECONDS`.
#[derive(Debug, Clone)]
pub struct BenchEnv {
    /// Largest forest size in the Fig. 6/7 sweeps.
    pub max_trees: usize,
    /// Forest size for the Table 1/2 reproduction (paper: 10,000).
    pub table_trees: usize,
    /// Node budget for the non-`*` variants (they explode; the paper cuts
    /// those series off too).
    pub node_budget: usize,
    /// Generous node budget for the `*` variants (terminates the sweep
    /// cleanly instead of thrashing if a star variant grows too far on a
    /// noisy dataset).
    pub star_budget: usize,
    /// Measurement window for throughput benches.
    pub measure_secs: f64,
    /// Wall-clock budget per sweep variant (`FOREST_ADD_BENCH_VARIANT_SECS`).
    pub variant_secs: u64,
    /// Wall-clock budget per Table-1/2 dataset (`FOREST_ADD_BENCH_DATASET_SECS`).
    pub dataset_secs: u64,
}

impl BenchEnv {
    /// Read the environment (with CI-scale defaults).
    pub fn load() -> BenchEnv {
        let get = |k: &str, d: usize| {
            std::env::var(k)
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(d)
        };
        BenchEnv {
            max_trees: get("FOREST_ADD_BENCH_MAX_TREES", 10_000),
            table_trees: get("FOREST_ADD_BENCH_TABLE_TREES", 10_000),
            node_budget: get("FOREST_ADD_BENCH_BUDGET", 300_000),
            star_budget: get("FOREST_ADD_BENCH_STAR_BUDGET", 2_000_000),
            measure_secs: get("FOREST_ADD_BENCH_SECONDS", 2) as f64,
            variant_secs: get("FOREST_ADD_BENCH_VARIANT_SECS", 600) as u64,
            dataset_secs: get("FOREST_ADD_BENCH_DATASET_SECS", 600) as u64,
        }
    }
}

/// Log-spaced checkpoints `1, 2, 5, 10, …` up to and including `max`.
pub fn log_checkpoints(max: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut decade = 1usize;
    'outer: loop {
        for m in [1, 2, 5] {
            let v = decade * m;
            if v >= max {
                break 'outer;
            }
            out.push(v);
        }
        decade *= 10;
    }
    out.push(max);
    out
}

/// One measured point of a sweep series.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// Forest size at this checkpoint.
    pub trees: usize,
    /// Mean §6 step count over the dataset.
    pub steps: f64,
    /// Structure size in nodes.
    pub size: usize,
}

/// One series (e.g. `Class vector DD*`) of the Fig. 6/7 sweeps.
#[derive(Debug, Clone)]
pub struct SweepSeries {
    /// Paper-style label.
    pub label: String,
    /// Measured checkpoints (may stop early on cutoff).
    pub points: Vec<SweepPoint>,
    /// Cutoff description when the variant exploded past the node budget.
    pub cutoff: Option<String>,
}

/// Full sweep data for one dataset: the RF baseline plus all six DD
/// variants (word/vector/majority × ±unsat), as in Figs. 6/7.
pub struct PaperSweep {
    /// Dataset name.
    pub dataset: String,
    /// Checkpoints requested.
    pub checkpoints: Vec<usize>,
    /// RF baseline series.
    pub forest: SweepSeries,
    /// DD variant series.
    pub variants: Vec<SweepSeries>,
}

/// Run the Fig. 6/7 sweep on a dataset.
///
/// The forest is trained once at `max_trees`; prefixes give every
/// checkpoint (the paper's incremental aggregation setting). Non-`*`
/// variants run under `node_budget` and report their cutoff.
pub fn paper_sweep(data: &Dataset, env: &BenchEnv, seed: u64) -> PaperSweep {
    let checkpoints = log_checkpoints(env.max_trees);
    crate::log_info!(
        "[sweep] training {} trees on '{}' …",
        env.max_trees,
        data.name
    );
    let forest = ForestLearner::default()
        .trees(env.max_trees)
        .seed(seed)
        .fit(data);

    // RF baseline: steps are linear; evaluate per checkpoint via prefixes.
    let mut rf_points = Vec::new();
    for &n in &checkpoints {
        if n == 0 {
            continue;
        }
        let prefix = forest.prefix(n);
        rf_points.push(SweepPoint {
            trees: n,
            steps: prefix.mean_steps(data),
            size: prefix.n_nodes(),
        });
    }
    let rf_series = SweepSeries {
        label: "Random Forest".into(),
        points: rf_points,
        cutoff: None,
    };

    let mut variants = Vec::new();
    for (abstraction, unsat) in [
        (Abstraction::Word, false),
        (Abstraction::Word, true),
        (Abstraction::Vector, false),
        (Abstraction::Vector, true),
        (Abstraction::Majority, false),
        (Abstraction::Majority, true),
    ] {
        let label = abstraction.label(unsat);
        crate::log_info!("[sweep] {label} …");
        let opts = CompileOptions {
            abstraction,
            unsat_elim: unsat,
            // Non-* variants explode; the budget turns that into a recorded
            // cutoff instead of an OOM (the paper's truncated curves). Star
            // variants get a generous budget as a termination guarantee.
            node_budget: if unsat { env.star_budget } else { env.node_budget },
            time_budget: Some(Duration::from_secs(env.variant_secs)),
            ..Default::default()
        };
        let mut points = Vec::new();
        let t0 = Instant::now();
        let result = ForestCompiler::new(opts).sweep(&forest, &checkpoints, &mut |n, dd| {
            let p = SweepPoint {
                trees: n,
                steps: dd.mean_steps(data),
                size: dd.size().total(),
            };
            crate::log_info!(
                "[sweep]   n={n}: steps {:.2}, {} nodes ({:.1?} elapsed)",
                p.steps,
                p.size,
                t0.elapsed()
            );
            points.push(p);
        });
        let cutoff = match result {
            Ok(outcome) => outcome
                .cutoff
                .map(|(at, why)| format!("cut off at {at} trees: {why}")),
            Err(e) => Some(format!("failed: {e}")),
        };
        variants.push(SweepSeries {
            label,
            points,
            cutoff,
        });
    }
    PaperSweep {
        dataset: data.name.clone(),
        checkpoints,
        forest: rf_series,
        variants,
    }
}

impl PaperSweep {
    /// Render one metric (steps or size) as a table with a column per series.
    pub fn to_table(&self, metric: impl Fn(&SweepPoint) -> String) -> Table {
        let mut headers: Vec<String> = vec!["trees".into(), self.forest.label.clone()];
        headers.extend(self.variants.iter().map(|v| v.label.clone()));
        let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(&headers_ref);
        for &n in &self.checkpoints {
            let mut row = vec![n.to_string()];
            let find = |s: &SweepSeries| {
                s.points
                    .iter()
                    .find(|p| p.trees == n)
                    .map(&metric)
                    .unwrap_or_else(|| "—".into())
            };
            row.push(find(&self.forest));
            for v in &self.variants {
                row.push(find(v));
            }
            t.row(row);
        }
        t
    }

    /// Footnotes for cut-off series.
    pub fn cutoff_notes(&self) -> Vec<String> {
        self.variants
            .iter()
            .filter_map(|v| v.cutoff.as_ref().map(|c| format!("{}: {c}", v.label)))
            .collect()
    }
}

/// Compile one dataset's `Most frequent class DD*` at `trees` (Table 1/2
/// row), returning the baseline forest as well.
pub fn table_row(data: &Dataset, trees: usize, seed: u64) -> (RandomForest, CompiledDD) {
    let forest = ForestLearner::default().trees(trees).seed(seed).fit(data);
    let dd = ForestCompiler::new(CompileOptions::default())
        .compile(&forest)
        .expect("DD* compilation must not explode");
    (forest, dd)
}

/// Time-budgeted Table-1/2 row: aggregates towards `trees`, snapshotting at
/// log-spaced checkpoints; returns the forest, the largest completed
/// snapshot, and the tree count it corresponds to (== `trees` when the
/// budget sufficed). This is how the benches degrade gracefully on slow
/// datasets instead of hanging (the cutoff is reported in the table notes).
pub fn table_row_budgeted(
    data: &Dataset,
    trees: usize,
    seed: u64,
    budget: Duration,
) -> (RandomForest, CompiledDD, usize) {
    let forest = ForestLearner::default().trees(trees).seed(seed).fit(data);
    let compiler = ForestCompiler::new(CompileOptions {
        time_budget: Some(budget),
        ..Default::default()
    });
    let checkpoints = log_checkpoints(trees);
    let mut last: Option<(usize, CompiledDD)> = None;
    compiler
        .sweep(&forest, &checkpoints, &mut |n, dd| last = Some((n, dd)))
        .expect("sweep must produce at least the first checkpoint");
    let (n, dd) = last.expect("time budget too small for even one tree");
    (forest, dd, n)
}

/// Measure mean wall-clock nanoseconds of `f` over a timed window.
pub fn measure_ns(window: Duration, mut f: impl FnMut()) -> f64 {
    // single warm-up pass (some measured operations are seconds-long)
    f();
    let start = Instant::now();
    let mut iters = 0u64;
    while start.elapsed() < window {
        f();
        iters += 1;
    }
    start.elapsed().as_nanos() as f64 / iters as f64
}

/// Output directory for bench reports (`bench_results/`).
pub fn out_dir() -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("bench_results");
    let _ = std::fs::create_dir_all(&dir);
    dir
}

/// Write a report: aligned text to stdout, CSV + Markdown to
/// `bench_results/<name>.{csv,md}`.
pub fn report(name: &str, title: &str, table: &Table, notes: &[String]) {
    println!("\n=== {title} ===");
    print!("{}", table.to_text());
    for n in notes {
        println!("note: {n}");
    }
    let dir = out_dir();
    let _ = std::fs::write(dir.join(format!("{name}.csv")), table.to_csv());
    let mut md = format!("# {title}\n\n{}", table.to_markdown());
    for n in notes {
        md.push_str(&format!("\n> {n}\n"));
    }
    let _ = std::fs::write(dir.join(format!("{name}.md")), md);
    println!("[written bench_results/{name}.csv bench_results/{name}.md]");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;

    #[test]
    fn checkpoints_log_spaced_and_capped() {
        assert_eq!(log_checkpoints(100), vec![1, 2, 5, 10, 20, 50, 100]);
        assert_eq!(log_checkpoints(7), vec![1, 2, 5, 7]);
        assert_eq!(log_checkpoints(1), vec![1]);
        let c = log_checkpoints(10_000);
        assert_eq!(*c.last().unwrap(), 10_000);
        assert!(c.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn small_sweep_has_expected_shape() {
        let ds = datasets::lenses();
        let env = BenchEnv {
            max_trees: 20,
            table_trees: 20,
            node_budget: 100_000,
            star_budget: 1_000_000,
            measure_secs: 0.01,
            variant_secs: 600,
            dataset_secs: 600,
        };
        let sweep = paper_sweep(&ds, &env, 7);
        assert_eq!(sweep.forest.points.len(), sweep.checkpoints.len());
        assert_eq!(sweep.variants.len(), 6);
        // RF steps grow monotonically with n
        let rf: Vec<f64> = sweep.forest.points.iter().map(|p| p.steps).collect();
        assert!(rf.windows(2).all(|w| w[0] <= w[1]), "{rf:?}");
        // DD* (majority) steps at the end are far below RF steps
        let mv_star = sweep.variants.iter().find(|v| v.label == "Most frequent class DD*").unwrap();
        let last = mv_star.points.last().unwrap();
        assert!(last.steps < rf.last().unwrap() / 2.0);
        // table renders with one row per checkpoint
        let t = sweep.to_table(|p| format!("{:.2}", p.steps));
        assert_eq!(t.len(), sweep.checkpoints.len());
    }

    #[test]
    fn measure_ns_returns_positive() {
        let ns = measure_ns(Duration::from_millis(10), || {
            std::hint::black_box(1 + 1);
        });
        assert!(ns > 0.0);
    }
}
