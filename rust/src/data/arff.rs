//! ARFF (Weka's Attribute-Relation File Format) loader/writer.
//!
//! The paper's reference RF implementation is Weka, whose native interchange
//! format is ARFF; supporting it makes this system a drop-in consumer of
//! existing Weka dataset files. Supported: `@relation`, `@attribute` with
//! `numeric`/`real`/`integer` or nominal `{a,b,c}` domains, `@data` with
//! comma-separated rows, `%` comments. The **last attribute is the class**
//! and must be nominal. Sparse rows and strings/dates are not supported
//! (none of the evaluation datasets need them).

use super::{Dataset, Feature, FeatureKind, Schema};
use crate::error::{Error, Result};

fn strip_quotes(s: &str) -> &str {
    let s = s.trim();
    if (s.starts_with('\'') && s.ends_with('\'') && s.len() >= 2)
        || (s.starts_with('"') && s.ends_with('"') && s.len() >= 2)
    {
        &s[1..s.len() - 1]
    } else {
        s
    }
}

/// Parse ARFF text into a [`Dataset`].
pub fn parse(text: &str) -> Result<Dataset> {
    let mut relation = String::from("arff");
    let mut attrs: Vec<(String, Option<Vec<String>>)> = Vec::new(); // None = numeric
    let mut in_data = false;
    let mut rows: Vec<Vec<String>> = Vec::new();

    for (lineno, raw) in text.lines().enumerate() {
        let lineno = lineno + 1;
        let line = raw.trim();
        if line.is_empty() || line.starts_with('%') {
            continue;
        }
        if !in_data {
            let lower = line.to_ascii_lowercase();
            if lower.starts_with("@relation") {
                relation = strip_quotes(line[9..].trim()).to_string();
            } else if lower.starts_with("@attribute") {
                let rest = line[10..].trim();
                // name may be quoted and contain spaces
                let (name, domain) = if rest.starts_with('\'') || rest.starts_with('"') {
                    let quote = rest.chars().next().unwrap();
                    let end = rest[1..]
                        .find(quote)
                        .ok_or_else(|| Error::parse(format!("line {lineno}: unterminated attribute name")))?
                        + 1;
                    (rest[1..end].to_string(), rest[end + 1..].trim())
                } else {
                    let mut it = rest.splitn(2, char::is_whitespace);
                    let n = it.next().unwrap().to_string();
                    (n, it.next().unwrap_or("").trim())
                };
                if domain.starts_with('{') {
                    let inner = domain
                        .strip_prefix('{')
                        .and_then(|d| d.trim_end().strip_suffix('}'))
                        .ok_or_else(|| {
                            Error::parse(format!("line {lineno}: malformed nominal domain"))
                        })?;
                    let values: Vec<String> = inner
                        .split(',')
                        .map(|v| strip_quotes(v).to_string())
                        .collect();
                    if values.is_empty() {
                        return Err(Error::parse(format!("line {lineno}: empty nominal domain")));
                    }
                    attrs.push((name, Some(values)));
                } else {
                    let d = domain.to_ascii_lowercase();
                    if d.starts_with("numeric") || d.starts_with("real") || d.starts_with("integer")
                    {
                        attrs.push((name, None));
                    } else {
                        return Err(Error::parse(format!(
                            "line {lineno}: unsupported attribute type '{domain}'"
                        )));
                    }
                }
            } else if lower.starts_with("@data") {
                in_data = true;
            } else {
                return Err(Error::parse(format!(
                    "line {lineno}: unexpected directive '{line}'"
                )));
            }
        } else {
            let fields: Vec<String> = line
                .split(',')
                .map(|f| strip_quotes(f).to_string())
                .collect();
            if fields.len() != attrs.len() {
                return Err(Error::parse(format!(
                    "line {lineno}: expected {} fields, found {}",
                    attrs.len(),
                    fields.len()
                )));
            }
            rows.push(fields);
        }
    }

    if attrs.len() < 2 {
        return Err(Error::parse("ARFF needs at least one feature and a class attribute"));
    }
    if rows.is_empty() {
        return Err(Error::parse("ARFF has no data rows"));
    }
    let (class_name, class_domain) = attrs.pop().unwrap();
    let classes = class_domain.ok_or_else(|| {
        Error::parse(format!("class attribute '{class_name}' must be nominal"))
    })?;

    let features: Vec<Feature> = attrs
        .iter()
        .map(|(name, dom)| Feature {
            name: name.clone(),
            kind: match dom {
                None => FeatureKind::Numeric,
                Some(values) => FeatureKind::Categorical {
                    values: values.clone(),
                },
            },
        })
        .collect();
    let nf = features.len();
    let schema = Schema {
        features,
        classes: classes.clone(),
        task: super::Task::Classification,
    };

    let mut cells = Vec::with_capacity(rows.len() * nf);
    let mut labels = Vec::with_capacity(rows.len());
    for (r, row) in rows.iter().enumerate() {
        for (c, (name, dom)) in attrs.iter().enumerate() {
            let field = &row[c];
            match dom {
                None => cells.push(field.parse::<f32>().map_err(|_| {
                    Error::parse(format!("data row {r}: '{field}' is not numeric for '{name}'"))
                })?),
                Some(values) => {
                    let code = values.iter().position(|v| v == field).ok_or_else(|| {
                        Error::parse(format!(
                            "data row {r}: value '{field}' not in domain of '{name}'"
                        ))
                    })?;
                    cells.push(code as f32);
                }
            }
        }
        let y = classes
            .iter()
            .position(|v| *v == row[nf])
            .ok_or_else(|| Error::parse(format!("data row {r}: unknown class '{}'", row[nf])))?;
        labels.push(y as u32);
    }
    Dataset::new(relation, schema, cells, labels)
}

/// Load an ARFF file.
pub fn load_file(path: &str) -> Result<Dataset> {
    parse(&std::fs::read_to_string(path)?)
}

/// Render a dataset as ARFF text (round-trips through [`parse`]).
pub fn to_arff(ds: &Dataset) -> String {
    let mut out = format!("@relation '{}'\n\n", ds.name);
    for f in &ds.schema.features {
        match &f.kind {
            FeatureKind::Numeric => out.push_str(&format!("@attribute '{}' numeric\n", f.name)),
            FeatureKind::Categorical { values } => out.push_str(&format!(
                "@attribute '{}' {{{}}}\n",
                f.name,
                values.join(",")
            )),
        }
    }
    out.push_str(&format!("@attribute 'class' {{{}}}\n", ds.schema.classes.join(",")));
    out.push_str("\n@data\n");
    for i in 0..ds.n_rows() {
        let mut row: Vec<String> = ds
            .row(i)
            .iter()
            .enumerate()
            .map(|(f, &v)| ds.schema.render_value(f, v))
            .collect();
        row.push(ds.schema.classes[ds.label(i) as usize].clone());
        out.push_str(&row.join(","));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
% Iris fragment
@relation iris
@attribute sepallength numeric
@attribute 'petal width' real
@attribute color {red, green}
@attribute class {setosa,versicolor}

@data
5.1,0.2,red,setosa
7.0,1.4,green,versicolor
% trailing comment
4.9,0.2,red,setosa
";

    #[test]
    fn parse_basic() {
        let ds = parse(SAMPLE).unwrap();
        assert_eq!(ds.name, "iris");
        assert_eq!(ds.n_rows(), 3);
        assert_eq!(ds.n_features(), 3);
        assert_eq!(ds.schema.features[1].name, "petal width");
        assert_eq!(ds.row(1), &[7.0, 1.4, 1.0]);
        assert_eq!(ds.label(1), 1);
    }

    #[test]
    fn roundtrip() {
        let ds = parse(SAMPLE).unwrap();
        let ds2 = parse(&to_arff(&ds)).unwrap();
        assert_eq!(ds.n_rows(), ds2.n_rows());
        for i in 0..ds.n_rows() {
            assert_eq!(ds.row(i), ds2.row(i));
            assert_eq!(ds.label(i), ds2.label(i));
        }
        assert_eq!(ds.schema, ds2.schema);
    }

    #[test]
    fn class_must_be_nominal() {
        let bad = "@relation r\n@attribute a numeric\n@attribute class numeric\n@data\n1,2\n";
        assert!(parse(bad).unwrap_err().to_string().contains("nominal"));
    }

    #[test]
    fn unknown_nominal_value_rejected() {
        let bad = "@relation r\n@attribute a {x,y}\n@attribute class {p,n}\n@data\nz,p\n";
        assert!(parse(bad).unwrap_err().to_string().contains("not in domain"));
    }

    #[test]
    fn ragged_row_rejected() {
        let bad = "@relation r\n@attribute a numeric\n@attribute class {p,n}\n@data\n1\n";
        assert!(parse(bad).unwrap_err().to_string().contains("expected 2 fields"));
    }

    #[test]
    fn unsupported_type_rejected() {
        let bad = "@relation r\n@attribute a string\n@attribute class {p}\n@data\nx,p\n";
        assert!(parse(bad).unwrap_err().to_string().contains("unsupported"));
    }
}
