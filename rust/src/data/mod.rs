//! Dataset substrate: schemas, tabular data, loaders and splits.
//!
//! The system is Weka-free (the paper used Weka only as a stock RF
//! implementation), so this module provides the equivalent data handling:
//! typed schemas (numeric + categorical features), CSV and ARFF loaders,
//! the six built-in evaluation datasets, train/test splitting, and synthetic
//! generators for the serving workload.
//!
//! **Encoding.** Categorical features are stored as ordinal codes in `f32`
//! cells (`0.0, 1.0, …`). Trees split every feature with a threshold
//! predicate `x[f] < t`; for a `k`-valued categorical this expresses every
//! prefix/suffix partition of the code ordering, which together with the
//! discrete-grid feasibility rules in [`crate::feas`] preserves the paper's
//! predicate semantics while keeping a single uniform predicate language
//! (see DESIGN.md §Substitutions).

pub mod arff;
pub mod csv;
pub mod datasets;
pub mod split;
pub mod synth;

use crate::error::{Error, Result};

/// Resolve a dataset spec: a built-in name, or a `.csv`/`.arff` path.
pub fn resolve(spec: &str) -> Result<Dataset> {
    if spec.ends_with(".csv") {
        csv::load_file(spec)
    } else if spec.ends_with(".arff") {
        arff::load_file(spec)
    } else {
        datasets::load(spec)
    }
}

/// The kind of a feature column.
#[derive(Debug, Clone, PartialEq)]
pub enum FeatureKind {
    /// Real-valued.
    Numeric,
    /// Finite-valued; cell values are ordinal codes `0..values.len()`.
    Categorical { values: Vec<String> },
}

impl FeatureKind {
    /// Number of distinct values for categorical features.
    pub fn cardinality(&self) -> Option<usize> {
        match self {
            FeatureKind::Numeric => None,
            FeatureKind::Categorical { values } => Some(values.len()),
        }
    }
}

/// A named feature column.
#[derive(Debug, Clone, PartialEq)]
pub struct Feature {
    /// Column name (used in predicate rendering, e.g. `petalwidth < 1.65`).
    pub name: String,
    /// Numeric or categorical.
    pub kind: FeatureKind,
}

/// What a model predicts — the interpretation of the class alphabet.
///
/// The aggregation algebra is identical for both tasks: trees vote for
/// class indices, the compiled DD carries the per-class vote vector, and
/// the *decision rule* is a pure post-map over that vector
/// ([`crate::add::terminal::argmax`] /
/// [`crate::add::terminal::weighted_argmax`] /
/// [`crate::add::terminal::expected_value`]). Regression reuses the
/// whole pipeline by treating each class as a target-value bin: the
/// schema carries one representative value per bin and the prediction is
/// the vote-weighted mean of those values.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum Task {
    /// Classes are categorical labels; predictions are argmax decisions.
    #[default]
    Classification,
    /// Classes are target-value bins; predictions are vote-weighted
    /// means over the bin value table.
    Regression {
        /// Representative target value per class (one entry per class;
        /// the mean of the training targets that fell in the bin).
        values: Vec<f32>,
    },
}

impl Task {
    /// True for [`Task::Regression`].
    pub fn is_regression(&self) -> bool {
        matches!(self, Task::Regression { .. })
    }

    /// The per-class value table of a regression task (`None` for
    /// classification).
    pub fn values(&self) -> Option<&[f32]> {
        match self {
            Task::Classification => None,
            Task::Regression { values } => Some(values),
        }
    }
}

/// Dataset schema: feature columns plus the class alphabet `C`.
#[derive(Debug, Clone, PartialEq)]
pub struct Schema {
    /// Feature columns, in cell order.
    pub features: Vec<Feature>,
    /// Class labels; the classification co-domain `C` of the paper.
    pub classes: Vec<String>,
    /// What the classes mean: categorical labels, or target-value bins
    /// of a regression forest (see [`Task`]).
    pub task: Task,
}

impl Schema {
    /// Number of feature columns.
    pub fn n_features(&self) -> usize {
        self.features.len()
    }

    /// Number of classes `|C|`.
    pub fn n_classes(&self) -> usize {
        self.classes.len()
    }

    /// Index of a class label.
    pub fn class_index(&self, label: &str) -> Option<usize> {
        self.classes.iter().position(|c| c == label)
    }

    /// The per-class regression value table (`None` for classification).
    pub fn values(&self) -> Option<&[f32]> {
        self.task.values()
    }

    /// Check the task is internally consistent: a regression schema
    /// needs exactly one finite value per class.
    pub fn validate_task(&self) -> Result<()> {
        if let Task::Regression { values } = &self.task {
            if values.len() != self.classes.len() {
                return Err(Error::invalid(format!(
                    "regression schema has {} values for {} classes",
                    values.len(),
                    self.classes.len()
                )));
            }
            if values.iter().any(|v| !v.is_finite()) {
                return Err(Error::invalid(
                    "regression value table must be finite",
                ));
            }
        }
        Ok(())
    }

    /// Render a cell value for display (categorical codes back to names).
    pub fn render_value(&self, feature: usize, v: f32) -> String {
        match &self.features[feature].kind {
            FeatureKind::Numeric => format!("{v}"),
            FeatureKind::Categorical { values } => {
                let i = v as usize;
                values
                    .get(i)
                    .cloned()
                    .unwrap_or_else(|| format!("<bad code {v}>"))
            }
        }
    }
}

/// An in-memory labelled dataset (row-major cells).
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Human-readable dataset name.
    pub name: String,
    /// Column schema.
    pub schema: Schema,
    cells: Vec<f32>,
    labels: Vec<u32>,
}

impl Dataset {
    /// Build a dataset, validating dimensions and label/code ranges.
    pub fn new(
        name: impl Into<String>,
        schema: Schema,
        cells: Vec<f32>,
        labels: Vec<u32>,
    ) -> Result<Dataset> {
        let nf = schema.n_features();
        if nf == 0 {
            return Err(Error::invalid("dataset must have at least one feature"));
        }
        schema.validate_task()?;
        if cells.len() % nf != 0 {
            return Err(Error::invalid(format!(
                "cell count {} is not a multiple of feature count {nf}",
                cells.len()
            )));
        }
        let rows = cells.len() / nf;
        if labels.len() != rows {
            return Err(Error::invalid(format!(
                "label count {} != row count {rows}",
                labels.len()
            )));
        }
        for &y in &labels {
            if y as usize >= schema.n_classes() {
                return Err(Error::invalid(format!(
                    "label {y} out of range for {} classes",
                    schema.n_classes()
                )));
            }
        }
        for (f, feat) in schema.features.iter().enumerate() {
            if let Some(k) = feat.kind.cardinality() {
                for r in 0..rows {
                    let v = cells[r * nf + f];
                    if v.fract() != 0.0 || v < 0.0 || v as usize >= k {
                        return Err(Error::invalid(format!(
                            "row {r}, feature '{}': code {v} out of range 0..{k}",
                            feat.name
                        )));
                    }
                }
            }
        }
        Ok(Dataset {
            name: name.into(),
            schema,
            cells,
            labels,
        })
    }

    /// Number of rows.
    pub fn n_rows(&self) -> usize {
        self.labels.len()
    }

    /// Number of feature columns.
    pub fn n_features(&self) -> usize {
        self.schema.n_features()
    }

    /// Number of classes.
    pub fn n_classes(&self) -> usize {
        self.schema.n_classes()
    }

    /// Feature row `i`.
    pub fn row(&self, i: usize) -> &[f32] {
        let nf = self.n_features();
        &self.cells[i * nf..(i + 1) * nf]
    }

    /// The whole dataset as a zero-copy [`RowMatrix`](crate::batch::RowMatrix)
    /// batch (cells are already stored row-major).
    pub fn matrix(&self) -> crate::batch::RowMatrix<'_> {
        crate::batch::RowMatrix::new(&self.cells, self.n_features())
            .expect("dataset cells are rectangular by construction")
    }

    /// Label of row `i` (class index).
    pub fn label(&self, i: usize) -> u32 {
        self.labels[i]
    }

    /// All labels.
    pub fn labels(&self) -> &[u32] {
        &self.labels
    }

    /// Iterate `(row, label)` pairs.
    pub fn iter(&self) -> impl Iterator<Item = (&[f32], u32)> + '_ {
        (0..self.n_rows()).map(move |i| (self.row(i), self.label(i)))
    }

    /// Per-class row counts.
    pub fn class_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.n_classes()];
        for &y in &self.labels {
            h[y as usize] += 1;
        }
        h
    }

    /// Select a subset of rows (by index, duplicates allowed — used for
    /// bootstrap samples).
    pub fn select(&self, indices: &[usize]) -> Dataset {
        let nf = self.n_features();
        let mut cells = Vec::with_capacity(indices.len() * nf);
        let mut labels = Vec::with_capacity(indices.len());
        for &i in indices {
            cells.extend_from_slice(self.row(i));
            labels.push(self.label(i));
        }
        Dataset {
            name: self.name.clone(),
            schema: self.schema.clone(),
            cells,
            labels,
        }
    }

    /// Distinct sorted values of a feature column (split-candidate support).
    pub fn distinct_values(&self, feature: usize) -> Vec<f32> {
        let nf = self.n_features();
        let mut vs: Vec<f32> = (0..self.n_rows())
            .map(|r| self.cells[r * nf + feature])
            .collect();
        vs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        vs.dedup();
        vs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn tiny_schema() -> Schema {
        Schema {
            features: vec![
                Feature {
                    name: "x0".into(),
                    kind: FeatureKind::Numeric,
                },
                Feature {
                    name: "color".into(),
                    kind: FeatureKind::Categorical {
                        values: vec!["red".into(), "green".into()],
                    },
                },
            ],
            classes: vec!["a".into(), "b".into()],
            task: Task::Classification,
        }
    }

    #[test]
    fn construct_and_access() {
        let ds = Dataset::new(
            "tiny",
            tiny_schema(),
            vec![0.5, 0.0, 1.5, 1.0, -1.0, 0.0],
            vec![0, 1, 0],
        )
        .unwrap();
        assert_eq!(ds.n_rows(), 3);
        assert_eq!(ds.row(1), &[1.5, 1.0]);
        assert_eq!(ds.label(2), 0);
        assert_eq!(ds.class_histogram(), vec![2, 1]);
    }

    #[test]
    fn rejects_bad_shapes_and_codes() {
        assert!(Dataset::new("t", tiny_schema(), vec![0.0; 5], vec![0, 0]).is_err());
        assert!(Dataset::new("t", tiny_schema(), vec![0.0; 4], vec![0]).is_err());
        // label out of range
        assert!(Dataset::new("t", tiny_schema(), vec![0.0; 4], vec![0, 7]).is_err());
        // categorical code out of range
        assert!(
            Dataset::new("t", tiny_schema(), vec![0.0, 5.0, 0.0, 0.0], vec![0, 0]).is_err()
        );
        // fractional categorical code
        assert!(
            Dataset::new("t", tiny_schema(), vec![0.0, 0.5, 0.0, 0.0], vec![0, 0]).is_err()
        );
    }

    #[test]
    fn select_and_distinct() {
        let ds = Dataset::new(
            "t",
            tiny_schema(),
            vec![3.0, 0.0, 1.0, 1.0, 3.0, 0.0],
            vec![0, 1, 1],
        )
        .unwrap();
        let sub = ds.select(&[2, 2, 0]);
        assert_eq!(sub.n_rows(), 3);
        assert_eq!(sub.label(0), 1);
        assert_eq!(sub.row(2), &[3.0, 0.0]);
        assert_eq!(ds.distinct_values(0), vec![1.0, 3.0]);
    }

    #[test]
    fn render_values() {
        let s = tiny_schema();
        assert_eq!(s.render_value(0, 1.5), "1.5");
        assert_eq!(s.render_value(1, 1.0), "green");
    }
}
