//! Train/test splitting and cross-validation folds.

use super::Dataset;
use crate::error::{Error, Result};
use crate::util::rng::Rng;

/// Split into `(train, test)` with `test_frac` of rows in the test set.
///
/// Stratified: each class contributes proportionally to the test set, so
/// small classes are never absent from either side.
pub fn train_test_split(ds: &Dataset, test_frac: f64, seed: u64) -> Result<(Dataset, Dataset)> {
    if !(0.0..1.0).contains(&test_frac) {
        return Err(Error::invalid("test_frac must be in [0, 1)"));
    }
    let mut rng = Rng::new(seed);
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); ds.n_classes()];
    for i in 0..ds.n_rows() {
        by_class[ds.label(i) as usize].push(i);
    }
    let mut train_idx = Vec::new();
    let mut test_idx = Vec::new();
    for mut idxs in by_class {
        rng.shuffle(&mut idxs);
        let n_test = ((idxs.len() as f64) * test_frac).round() as usize;
        test_idx.extend_from_slice(&idxs[..n_test]);
        train_idx.extend_from_slice(&idxs[n_test..]);
    }
    train_idx.sort_unstable();
    test_idx.sort_unstable();
    if train_idx.is_empty() {
        return Err(Error::invalid("split left the training set empty"));
    }
    Ok((ds.select(&train_idx), ds.select(&test_idx)))
}

/// Stratified k-fold split; returns `k` (train, test) pairs covering all rows.
pub fn k_folds(ds: &Dataset, k: usize, seed: u64) -> Result<Vec<(Dataset, Dataset)>> {
    if k < 2 || k > ds.n_rows() {
        return Err(Error::invalid(format!(
            "k must be in 2..=n_rows ({}), got {k}",
            ds.n_rows()
        )));
    }
    let mut rng = Rng::new(seed);
    let mut fold_of = vec![0usize; ds.n_rows()];
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); ds.n_classes()];
    for i in 0..ds.n_rows() {
        by_class[ds.label(i) as usize].push(i);
    }
    // Deal each class's rows round-robin over folds, starting at a random
    // offset so folds are balanced per class.
    for mut idxs in by_class {
        rng.shuffle(&mut idxs);
        let start = rng.below_usize(k);
        for (j, i) in idxs.into_iter().enumerate() {
            fold_of[i] = (start + j) % k;
        }
    }
    let mut out = Vec::with_capacity(k);
    for f in 0..k {
        let test: Vec<usize> = (0..ds.n_rows()).filter(|&i| fold_of[i] == f).collect();
        let train: Vec<usize> = (0..ds.n_rows()).filter(|&i| fold_of[i] != f).collect();
        if test.is_empty() || train.is_empty() {
            return Err(Error::invalid("degenerate fold (too many folds for dataset)"));
        }
        out.push((ds.select(&train), ds.select(&test)));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;

    #[test]
    fn split_partitions_rows() {
        let ds = datasets::iris();
        let (train, test) = train_test_split(&ds, 0.2, 1).unwrap();
        assert_eq!(train.n_rows() + test.n_rows(), 150);
        assert_eq!(test.n_rows(), 30);
        // stratified: 10 per class
        assert_eq!(test.class_histogram(), vec![10, 10, 10]);
    }

    #[test]
    fn split_is_seeded() {
        let ds = datasets::iris();
        let (a, _) = train_test_split(&ds, 0.3, 7).unwrap();
        let (b, _) = train_test_split(&ds, 0.3, 7).unwrap();
        let (c, _) = train_test_split(&ds, 0.3, 8).unwrap();
        assert_eq!(a.row(0), b.row(0));
        assert_eq!(a.labels(), b.labels());
        assert!(a.labels() != c.labels() || a.row(5) != c.row(5));
    }

    #[test]
    fn split_rejects_bad_frac() {
        let ds = datasets::lenses();
        assert!(train_test_split(&ds, 1.0, 0).is_err());
        assert!(train_test_split(&ds, -0.1, 0).is_err());
    }

    #[test]
    fn folds_cover_everything() {
        let ds = datasets::iris();
        let folds = k_folds(&ds, 5, 3).unwrap();
        assert_eq!(folds.len(), 5);
        let total_test: usize = folds.iter().map(|(_, t)| t.n_rows()).sum();
        assert_eq!(total_test, 150);
        for (train, test) in &folds {
            assert_eq!(train.n_rows() + test.n_rows(), 150);
            // stratification keeps all classes present
            assert!(test.class_histogram().iter().all(|&c| c > 0));
        }
    }

    #[test]
    fn folds_reject_bad_k() {
        let ds = datasets::lenses();
        assert!(k_folds(&ds, 1, 0).is_err());
        assert!(k_folds(&ds, 25, 0).is_err());
    }
}
