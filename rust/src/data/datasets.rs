//! Built-in evaluation datasets — the six UCI corpora from the paper's §6.
//!
//! No network access exists in this environment, so each dataset is either
//! **derived exactly** (three of the six are defined by deterministic rules,
//! not collected data) or **synthesised** from a documented class-conditional
//! model with the original schema, row count and class balance (see
//! DESIGN.md §Substitutions):
//!
//! | name            | rows | provenance |
//! |-----------------|------|------------|
//! | `iris`          | 150  | synthesised from Fisher's published per-class means/stds, 50/class, 1-decimal grid |
//! | `balance-scale` | 625  | **exact**: full 5⁴ factorial, class by comparing `LW·LD` vs `RW·RD` |
//! | `lenses`        | 24   | **exact**: full factorial with Cendrowska's fitting rules (4 hard / 5 soft / 15 none) |
//! | `tic-tac-toe`   | 958  | **exact**: all distinct terminal boards of the game tree (626 x-wins positive) |
//! | `vote`          | 435  | synthesised: 267 dem / 168 rep, 16 issues, party-conditional vote model with abstentions |
//! | `breast-cancer` | 286  | synthesised: 201 / 85 class split, Ljubljana schema, risk-factor-conditional model |

use super::{Dataset, Feature, FeatureKind, Schema, Task};
use crate::error::{Error, Result};
use crate::util::rng::Rng;
use std::collections::BTreeSet;

/// Names of all built-in datasets (the paper's Table 1/2 rows, plus the
/// synthetic regression corpus `synth-reg`).
pub fn names() -> Vec<&'static str> {
    vec![
        "balance-scale",
        "breast-cancer",
        "lenses",
        "iris",
        "synth-reg",
        "tic-tac-toe",
        "vote",
    ]
}

/// Load a built-in dataset by name (case-insensitive; `_` ≡ `-`).
pub fn load(name: &str) -> Result<Dataset> {
    match name.to_ascii_lowercase().replace('_', "-").as_str() {
        "iris" => Ok(iris()),
        "balance-scale" | "balance" => Ok(balance_scale()),
        "lenses" => Ok(lenses()),
        "tic-tac-toe" | "tictactoe" | "ttt" => Ok(tic_tac_toe()),
        "vote" | "voting" | "house-votes-84" => Ok(vote()),
        "breast-cancer" | "breast" => Ok(breast_cancer()),
        "synth-reg" | "synthreg" | "regression" => {
            super::synth::regression(&super::synth::RegressionSpec::default())
        }
        other => Err(Error::invalid(format!(
            "unknown dataset '{other}' (available: {})",
            names().join(", ")
        ))),
    }
}

fn numeric(name: &str) -> Feature {
    Feature {
        name: name.to_string(),
        kind: FeatureKind::Numeric,
    }
}

fn categorical(name: &str, values: &[&str]) -> Feature {
    Feature {
        name: name.to_string(),
        kind: FeatureKind::Categorical {
            values: values.iter().map(|v| v.to_string()).collect(),
        },
    }
}

/// Iris (Fisher 1936): 150 rows, 4 numeric features, 3 species.
///
/// Synthesised from the published per-class feature means and standard
/// deviations, sampled on the same 1-decimal measurement grid. The
/// experiments measure structural quantities (steps, DD sizes), which depend
/// on the threshold structure the learner extracts, not the historical rows.
pub fn iris() -> Dataset {
    // (per-class) means and stds for sepal length/width, petal length/width —
    // the statistics reported for the original data.
    const STATS: [([f64; 4], [f64; 4]); 3] = [
        ([5.006, 3.428, 1.462, 0.246], [0.352, 0.379, 0.174, 0.105]),
        ([5.936, 2.770, 4.260, 1.326], [0.516, 0.314, 0.470, 0.198]),
        ([6.588, 2.974, 5.552, 2.026], [0.636, 0.322, 0.552, 0.275]),
    ];
    let mut rng = Rng::new(0x1A15);
    let mut cells = Vec::with_capacity(150 * 4);
    let mut labels = Vec::with_capacity(150);
    for (cls, (means, stds)) in STATS.iter().enumerate() {
        for _ in 0..50 {
            for f in 0..4 {
                let v = means[f] + stds[f] * rng.normal();
                let v = (v * 10.0).round() / 10.0; // 1-decimal measurement grid
                cells.push(v.max(0.1) as f32);
            }
            labels.push(cls as u32);
        }
    }
    Dataset::new(
        "iris",
        Schema {
            features: vec![
                numeric("sepallength"),
                numeric("sepalwidth"),
                numeric("petallength"),
                numeric("petalwidth"),
            ],
            classes: vec!["setosa".into(), "versicolor".into(), "virginica".into()],
            task: Task::Classification,
        },
        cells,
        labels,
    )
    .expect("iris generator is well-formed")
}

/// Balance Scale: **exact** — the UCI dataset is the full factorial of
/// weights/distances in `1..=5` on both arms, labelled by the physics:
/// `L` if `LW·LD > RW·RD`, `R` if `<`, `B` if balanced. 625 rows
/// (288 L / 49 B / 288 R).
pub fn balance_scale() -> Dataset {
    let mut cells = Vec::with_capacity(625 * 4);
    let mut labels = Vec::with_capacity(625);
    for lw in 1..=5u32 {
        for ld in 1..=5u32 {
            for rw in 1..=5u32 {
                for rd in 1..=5u32 {
                    cells.extend_from_slice(&[lw as f32, ld as f32, rw as f32, rd as f32]);
                    let (l, r) = (lw * ld, rw * rd);
                    labels.push(if l > r {
                        0
                    } else if l == r {
                        1
                    } else {
                        2
                    });
                }
            }
        }
    }
    Dataset::new(
        "balance-scale",
        Schema {
            features: vec![
                numeric("left-weight"),
                numeric("left-distance"),
                numeric("right-weight"),
                numeric("right-distance"),
            ],
            classes: vec!["L".into(), "B".into(), "R".into()],
            task: Task::Classification,
        },
        cells,
        labels,
    )
    .expect("balance-scale generator is well-formed")
}

/// Lenses (Cendrowska 1987): **exact** — the complete 3·2·2·2 factorial with
/// the published fitting rules. 24 rows (4 hard / 5 soft / 15 none).
pub fn lenses() -> Dataset {
    let ages = ["young", "pre-presbyopic", "presbyopic"];
    let prescriptions = ["myope", "hypermetrope"];
    let astigmatic = ["no", "yes"];
    let tears = ["reduced", "normal"];
    let mut cells = Vec::new();
    let mut labels = Vec::new();
    for (ai, _age) in ages.iter().enumerate() {
        for (pi, _p) in prescriptions.iter().enumerate() {
            for (si, _a) in astigmatic.iter().enumerate() {
                for (ti, _t) in tears.iter().enumerate() {
                    cells.extend_from_slice(&[ai as f32, pi as f32, si as f32, ti as f32]);
                    // Cendrowska's rule set.
                    let cls = if ti == 0 {
                        2 // reduced tear production -> none
                    } else if si == 0 {
                        // not astigmatic -> soft, except presbyopic myopes
                        if ai == 2 && pi == 0 {
                            2
                        } else {
                            1
                        }
                    } else {
                        // astigmatic -> hard for myopes; hypermetropes only when young
                        if pi == 0 {
                            0
                        } else if ai == 0 {
                            0
                        } else {
                            2
                        }
                    };
                    labels.push(cls);
                }
            }
        }
    }
    Dataset::new(
        "lenses",
        Schema {
            features: vec![
                categorical("age", &ages),
                categorical("spectacle-prescrip", &prescriptions),
                categorical("astigmatism", &astigmatic),
                categorical("tear-prod-rate", &tears),
            ],
            classes: vec!["hard".into(), "soft".into(), "none".into()],
            task: Task::Classification,
        },
        cells,
        labels,
    )
    .expect("lenses generator is well-formed")
}

/// Tic-Tac-Toe Endgame: **exact** — the distinct terminal board
/// configurations of tic-tac-toe with `x` moving first (the UCI dataset's
/// definition). 958 rows; class `positive` iff `x` has a three-in-a-row
/// (626 positive / 332 negative).
pub fn tic_tac_toe() -> Dataset {
    const LINES: [[usize; 3]; 8] = [
        [0, 1, 2],
        [3, 4, 5],
        [6, 7, 8],
        [0, 3, 6],
        [1, 4, 7],
        [2, 5, 8],
        [0, 4, 8],
        [2, 4, 6],
    ];
    fn winner(board: &[u8; 9], player: u8) -> bool {
        LINES
            .iter()
            .any(|l| l.iter().all(|&i| board[i] == player))
    }
    // DFS over the game tree, collecting distinct terminal positions.
    fn walk(board: &mut [u8; 9], player: u8, out: &mut BTreeSet<[u8; 9]>) {
        // players: 1 = x, 2 = o; 0 = blank
        if winner(board, 1) || winner(board, 2) || board.iter().all(|&c| c != 0) {
            out.insert(*board);
            return;
        }
        for i in 0..9 {
            if board[i] == 0 {
                board[i] = player;
                walk(board, 3 - player, out);
                board[i] = 0;
            }
        }
    }
    let mut terminals = BTreeSet::new();
    walk(&mut [0u8; 9], 1, &mut terminals);

    let squares = [
        "top-left", "top-middle", "top-right", "middle-left", "middle-middle", "middle-right",
        "bottom-left", "bottom-middle", "bottom-right",
    ];
    let mut cells = Vec::with_capacity(terminals.len() * 9);
    let mut labels = Vec::with_capacity(terminals.len());
    for board in &terminals {
        for &c in board.iter() {
            // codes follow the UCI value order {x, o, b}
            cells.push(match c {
                1 => 0.0,
                2 => 1.0,
                _ => 2.0,
            });
        }
        labels.push(if winner(board, 1) { 0 } else { 1 });
    }
    let features = squares
        .iter()
        .map(|s| categorical(&format!("{s}-square"), &["x", "o", "b"]))
        .collect();
    Dataset::new(
        "tic-tac-toe",
        Schema {
            features,
            classes: vec!["positive".into(), "negative".into()],
            task: Task::Classification,
        },
        cells,
        labels,
    )
    .expect("tic-tac-toe generator is well-formed")
}

/// Congressional Voting Records (synthesised): 435 rows (267 democrat /
/// 168 republican), 16 boolean issues with abstentions (`y`/`n`/`?`).
///
/// Per-issue party-conditional yes-probabilities mirror the qualitative
/// structure of the 1984 roll call (a handful of near-party-line votes,
/// several moderately separating issues, a few non-separating ones) — which
/// is what gives the learned forests their shallow, highly shared predicate
/// structure.
pub fn vote() -> Dataset {
    // (issue, P(yes | democrat), P(yes | republican))
    const ISSUES: [(&str, f64, f64); 16] = [
        ("handicapped-infants", 0.60, 0.19),
        ("water-project-cost-sharing", 0.50, 0.51),
        ("adoption-of-the-budget-resolution", 0.89, 0.13),
        ("physician-fee-freeze", 0.05, 0.99),
        ("el-salvador-aid", 0.22, 0.95),
        ("religious-groups-in-schools", 0.47, 0.90),
        ("anti-satellite-test-ban", 0.77, 0.24),
        ("aid-to-nicaraguan-contras", 0.83, 0.15),
        ("mx-missile", 0.76, 0.12),
        ("immigration", 0.47, 0.56),
        ("synfuels-corporation-cutback", 0.51, 0.13),
        ("education-spending", 0.14, 0.87),
        ("superfund-right-to-sue", 0.29, 0.86),
        ("crime", 0.35, 0.98),
        ("duty-free-exports", 0.64, 0.09),
        ("export-administration-act-south-africa", 0.94, 0.66),
    ];
    const MISSING_P: f64 = 0.055; // overall abstention rate in the original
    let mut rng = Rng::new(0x707E);
    let mut cells = Vec::with_capacity(435 * 16);
    let mut labels = Vec::with_capacity(435);
    for i in 0..435u32 {
        let dem = i < 267;
        for &(_, dp, rp) in ISSUES.iter() {
            let p = if dem { dp } else { rp };
            let code = if rng.chance(MISSING_P) {
                2.0 // '?'
            } else if rng.chance(p) {
                1.0 // 'y'
            } else {
                0.0 // 'n'
            };
            cells.push(code);
        }
        labels.push(if dem { 0 } else { 1 });
    }
    let features = ISSUES
        .iter()
        .map(|(name, _, _)| categorical(name, &["n", "y", "?"]))
        .collect();
    Dataset::new(
        "vote",
        Schema {
            features,
            classes: vec!["democrat".into(), "republican".into()],
            task: Task::Classification,
        },
        cells,
        labels,
    )
    .expect("vote generator is well-formed")
}

/// Breast Cancer, Ljubljana schema (synthesised): 286 rows
/// (201 no-recurrence / 85 recurrence), 9 categorical risk factors.
///
/// Class-conditional sampling skews recurrence cases toward higher tumour
/// grade (`deg-malig`), nodal involvement and larger tumours, matching the
/// medically documented direction of each factor.
pub fn breast_cancer() -> Dataset {
    let age = ["20-29", "30-39", "40-49", "50-59", "60-69", "70-79"];
    let menopause = ["lt40", "ge40", "premeno"];
    let tumor_size = [
        "0-4", "5-9", "10-14", "15-19", "20-24", "25-29", "30-34", "35-39", "40-44", "45-49",
        "50-54",
    ];
    let inv_nodes = ["0-2", "3-5", "6-8", "9-11", "12-14", "15-17", "24-26"];
    let node_caps = ["no", "yes"];
    let deg_malig = ["1", "2", "3"];
    let breast = ["left", "right"];
    let quad = ["left-up", "left-low", "right-up", "right-low", "central"];
    let irradiat = ["no", "yes"];

    // Per-class sampling weights (no-recurrence, recurrence) per value.
    let w_age: [&[f64]; 2] = [&[1.0, 4.0, 9.0, 10.0, 6.0, 1.0], &[1.0, 5.0, 10.0, 9.0, 5.0, 1.0]];
    let w_meno: [&[f64]; 2] = [&[1.0, 5.0, 7.0], &[1.0, 4.0, 8.0]];
    let w_size: [&[f64]; 2] = [
        &[2.0, 3.0, 6.0, 7.0, 10.0, 9.0, 7.0, 4.0, 2.0, 1.0, 1.0],
        &[1.0, 1.0, 3.0, 5.0, 8.0, 9.0, 9.0, 6.0, 4.0, 2.0, 2.0],
    ];
    let w_nodes: [&[f64]; 2] = [
        &[40.0, 4.0, 2.0, 1.0, 0.5, 0.3, 0.2],
        &[15.0, 8.0, 5.0, 3.0, 2.0, 1.0, 0.5],
    ];
    let w_caps: [&[f64]; 2] = [&[12.0, 1.0], &[5.0, 4.0]];
    let w_malig: [&[f64]; 2] = [&[5.0, 8.0, 3.0], &[1.0, 4.0, 9.0]];
    let w_breast: [&[f64]; 2] = [&[1.05, 1.0], &[1.1, 1.0]];
    let w_quad: [&[f64]; 2] = [&[3.0, 10.0, 3.0, 3.0, 1.5], &[3.5, 9.0, 3.0, 3.5, 2.0]];
    let w_irr: [&[f64]; 2] = [&[5.0, 1.0], &[2.5, 1.5]];

    let mut rng = Rng::new(0xBC286);
    let mut cells = Vec::with_capacity(286 * 9);
    let mut labels = Vec::with_capacity(286);
    for i in 0..286usize {
        let cls = usize::from(i >= 201); // 0 = no-recurrence, 1 = recurrence
        for weights in [
            w_age[cls], w_meno[cls], w_size[cls], w_nodes[cls], w_caps[cls], w_malig[cls],
            w_breast[cls], w_quad[cls], w_irr[cls],
        ] {
            cells.push(rng.categorical(weights) as f32);
        }
        labels.push(cls as u32);
    }
    Dataset::new(
        "breast-cancer",
        Schema {
            features: vec![
                categorical("age", &age),
                categorical("menopause", &menopause),
                categorical("tumor-size", &tumor_size),
                categorical("inv-nodes", &inv_nodes),
                categorical("node-caps", &node_caps),
                categorical("deg-malig", &deg_malig),
                categorical("breast", &breast),
                categorical("breast-quad", &quad),
                categorical("irradiat", &irradiat),
            ],
            classes: vec!["no-recurrence-events".into(), "recurrence-events".into()],
            task: Task::Classification,
        },
        cells,
        labels,
    )
    .expect("breast-cancer generator is well-formed")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_all_names() {
        for n in names() {
            let ds = load(n).unwrap();
            assert!(ds.n_rows() > 0, "{n}");
        }
        assert!(load("nope").is_err());
        assert!(load("Tic_Tac_Toe").is_ok());
    }

    #[test]
    fn iris_shape_and_balance() {
        let ds = iris();
        assert_eq!(ds.n_rows(), 150);
        assert_eq!(ds.n_features(), 4);
        assert_eq!(ds.class_histogram(), vec![50, 50, 50]);
        // Petal length separates setosa from the rest by a wide margin in the
        // source statistics; the synthesis must preserve that structure.
        let setosa_max = (0..50).map(|i| ds.row(i)[2]).fold(f32::MIN, f32::max);
        let others_min = (50..150).map(|i| ds.row(i)[2]).fold(f32::MAX, f32::min);
        assert!(setosa_max < others_min, "{setosa_max} vs {others_min}");
    }

    #[test]
    fn iris_deterministic() {
        let a = iris();
        let b = iris();
        assert_eq!(a.row(17), b.row(17));
        assert_eq!(a.row(149), b.row(149));
    }

    #[test]
    fn balance_scale_exact() {
        let ds = balance_scale();
        assert_eq!(ds.n_rows(), 625);
        // The known exact distribution of the UCI dataset.
        assert_eq!(ds.class_histogram(), vec![288, 49, 288]);
    }

    #[test]
    fn lenses_exact() {
        let ds = lenses();
        assert_eq!(ds.n_rows(), 24);
        // Cendrowska's published distribution: 4 hard, 5 soft, 15 none.
        assert_eq!(ds.class_histogram(), vec![4, 5, 15]);
    }

    #[test]
    fn tic_tac_toe_exact_terminal_count() {
        let ds = tic_tac_toe();
        // The canonical counts: 958 distinct terminal boards, 626 x-wins.
        assert_eq!(ds.n_rows(), 958);
        assert_eq!(ds.class_histogram(), vec![626, 332]);
        assert_eq!(ds.n_features(), 9);
    }

    #[test]
    fn vote_shape() {
        let ds = vote();
        assert_eq!(ds.n_rows(), 435);
        assert_eq!(ds.n_features(), 16);
        assert_eq!(ds.class_histogram(), vec![267, 168]);
        // physician-fee-freeze (feature 3) must be near-party-line.
        let mut dem_yes = 0;
        let mut rep_yes = 0;
        for (row, y) in ds.iter() {
            if row[3] == 1.0 {
                if y == 0 {
                    dem_yes += 1;
                } else {
                    rep_yes += 1;
                }
            }
        }
        assert!(dem_yes < 30, "dem_yes={dem_yes}");
        assert!(rep_yes > 140, "rep_yes={rep_yes}");
    }

    #[test]
    fn breast_cancer_shape() {
        let ds = breast_cancer();
        assert_eq!(ds.n_rows(), 286);
        assert_eq!(ds.n_features(), 9);
        assert_eq!(ds.class_histogram(), vec![201, 85]);
        // deg-malig=3 (feature 5) must be enriched in recurrence cases.
        let frac = |lo: usize, hi: usize| {
            (lo..hi).filter(|&i| ds.row(i)[5] == 2.0).count() as f64 / (hi - lo) as f64
        };
        assert!(frac(201, 286) > frac(0, 201) + 0.2);
    }

    #[test]
    fn all_built_ins_are_deterministic() {
        for n in names() {
            let a = load(n).unwrap();
            let b = load(n).unwrap();
            assert_eq!(a.labels(), b.labels(), "{n}");
            assert_eq!(a.row(a.n_rows() - 1), b.row(b.n_rows() - 1), "{n}");
        }
    }
}
