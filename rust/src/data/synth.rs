//! Generic synthetic dataset generators.
//!
//! Used by the serving workload generator, scalability benches, and
//! property tests — places that need datasets with controlled shape
//! (feature count, class count, difficulty) rather than a fixed corpus.

use super::{Dataset, Feature, FeatureKind, Schema, Task};
use crate::error::{Error, Result};
use crate::util::rng::Rng;

/// Configuration for a Gaussian-blob classification problem.
#[derive(Debug, Clone)]
pub struct BlobSpec {
    /// Rows to generate.
    pub rows: usize,
    /// Numeric feature count.
    pub features: usize,
    /// Class count (one blob per class).
    pub classes: usize,
    /// Distance between class centres (larger = easier).
    pub separation: f64,
    /// Per-feature noise std.
    pub noise: f64,
    /// RNG seed.
    pub seed: u64,
}

impl Default for BlobSpec {
    fn default() -> Self {
        BlobSpec {
            rows: 200,
            features: 4,
            classes: 3,
            separation: 3.0,
            noise: 1.0,
            seed: 0,
        }
    }
}

/// Gaussian blobs: class `c` is centred at a random point scaled by
/// `separation`; rows cycle through classes so the histogram is balanced.
pub fn blobs(spec: &BlobSpec) -> Result<Dataset> {
    let mut rng = Rng::new(spec.seed);
    let centers: Vec<Vec<f64>> = (0..spec.classes)
        .map(|_| {
            (0..spec.features)
                .map(|_| rng.normal() * spec.separation)
                .collect()
        })
        .collect();
    let mut cells = Vec::with_capacity(spec.rows * spec.features);
    let mut labels = Vec::with_capacity(spec.rows);
    for i in 0..spec.rows {
        let c = i % spec.classes;
        for f in 0..spec.features {
            cells.push((centers[c][f] + rng.normal() * spec.noise) as f32);
        }
        labels.push(c as u32);
    }
    let schema = Schema {
        features: (0..spec.features)
            .map(|f| Feature {
                name: format!("x{f}"),
                kind: FeatureKind::Numeric,
            })
            .collect(),
        classes: (0..spec.classes).map(|c| format!("c{c}")).collect(),
        task: Task::Classification,
    };
    Dataset::new(
        format!("blobs-{}x{}", spec.rows, spec.features),
        schema,
        cells,
        labels,
    )
}

/// A mixed numeric/categorical problem where the label is a noisy rule over
/// both feature kinds — exercises the full predicate language.
pub fn mixed_rule(rows: usize, seed: u64) -> Result<Dataset> {
    let mut rng = Rng::new(seed);
    let mut cells = Vec::with_capacity(rows * 4);
    let mut labels = Vec::with_capacity(rows);
    for _ in 0..rows {
        let a = rng.range_f64(0.0, 10.0) as f32;
        let b = rng.range_f64(-5.0, 5.0) as f32;
        let color = rng.below(3) as f32;
        let shape = rng.below(2) as f32;
        cells.extend_from_slice(&[a, b, color, shape]);
        let rule = (a < 4.0 && color == 0.0) || (b >= 1.5 && shape == 1.0);
        let noisy = if rng.chance(0.05) { !rule } else { rule };
        labels.push(noisy as u32);
    }
    let schema = Schema {
        features: vec![
            Feature {
                name: "a".into(),
                kind: FeatureKind::Numeric,
            },
            Feature {
                name: "b".into(),
                kind: FeatureKind::Numeric,
            },
            Feature {
                name: "color".into(),
                kind: FeatureKind::Categorical {
                    values: vec!["red".into(), "green".into(), "blue".into()],
                },
            },
            Feature {
                name: "shape".into(),
                kind: FeatureKind::Categorical {
                    values: vec!["square".into(), "round".into()],
                },
            },
        ],
        classes: vec!["no".into(), "yes".into()],
        task: Task::Classification,
    };
    Dataset::new(format!("mixed-rule-{rows}"), schema, cells, labels)
}

/// Bin continuous targets into `bins` equal-frequency quantile bins and
/// return a regression [`Dataset`]: labels are bin indices, the schema's
/// [`Task::Regression`] value table carries each bin's mean target, and
/// class "labels" render as the bin value. This is the bridge between
/// continuous targets and the paper's vote algebra — every tree votes
/// for a value bin, and the forest's prediction is the vote-weighted
/// mean ([`crate::add::terminal::expected_value`]), which the DD
/// aggregation preserves exactly.
pub fn bin_targets(
    name: impl Into<String>,
    features: Vec<Feature>,
    cells: Vec<f32>,
    targets: &[f32],
    bins: usize,
) -> Result<Dataset> {
    if bins < 2 {
        return Err(Error::invalid("regression binning needs at least 2 bins"));
    }
    if targets.is_empty() {
        return Err(Error::invalid("regression binning needs targets"));
    }
    if targets.iter().any(|t| !t.is_finite()) {
        return Err(Error::invalid("regression targets must be finite"));
    }
    // Equal-frequency bin edges over the sorted targets; duplicate edges
    // (heavily tied targets) collapse, so the effective bin count may be
    // smaller than requested.
    let mut sorted = targets.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut edges: Vec<f32> = (1..bins)
        .map(|b| sorted[b * sorted.len() / bins])
        .collect();
    edges.dedup();
    // Assign each target to its bin: index of the first edge above it.
    let bin_of = |t: f32| edges.partition_point(|&e| e <= t) as u32;
    let n_bins = edges.len() + 1;
    let labels: Vec<u32> = targets.iter().map(|&t| bin_of(t)).collect();
    // Per-bin mean target (f64 accumulation, the bin's representative
    // value); empty bins keep the midpoint of their edge interval.
    let mut sums = vec![0.0f64; n_bins];
    let mut counts = vec![0u64; n_bins];
    for (&t, &l) in targets.iter().zip(&labels) {
        sums[l as usize] += t as f64;
        counts[l as usize] += 1;
    }
    let values: Vec<f32> = (0..n_bins)
        .map(|b| {
            if counts[b] > 0 {
                (sums[b] / counts[b] as f64) as f32
            } else {
                *edges.get(b.saturating_sub(1)).unwrap_or(&0.0)
            }
        })
        .collect();
    let classes = values.iter().map(|v| format!("{v}")).collect();
    let schema = Schema {
        features,
        classes,
        task: Task::Regression { values },
    };
    Dataset::new(name, schema, cells, labels)
}

/// Configuration for the built-in synthetic regression problem.
#[derive(Debug, Clone)]
pub struct RegressionSpec {
    /// Rows to generate.
    pub rows: usize,
    /// Target-value bins (the regression resolution; see [`bin_targets`]).
    pub bins: usize,
    /// Additive noise std on the target.
    pub noise: f64,
    /// RNG seed.
    pub seed: u64,
}

impl Default for RegressionSpec {
    fn default() -> Self {
        RegressionSpec {
            rows: 400,
            bins: 16,
            noise: 0.5,
            seed: 0,
        }
    }
}

/// Friedman-#1-style regression surface over 5 numeric features:
/// `y = 10·sin(π·x0·x1) + 20·(x2 − 0.5)² + 10·x3 + 5·x4 + noise`,
/// binned through [`bin_targets`]. The built-in `synth-reg` dataset.
pub fn regression(spec: &RegressionSpec) -> Result<Dataset> {
    let mut rng = Rng::new(spec.seed);
    let nf = 5usize;
    let mut cells = Vec::with_capacity(spec.rows * nf);
    let mut targets = Vec::with_capacity(spec.rows);
    for _ in 0..spec.rows {
        let x: Vec<f64> = (0..nf).map(|_| rng.range_f64(0.0, 1.0)).collect();
        cells.extend(x.iter().map(|&v| v as f32));
        let y = 10.0 * (std::f64::consts::PI * x[0] * x[1]).sin()
            + 20.0 * (x[2] - 0.5).powi(2)
            + 10.0 * x[3]
            + 5.0 * x[4]
            + rng.normal() * spec.noise;
        targets.push(y as f32);
    }
    let features = (0..nf)
        .map(|f| Feature {
            name: format!("x{f}"),
            kind: FeatureKind::Numeric,
        })
        .collect();
    bin_targets(
        format!("synth-reg-{}", spec.rows),
        features,
        cells,
        &targets,
        spec.bins,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blobs_shape_and_balance() {
        let ds = blobs(&BlobSpec {
            rows: 90,
            classes: 3,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(ds.n_rows(), 90);
        assert_eq!(ds.class_histogram(), vec![30, 30, 30]);
    }

    #[test]
    fn blobs_deterministic_per_seed() {
        let s = BlobSpec::default();
        let a = blobs(&s).unwrap();
        let b = blobs(&s).unwrap();
        assert_eq!(a.row(7), b.row(7));
        let c = blobs(&BlobSpec { seed: 1, ..s }).unwrap();
        assert_ne!(a.row(7), c.row(7));
    }

    #[test]
    fn blobs_separable_when_separation_high() {
        // With huge separation and small noise, nearest-centre classification
        // by feature 0 alone should be mostly consistent within a class.
        let ds = blobs(&BlobSpec {
            rows: 300,
            separation: 50.0,
            noise: 0.5,
            seed: 2,
            ..Default::default()
        })
        .unwrap();
        // within-class variance of feature 0 must be far below global variance
        let mean = |xs: &[f32]| xs.iter().map(|&v| v as f64).sum::<f64>() / xs.len() as f64;
        let var = |xs: &[f32]| {
            let m = mean(xs);
            xs.iter().map(|&v| (v as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64
        };
        let all: Vec<f32> = (0..300).map(|i| ds.row(i)[0]).collect();
        let c0: Vec<f32> = (0..300)
            .filter(|&i| ds.label(i) == 0)
            .map(|i| ds.row(i)[0])
            .collect();
        assert!(var(&c0) * 20.0 < var(&all));
    }

    #[test]
    fn mixed_rule_valid_and_learnable_signal() {
        let ds = mixed_rule(500, 3).unwrap();
        assert_eq!(ds.n_rows(), 500);
        assert_eq!(ds.n_classes(), 2);
        let h = ds.class_histogram();
        assert!(h[0] > 50 && h[1] > 50, "{h:?}");
    }

    #[test]
    fn regression_dataset_bins_targets() {
        let ds = regression(&RegressionSpec::default()).unwrap();
        assert_eq!(ds.n_rows(), 400);
        assert_eq!(ds.n_features(), 5);
        assert!(ds.schema.task.is_regression());
        let values = ds.schema.values().unwrap();
        assert_eq!(values.len(), ds.n_classes());
        // bin values are sorted and finite (quantile binning preserves order)
        for w in values.windows(2) {
            assert!(w[0] <= w[1], "{values:?}");
        }
        // every label's bin value is a plausible target (Friedman#1 ∈ ~[0,30])
        for &v in values {
            assert!(v.is_finite() && v > -5.0 && v < 35.0, "{v}");
        }
        // deterministic per seed
        let again = regression(&RegressionSpec::default()).unwrap();
        assert_eq!(ds.labels(), again.labels());
        assert_eq!(ds.schema, again.schema);
    }

    #[test]
    fn bin_targets_validates_inputs() {
        let feats = vec![Feature {
            name: "x".into(),
            kind: FeatureKind::Numeric,
        }];
        assert!(bin_targets("t", feats.clone(), vec![1.0], &[1.0], 1).is_err());
        assert!(bin_targets("t", feats.clone(), vec![], &[], 4).is_err());
        assert!(bin_targets("t", feats.clone(), vec![1.0], &[f32::NAN], 4).is_err());
        // tied targets collapse edges instead of failing
        let ds = bin_targets(
            "t",
            feats,
            vec![1.0, 2.0, 3.0, 4.0],
            &[5.0, 5.0, 5.0, 9.0],
            4,
        )
        .unwrap();
        assert!(ds.n_classes() >= 2);
        assert!(ds.schema.task.is_regression());
    }
}
