//! Generic synthetic dataset generators.
//!
//! Used by the serving workload generator, scalability benches, and
//! property tests — places that need datasets with controlled shape
//! (feature count, class count, difficulty) rather than a fixed corpus.

use super::{Dataset, Feature, FeatureKind, Schema};
use crate::error::Result;
use crate::util::rng::Rng;

/// Configuration for a Gaussian-blob classification problem.
#[derive(Debug, Clone)]
pub struct BlobSpec {
    /// Rows to generate.
    pub rows: usize,
    /// Numeric feature count.
    pub features: usize,
    /// Class count (one blob per class).
    pub classes: usize,
    /// Distance between class centres (larger = easier).
    pub separation: f64,
    /// Per-feature noise std.
    pub noise: f64,
    /// RNG seed.
    pub seed: u64,
}

impl Default for BlobSpec {
    fn default() -> Self {
        BlobSpec {
            rows: 200,
            features: 4,
            classes: 3,
            separation: 3.0,
            noise: 1.0,
            seed: 0,
        }
    }
}

/// Gaussian blobs: class `c` is centred at a random point scaled by
/// `separation`; rows cycle through classes so the histogram is balanced.
pub fn blobs(spec: &BlobSpec) -> Result<Dataset> {
    let mut rng = Rng::new(spec.seed);
    let centers: Vec<Vec<f64>> = (0..spec.classes)
        .map(|_| {
            (0..spec.features)
                .map(|_| rng.normal() * spec.separation)
                .collect()
        })
        .collect();
    let mut cells = Vec::with_capacity(spec.rows * spec.features);
    let mut labels = Vec::with_capacity(spec.rows);
    for i in 0..spec.rows {
        let c = i % spec.classes;
        for f in 0..spec.features {
            cells.push((centers[c][f] + rng.normal() * spec.noise) as f32);
        }
        labels.push(c as u32);
    }
    let schema = Schema {
        features: (0..spec.features)
            .map(|f| Feature {
                name: format!("x{f}"),
                kind: FeatureKind::Numeric,
            })
            .collect(),
        classes: (0..spec.classes).map(|c| format!("c{c}")).collect(),
    };
    Dataset::new(
        format!("blobs-{}x{}", spec.rows, spec.features),
        schema,
        cells,
        labels,
    )
}

/// A mixed numeric/categorical problem where the label is a noisy rule over
/// both feature kinds — exercises the full predicate language.
pub fn mixed_rule(rows: usize, seed: u64) -> Result<Dataset> {
    let mut rng = Rng::new(seed);
    let mut cells = Vec::with_capacity(rows * 4);
    let mut labels = Vec::with_capacity(rows);
    for _ in 0..rows {
        let a = rng.range_f64(0.0, 10.0) as f32;
        let b = rng.range_f64(-5.0, 5.0) as f32;
        let color = rng.below(3) as f32;
        let shape = rng.below(2) as f32;
        cells.extend_from_slice(&[a, b, color, shape]);
        let rule = (a < 4.0 && color == 0.0) || (b >= 1.5 && shape == 1.0);
        let noisy = if rng.chance(0.05) { !rule } else { rule };
        labels.push(noisy as u32);
    }
    let schema = Schema {
        features: vec![
            Feature {
                name: "a".into(),
                kind: FeatureKind::Numeric,
            },
            Feature {
                name: "b".into(),
                kind: FeatureKind::Numeric,
            },
            Feature {
                name: "color".into(),
                kind: FeatureKind::Categorical {
                    values: vec!["red".into(), "green".into(), "blue".into()],
                },
            },
            Feature {
                name: "shape".into(),
                kind: FeatureKind::Categorical {
                    values: vec!["square".into(), "round".into()],
                },
            },
        ],
        classes: vec!["no".into(), "yes".into()],
    };
    Dataset::new(format!("mixed-rule-{rows}"), schema, cells, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blobs_shape_and_balance() {
        let ds = blobs(&BlobSpec {
            rows: 90,
            classes: 3,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(ds.n_rows(), 90);
        assert_eq!(ds.class_histogram(), vec![30, 30, 30]);
    }

    #[test]
    fn blobs_deterministic_per_seed() {
        let s = BlobSpec::default();
        let a = blobs(&s).unwrap();
        let b = blobs(&s).unwrap();
        assert_eq!(a.row(7), b.row(7));
        let c = blobs(&BlobSpec { seed: 1, ..s }).unwrap();
        assert_ne!(a.row(7), c.row(7));
    }

    #[test]
    fn blobs_separable_when_separation_high() {
        // With huge separation and small noise, nearest-centre classification
        // by feature 0 alone should be mostly consistent within a class.
        let ds = blobs(&BlobSpec {
            rows: 300,
            separation: 50.0,
            noise: 0.5,
            seed: 2,
            ..Default::default()
        })
        .unwrap();
        // within-class variance of feature 0 must be far below global variance
        let mean = |xs: &[f32]| xs.iter().map(|&v| v as f64).sum::<f64>() / xs.len() as f64;
        let var = |xs: &[f32]| {
            let m = mean(xs);
            xs.iter().map(|&v| (v as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64
        };
        let all: Vec<f32> = (0..300).map(|i| ds.row(i)[0]).collect();
        let c0: Vec<f32> = (0..300)
            .filter(|&i| ds.label(i) == 0)
            .map(|i| ds.row(i)[0])
            .collect();
        assert!(var(&c0) * 20.0 < var(&all));
    }

    #[test]
    fn mixed_rule_valid_and_learnable_signal() {
        let ds = mixed_rule(500, 3).unwrap();
        assert_eq!(ds.n_rows(), 500);
        assert_eq!(ds.n_classes(), 2);
        let h = ds.class_histogram();
        assert!(h[0] > 50 && h[1] > 50, "{h:?}");
    }
}
