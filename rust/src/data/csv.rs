//! CSV loader/writer with schema inference.
//!
//! Format: first line is a header; the **last column is the class label**.
//! A column is numeric when every cell parses as a float, categorical
//! otherwise (value dictionary in first-appearance order). Quoted fields
//! with embedded separators/quotes are supported.

use super::{Dataset, Feature, FeatureKind, Schema};
use crate::error::{Error, Result};

/// Split one CSV record honouring double quotes.
fn split_record(line: &str, lineno: usize) -> Result<Vec<String>> {
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut chars = line.chars().peekable();
    let mut in_quotes = false;
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes => {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    cur.push('"');
                } else {
                    in_quotes = false;
                }
            }
            '"' if cur.is_empty() => in_quotes = true,
            ',' if !in_quotes => {
                fields.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    if in_quotes {
        return Err(Error::parse(format!("line {lineno}: unterminated quote")));
    }
    fields.push(cur);
    Ok(fields.into_iter().map(|f| f.trim().to_string()).collect())
}

/// Parse CSV text into a [`Dataset`] (last column = class).
pub fn parse(name: &str, text: &str) -> Result<Dataset> {
    let mut lines = text
        .lines()
        .enumerate()
        .map(|(i, l)| (i + 1, l.trim()))
        .filter(|(_, l)| !l.is_empty() && !l.starts_with('#'));
    let (hline, header) = lines
        .next()
        .ok_or_else(|| Error::parse("empty CSV document"))?;
    let header = split_record(header, hline)?;
    if header.len() < 2 {
        return Err(Error::parse("CSV needs at least one feature and a class column"));
    }
    let ncols = header.len();
    let mut records: Vec<Vec<String>> = Vec::new();
    for (lineno, line) in lines {
        let rec = split_record(line, lineno)?;
        if rec.len() != ncols {
            return Err(Error::parse(format!(
                "line {lineno}: expected {ncols} fields, found {}",
                rec.len()
            )));
        }
        records.push(rec);
    }
    if records.is_empty() {
        return Err(Error::parse("CSV has a header but no data rows"));
    }

    let nf = ncols - 1;
    // Infer column kinds.
    let mut numeric = vec![true; nf];
    for rec in &records {
        for (c, is_num) in numeric.iter_mut().enumerate() {
            if *is_num && rec[c].parse::<f32>().is_err() {
                *is_num = false;
            }
        }
    }
    // Value dictionaries for categorical columns, classes for the last.
    let mut dicts: Vec<Vec<String>> = vec![Vec::new(); nf];
    let mut classes: Vec<String> = Vec::new();
    for rec in &records {
        for c in 0..nf {
            if !numeric[c] && !dicts[c].contains(&rec[c]) {
                dicts[c].push(rec[c].clone());
            }
        }
        if !classes.contains(&rec[nf]) {
            classes.push(rec[nf].clone());
        }
    }

    let features = (0..nf)
        .map(|c| Feature {
            name: header[c].clone(),
            kind: if numeric[c] {
                FeatureKind::Numeric
            } else {
                FeatureKind::Categorical {
                    values: dicts[c].clone(),
                }
            },
        })
        .collect();
    let schema = Schema {
        features,
        classes,
        task: super::Task::Classification,
    };

    let mut cells = Vec::with_capacity(records.len() * nf);
    let mut labels = Vec::with_capacity(records.len());
    for rec in &records {
        for c in 0..nf {
            if numeric[c] {
                cells.push(rec[c].parse::<f32>().unwrap());
            } else {
                cells.push(dicts[c].iter().position(|v| *v == rec[c]).unwrap() as f32);
            }
        }
        labels.push(schema.class_index(&rec[nf]).unwrap() as u32);
    }
    Dataset::new(name, schema, cells, labels)
}

/// Load a CSV file.
pub fn load_file(path: &str) -> Result<Dataset> {
    let text = std::fs::read_to_string(path)?;
    let name = std::path::Path::new(path)
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("csv")
        .to_string();
    parse(&name, &text)
}

/// Render a dataset back to CSV text (categorical codes as names).
pub fn to_csv(ds: &Dataset) -> String {
    let esc = |c: &str| {
        if c.contains([',', '"', '\n']) {
            format!("\"{}\"", c.replace('"', "\"\""))
        } else {
            c.to_string()
        }
    };
    let mut out = String::new();
    let headers: Vec<String> = ds
        .schema
        .features
        .iter()
        .map(|f| esc(&f.name))
        .chain(std::iter::once("class".to_string()))
        .collect();
    out.push_str(&headers.join(","));
    out.push('\n');
    for i in 0..ds.n_rows() {
        let mut row: Vec<String> = ds
            .row(i)
            .iter()
            .enumerate()
            .map(|(f, &v)| esc(&ds.schema.render_value(f, v)))
            .collect();
        row.push(esc(&ds.schema.classes[ds.label(i) as usize]));
        out.push_str(&row.join(","));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
sepal,petal,color,species
5.1,1.4,red,setosa
7.0,4.7,green,versicolor
6.3,6.0,red,virginica
5.0,1.5,\"blue,ish\",setosa
";

    #[test]
    fn parse_infers_kinds() {
        let ds = parse("sample", SAMPLE).unwrap();
        assert_eq!(ds.n_rows(), 4);
        assert_eq!(ds.n_features(), 3);
        assert_eq!(ds.schema.features[0].kind, FeatureKind::Numeric);
        assert!(matches!(
            ds.schema.features[2].kind,
            FeatureKind::Categorical { .. }
        ));
        assert_eq!(ds.schema.classes, vec!["setosa", "versicolor", "virginica"]);
        assert_eq!(ds.label(1), 1);
        assert_eq!(ds.row(0)[0], 5.1);
        // quoted value with comma became code 2
        assert_eq!(ds.row(3)[2], 2.0);
    }

    #[test]
    fn roundtrip() {
        let ds = parse("sample", SAMPLE).unwrap();
        let text = to_csv(&ds);
        let ds2 = parse("sample", &text).unwrap();
        assert_eq!(ds2.n_rows(), ds.n_rows());
        for i in 0..ds.n_rows() {
            assert_eq!(ds.row(i), ds2.row(i));
            assert_eq!(ds.label(i), ds2.label(i));
        }
    }

    #[test]
    fn rejects_ragged_rows() {
        let err = parse("bad", "a,b,c\n1,2\n").unwrap_err();
        assert!(err.to_string().contains("expected 3 fields"));
    }

    #[test]
    fn rejects_empty() {
        assert!(parse("bad", "").is_err());
        assert!(parse("bad", "a,class\n").is_err());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let ds = parse("s", "# c\n\na,class\n1,x\n\n# end\n2,y\n").unwrap();
        assert_eq!(ds.n_rows(), 2);
    }

    #[test]
    fn unterminated_quote_rejected() {
        assert!(parse("bad", "a,class\n\"oops,x\n").is_err());
    }
}
