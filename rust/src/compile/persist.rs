//! Persistence for compiled decision diagrams.
//!
//! A `CompiledDD` is the deployable artifact of this system — serialising
//! it lets the serving fleet load pre-compiled diagrams instead of paying
//! aggregation cost at startup (`forest-add compile --out dd.json`, then
//! load on each replica). The format stores the predicate pool (the
//! variable order), the node arena of the live cone, the terminals of the
//! concrete abstraction, and the schema.

use super::{Abstraction, CompiledDD, CompileStats, Model};
use crate::add::{ClassLabel, ClassVector, ClassWord, Manager, NodeId, Terminal};
use crate::data::{Feature, FeatureKind, Schema};
use crate::error::{Error, Result};
use crate::predicate::{Domain, Predicate, PredicatePool};
use crate::util::json::{self, Json};
use std::sync::Arc;

impl CompiledDD {
    /// Serialise to JSON (pool + cone + terminals + schema).
    pub fn to_persist_json(&self) -> Json {
        let (abstraction, mgr_json) = match &self.model {
            Model::Word { mgr, root } => (
                "word",
                cone_json(mgr, *root, &|w: &ClassWord| {
                    Json::Arr(w.0.iter().map(|&c| json::num(c as f64)).collect())
                }),
            ),
            Model::Vector { mgr, root } => (
                "vector",
                cone_json(mgr, *root, &|v: &ClassVector| {
                    Json::Arr(v.0.iter().map(|&c| json::num(c as f64)).collect())
                }),
            ),
            Model::Majority { mgr, root } => (
                "majority",
                cone_json(mgr, *root, &|c: &ClassLabel| json::num(*c as f64)),
            ),
        };
        let pool = self.pool_json();
        json::obj(vec![
            ("format", json::s("forest-add/dd-v1")),
            ("abstraction", json::s(abstraction)),
            ("unsat_elim", Json::Bool(self.unsat_elim)),
            ("schema", schema_json(&self.schema)),
            ("pool", pool),
            ("diagram", mgr_json),
        ])
    }

    fn pool_json(&self) -> Json {
        let pool = match &self.model {
            Model::Word { mgr, .. } => mgr.pool().clone(),
            Model::Vector { mgr, .. } => mgr.pool().clone(),
            Model::Majority { mgr, .. } => mgr.pool().clone(),
        };
        let preds: Vec<Json> = (0..pool.len() as u32)
            .map(|l| {
                let p = pool.pred(l);
                json::obj(vec![
                    ("f", json::num(p.feature as f64)),
                    ("t", json::num(p.threshold as f64)),
                ])
            })
            .collect();
        Json::Arr(preds)
    }

    /// Save to a file.
    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_persist_json().to_string_compact())?;
        Ok(())
    }

    /// Deserialise a diagram saved by [`save`](Self::save).
    pub fn load_from_json(v: &Json) -> Result<CompiledDD> {
        if v.get_str("format") != Some("forest-add/dd-v1") {
            return Err(Error::parse("not a forest-add dd-v1 document"));
        }
        let schema = schema_from_json(
            v.get("schema")
                .ok_or_else(|| Error::parse("dd: missing schema"))?,
        )?;
        let preds = v
            .get("pool")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::parse("dd: missing pool"))?
            .iter()
            .map(|p| {
                Ok(Predicate {
                    feature: p.get_i64("f").ok_or_else(|| Error::parse("pred: f"))? as u32,
                    threshold: p
                        .get("t")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| Error::parse("pred: t"))? as f32,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let domains: Vec<Domain> = schema
            .features
            .iter()
            .map(|f| match &f.kind {
                FeatureKind::Numeric => Domain::Real,
                FeatureKind::Categorical { values } => Domain::Grid {
                    cardinality: values.len() as u32,
                },
            })
            .collect();
        let n_features = schema.n_features();
        let pool = Arc::new(PredicatePool::from_predicates(preds, domains, n_features));
        let unsat_elim = v.get("unsat_elim").and_then(Json::as_bool).unwrap_or(true);
        let diagram = v
            .get("diagram")
            .ok_or_else(|| Error::parse("dd: missing diagram"))?;
        let n_classes = schema.n_classes();
        let model = match v.get_str("abstraction") {
            Some("word") => {
                let (mgr, root) = cone_from_json(pool, diagram, &|t| {
                    let codes = t.as_arr().ok_or_else(|| Error::parse("word terminal"))?;
                    Ok(ClassWord(
                        codes
                            .iter()
                            .map(|c| c.as_i64().map(|v| v as u16))
                            .collect::<Option<_>>()
                            .ok_or_else(|| Error::parse("word symbol"))?,
                    ))
                })?;
                Model::Word { mgr, root }
            }
            Some("vector") => {
                let (mgr, root) = cone_from_json(pool, diagram, &|t| {
                    let counts = t.as_arr().ok_or_else(|| Error::parse("vector terminal"))?;
                    if counts.len() != n_classes {
                        return Err(Error::parse("vector terminal arity"));
                    }
                    Ok(ClassVector(
                        counts
                            .iter()
                            .map(|c| c.as_i64().map(|v| v as u32))
                            .collect::<Option<_>>()
                            .ok_or_else(|| Error::parse("vector count"))?,
                    ))
                })?;
                Model::Vector { mgr, root }
            }
            Some("majority") => {
                let (mgr, root) = cone_from_json(pool, diagram, &|t| {
                    t.as_i64()
                        .map(|v| v as ClassLabel)
                        .ok_or_else(|| Error::parse("label terminal"))
                })?;
                Model::Majority { mgr, root }
            }
            other => return Err(Error::parse(format!("unknown abstraction {other:?}"))),
        };
        Ok(CompiledDD {
            model,
            schema,
            unsat_elim,
            stats: CompileStats::default(),
        })
    }

    /// Load from a file.
    pub fn load(path: &str) -> Result<CompiledDD> {
        let text = std::fs::read_to_string(path)?;
        Self::load_from_json(&Json::parse(&text)?)
    }
}

/// Topologically serialise a cone: nodes listed children-first, the root
/// last; ids are indices into the combined `[terminals..., nodes...]` list.
fn cone_json<T: Terminal>(mgr: &Manager<T>, root: NodeId, term: &impl Fn(&T) -> Json) -> Json {
    let mut order: Vec<NodeId> = Vec::new();
    let mut index: std::collections::HashMap<NodeId, usize> = std::collections::HashMap::new();
    // iterative post-order
    let mut stack = vec![(root, false)];
    while let Some((id, expanded)) = stack.pop() {
        if index.contains_key(&id) {
            continue;
        }
        if id.is_terminal() || expanded {
            index.insert(id, order.len());
            order.push(id);
        } else {
            let n = mgr.internal(id);
            stack.push((id, true));
            stack.push((n.hi, false));
            stack.push((n.lo, false));
        }
    }
    let nodes: Vec<Json> = order
        .iter()
        .map(|&id| {
            if id.is_terminal() {
                json::obj(vec![("v", term(mgr.terminal_value(id)))])
            } else {
                let n = mgr.internal(id);
                json::obj(vec![
                    ("l", json::num(n.level as f64)),
                    ("h", json::num(index[&n.hi] as f64)),
                    ("o", json::num(index[&n.lo] as f64)),
                ])
            }
        })
        .collect();
    json::obj(vec![
        ("nodes", Json::Arr(nodes)),
        ("root", json::num((order.len() - 1) as f64)),
    ])
}

fn cone_from_json<T: Terminal>(
    pool: Arc<PredicatePool>,
    v: &Json,
    term: &impl Fn(&Json) -> Result<T>,
) -> Result<(Manager<T>, NodeId)> {
    let nodes = v
        .get("nodes")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::parse("diagram: missing nodes"))?;
    let root_idx = v
        .get_i64("root")
        .ok_or_else(|| Error::parse("diagram: missing root"))? as usize;
    let mut mgr = Manager::new(pool);
    let mut ids: Vec<NodeId> = Vec::with_capacity(nodes.len());
    for n in nodes {
        if let Some(t) = n.get("v") {
            ids.push(mgr.terminal(term(t)?));
        } else {
            let level = n.get_i64("l").ok_or_else(|| Error::parse("node: l"))? as u32;
            let hi = *ids
                .get(n.get_i64("h").ok_or_else(|| Error::parse("node: h"))? as usize)
                .ok_or_else(|| Error::parse("node: forward reference"))?;
            let lo = *ids
                .get(n.get_i64("o").ok_or_else(|| Error::parse("node: o"))? as usize)
                .ok_or_else(|| Error::parse("node: forward reference"))?;
            if level as usize >= mgr.pool().len() {
                return Err(Error::parse("node: level out of range"));
            }
            ids.push(mgr.mk(level, hi, lo));
        }
    }
    let root = *ids
        .get(root_idx)
        .ok_or_else(|| Error::parse("diagram: root out of range"))?;
    Ok((mgr, root))
}

fn schema_json(s: &Schema) -> Json {
    let mut fields = vec![
        (
            "classes",
            Json::Arr(s.classes.iter().map(|c| json::s(c.clone())).collect()),
        ),
        (
            "features",
            Json::Arr(
                s.features
                    .iter()
                    .map(|f| {
                        let kind = match &f.kind {
                            FeatureKind::Numeric => json::s("numeric"),
                            FeatureKind::Categorical { values } => Json::Arr(
                                values.iter().map(|v| json::s(v.clone())).collect(),
                            ),
                        };
                        json::obj(vec![("name", json::s(f.name.clone())), ("kind", kind)])
                    })
                    .collect(),
            ),
        ),
    ];
    // Regression forests carry the per-bin value table; classification
    // documents omit the field, keeping existing artifacts unchanged.
    if let Some(values) = s.values() {
        fields.push((
            "values",
            Json::Arr(values.iter().map(|&v| json::num(v as f64)).collect()),
        ));
    }
    json::obj(fields)
}

fn schema_from_json(v: &Json) -> Result<Schema> {
    let classes = v
        .get("classes")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::parse("schema: classes"))?
        .iter()
        .map(|c| c.as_str().map(String::from))
        .collect::<Option<_>>()
        .ok_or_else(|| Error::parse("schema: class label"))?;
    let features = v
        .get("features")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::parse("schema: features"))?
        .iter()
        .map(|f| {
            let name = f
                .get_str("name")
                .ok_or_else(|| Error::parse("feature: name"))?
                .to_string();
            let kind = match f.get("kind") {
                Some(Json::Str(s)) if s == "numeric" => FeatureKind::Numeric,
                Some(Json::Arr(vals)) => FeatureKind::Categorical {
                    values: vals
                        .iter()
                        .map(|v| v.as_str().map(String::from))
                        .collect::<Option<_>>()
                        .ok_or_else(|| Error::parse("feature: value"))?,
                },
                _ => return Err(Error::parse("feature: kind")),
            };
            Ok(Feature { name, kind })
        })
        .collect::<Result<Vec<_>>>()?;
    let task = match v.get("values").and_then(Json::as_arr) {
        Some(arr) => crate::data::Task::Regression {
            values: arr
                .iter()
                .map(|x| x.as_f64().map(|v| v as f32))
                .collect::<Option<_>>()
                .ok_or_else(|| Error::parse("schema: regression value"))?,
        },
        None => crate::data::Task::Classification,
    };
    let schema = Schema {
        features,
        classes,
        task,
    };
    schema
        .validate_task()
        .map_err(|e| Error::parse(format!("schema: {e}")))?;
    Ok(schema)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compile::{CompileOptions, ForestCompiler};
    use crate::data::datasets;
    use crate::forest::ForestLearner;

    fn roundtrip(abstraction: Abstraction) {
        let ds = datasets::lenses();
        let forest = ForestLearner::default().trees(12).seed(4).fit(&ds);
        let dd = ForestCompiler::new(CompileOptions {
            abstraction,
            ..Default::default()
        })
        .compile(&forest)
        .unwrap();
        let text = dd.to_persist_json().to_string_compact();
        let back = CompiledDD::load_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.abstraction(), abstraction);
        assert_eq!(back.size(), dd.size());
        for i in 0..ds.n_rows() {
            assert_eq!(
                back.classify_with_steps(ds.row(i)),
                dd.classify_with_steps(ds.row(i)),
                "row {i}"
            );
        }
    }

    #[test]
    fn roundtrips_all_abstractions() {
        roundtrip(Abstraction::Majority);
        roundtrip(Abstraction::Vector);
        roundtrip(Abstraction::Word);
    }

    #[test]
    fn roundtrips_numeric_dataset() {
        let ds = datasets::iris();
        let forest = ForestLearner::default().trees(8).seed(1).fit(&ds);
        let dd = ForestCompiler::new(CompileOptions::default())
            .compile(&forest)
            .unwrap();
        let back =
            CompiledDD::load_from_json(&Json::parse(&dd.to_persist_json().to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(back.agreement(&forest, &ds), 1.0);
        assert_eq!(back.schema, dd.schema);
    }

    #[test]
    fn file_save_load() {
        let ds = datasets::balance_scale();
        let forest = ForestLearner::default().trees(6).seed(2).fit(&ds);
        let dd = ForestCompiler::new(CompileOptions::default())
            .compile(&forest)
            .unwrap();
        let path = std::env::temp_dir().join(format!("dd-persist-{}.json", std::process::id()));
        dd.save(path.to_str().unwrap()).unwrap();
        let back = CompiledDD::load(path.to_str().unwrap()).unwrap();
        assert_eq!(back.agreement(&forest, &ds), 1.0);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn regression_value_table_roundtrips() {
        let ds = crate::data::synth::regression(&crate::data::synth::RegressionSpec {
            rows: 150,
            bins: 6,
            ..Default::default()
        })
        .unwrap();
        let forest = ForestLearner::default().trees(7).seed(9).fit(&ds);
        let dd = ForestCompiler::new(CompileOptions {
            abstraction: Abstraction::Vector,
            ..Default::default()
        })
        .compile(&forest)
        .unwrap();
        let text = dd.to_persist_json().to_string_compact();
        assert!(text.contains("\"values\""));
        let back = CompiledDD::load_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.schema, dd.schema, "task + value table survive");
        // classification documents never carry the field
        let cls = ForestCompiler::new(CompileOptions::default())
            .compile(&ForestLearner::default().trees(3).seed(1).fit(&datasets::lenses()))
            .unwrap();
        assert!(!cls.to_persist_json().to_string_compact().contains("\"values\""));
        // a value table whose arity disagrees with the classes is rejected
        let forged = text.replace("\"values\":[", "\"values\":[0.25,");
        assert!(CompiledDD::load_from_json(&Json::parse(&forged).unwrap()).is_err());
    }

    #[test]
    fn rejects_malformed_documents() {
        assert!(CompiledDD::load_from_json(&Json::parse("{}").unwrap()).is_err());
        let bad = r#"{"format":"forest-add/dd-v1","abstraction":"majority"}"#;
        assert!(CompiledDD::load_from_json(&Json::parse(bad).unwrap()).is_err());
        let wrong_fmt = r#"{"format":"v2"}"#;
        assert!(CompiledDD::load_from_json(&Json::parse(wrong_fmt).unwrap()).is_err());
    }
}
