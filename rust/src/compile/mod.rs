//! The forest→ADD compiler: the paper's full pipeline (§3–§5).
//!
//! A [`ForestCompiler`] aggregates a trained [`RandomForest`] into one
//! decision diagram under a chosen [`Abstraction`]:
//!
//! - [`Abstraction::Word`] — class-word ADD (§3): fully
//!   information-preserving; majority vote still costs `n` reads at runtime.
//! - [`Abstraction::Vector`] — class-vector ADD (§4.1): the coarsest
//!   compositional abstraction; `|C|` reads at runtime.
//! - [`Abstraction::Majority`] — majority-vote ADD (§4.2): the vector
//!   pipeline followed by the monadic `mv` at the very end (it is not
//!   compositional); zero aggregation reads at runtime.
//!
//! With [`CompileOptions::unsat_elim`], unsatisfiable-path elimination (§5)
//! runs every [`CompileOptions::reduce_every`] trees *during* aggregation —
//! the compositionality the paper highlights — and once more at the end.
//! This is what keeps intermediate diagrams small enough to scale to
//! 10,000-tree forests.
//!
//! Engineering safeguards not in the paper but required for a production
//! compiler: a node budget (clean [`Error::Capacity`] instead of OOM when a
//! non-`*` variant explodes — the paper's own Fig. 6/7 cut those series
//! off), and periodic arena compaction (hash-consed managers never free
//! nodes; long aggregations rebuild the live cone into a fresh manager).
//!
//! The compiled diagram is the *build-time* artifact; for serving,
//! [`CompiledDD::freeze`] (or [`ForestCompiler::compile_frozen`]) renders
//! it into the flat [`FrozenDD`](crate::frozen::FrozenDD) form with its
//! `fdd-v2` binary snapshot.

pub mod persist;

use crate::add::reduce::{reduce_feasible, FusedCombiner, Reducer};
use crate::add::{ClassLabel, ClassVector, ClassWord, Manager, Monoid, NodeId, SizeStats};
use crate::classifier::{BackendKind, Classifier, ClassifierInfo, CostModel};
use crate::data::{Dataset, Schema};
use crate::error::{Error, Result};
use crate::forest::RandomForest;
use crate::frozen::{builder::freeze_cone, FrozenDD, FrozenTerminals};
use crate::predicate::{PredicateOrder, PredicatePool};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Which co-domain the final diagram carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Abstraction {
    /// Class words `C*` (§3).
    Word,
    /// Class vectors `ℕ^|C|` (§4.1).
    Vector,
    /// Majority vote `C` (§4.2) — the paper's "Final DD".
    #[default]
    Majority,
}

impl Abstraction {
    /// Short name used in reports (the paper's series labels).
    pub fn label(&self, unsat: bool) -> String {
        let base = match self {
            Abstraction::Word => "Class word DD",
            Abstraction::Vector => "Class vector DD",
            Abstraction::Majority => "Most frequent class DD",
        };
        if unsat {
            format!("{base}*")
        } else {
            base.to_string()
        }
    }
}

/// Compiler configuration.
#[derive(Debug, Clone)]
pub struct CompileOptions {
    /// Target co-domain.
    pub abstraction: Abstraction,
    /// Enable unsatisfiable-path elimination (the `*` variants).
    pub unsat_elim: bool,
    /// Apply the reduction every `k` trees during aggregation (`0` = only
    /// at the very end). Ignored unless `unsat_elim`.
    pub reduce_every: usize,
    /// Predicate (variable) order heuristic.
    pub order: PredicateOrder,
    /// Live-node budget; exceeded ⇒ [`Error::Capacity`] (`0` = unlimited).
    pub node_budget: usize,
    /// Rebuild the manager when its arena exceeds this many internal nodes
    /// (`0` = never). Keeps long aggregations within memory bounds.
    pub gc_arena_threshold: usize,
    /// Wall-clock budget for the aggregation; exceeded ⇒ cutoff (sweeps
    /// keep the checkpoints already produced; `compile` returns
    /// [`Error::Capacity`]). `None` = unlimited.
    pub time_budget: Option<Duration>,
}

impl Default for CompileOptions {
    fn default() -> Self {
        CompileOptions {
            abstraction: Abstraction::Majority,
            unsat_elim: true,
            reduce_every: 1,
            // FrequencyDesc measured ~4x smaller diagrams, ~6x fewer steps
            // and faster compiles than (feature, threshold) order on every
            // evaluation dataset — see bench_results/ablation_order.md.
            order: PredicateOrder::FrequencyDesc,
            node_budget: 0,
            gc_arena_threshold: 1 << 21,
            time_budget: None,
        }
    }
}

/// Compilation statistics.
#[derive(Debug, Clone, Default)]
pub struct CompileStats {
    /// Trees aggregated.
    pub trees: usize,
    /// Distinct predicates (= ADD levels).
    pub predicates: usize,
    /// Reduction passes executed.
    pub reduces: usize,
    /// Manager compactions executed.
    pub gcs: usize,
    /// Peak live diagram size observed during aggregation.
    pub peak_live: usize,
    /// Final diagram size.
    pub final_size: SizeStats,
    /// Wall-clock compilation time.
    pub elapsed: Duration,
}

/// A compiled decision diagram, ready to classify.
#[derive(Debug)]
pub struct CompiledDD {
    model: Model,
    /// Schema of the training data (feature names, class labels).
    pub schema: Schema,
    /// Whether unsat elimination was applied.
    pub unsat_elim: bool,
    /// Compilation statistics.
    pub stats: CompileStats,
}

#[derive(Debug)]
enum Model {
    Word { mgr: Manager<ClassWord>, root: NodeId },
    Vector { mgr: Manager<ClassVector>, root: NodeId },
    Majority { mgr: Manager<ClassLabel>, root: NodeId },
}

impl CompiledDD {
    /// Which abstraction this diagram carries.
    pub fn abstraction(&self) -> Abstraction {
        match self.model {
            Model::Word { .. } => Abstraction::Word,
            Model::Vector { .. } => Abstraction::Vector,
            Model::Majority { .. } => Abstraction::Majority,
        }
    }

    /// Series label (paper style, e.g. `Most frequent class DD*`).
    pub fn label(&self) -> String {
        self.abstraction().label(self.unsat_elim)
    }

    /// Number of classes.
    pub fn n_classes(&self) -> usize {
        self.schema.n_classes()
    }

    /// Classify one row (majority vote semantics in every abstraction).
    pub fn classify(&self, x: &[f32]) -> u32 {
        self.classify_with_steps(x).0
    }

    /// Classify with the §6 step metric: decision nodes traversed, plus the
    /// runtime aggregation reads the abstraction still requires (`n` for
    /// words, `|C|` for vectors, `0` after the majority abstraction).
    pub fn classify_with_steps(&self, x: &[f32]) -> (u32, usize) {
        match &self.model {
            Model::Word { mgr, root } => {
                let (w, steps) = mgr.eval(*root, x);
                (w.majority(self.schema.n_classes()) as u32, steps + w.len())
            }
            Model::Vector { mgr, root } => {
                let (v, steps) = mgr.eval(*root, x);
                (v.majority() as u32, steps + self.schema.n_classes())
            }
            Model::Majority { mgr, root } => {
                let (c, steps) = mgr.eval(*root, x);
                (*c as u32, steps)
            }
        }
    }

    /// Per-class vote counts for one row — the terminal payload before
    /// any decision rule. Word diagrams recover it by counting the class
    /// word (§4.1's `W → V` homomorphism), vector diagrams carry it
    /// directly; the majority abstraction (§4.2) has already collapsed
    /// the distribution to one label, so it refuses rather than guess.
    pub fn votes(&self, x: &[f32]) -> Result<Vec<u32>> {
        match &self.model {
            Model::Word { mgr, root } => {
                let (w, _) = mgr.eval(*root, x);
                Ok(w.to_vector(self.schema.n_classes()).0)
            }
            Model::Vector { mgr, root } => {
                let (v, _) = mgr.eval(*root, x);
                Ok(v.0.clone())
            }
            Model::Majority { .. } => Err(Error::invalid(
                "majority-abstracted diagram has discarded vote distributions \
                 (compile with a word or vector abstraction to keep them)",
            )),
        }
    }

    /// Diagram size (Fig. 7 / Table 2 measure).
    pub fn size(&self) -> SizeStats {
        match &self.model {
            Model::Word { mgr, root } => mgr.size(*root),
            Model::Vector { mgr, root } => mgr.size(*root),
            Model::Majority { mgr, root } => mgr.size(*root),
        }
    }

    /// Mean §6 step count over a dataset. Delegates to
    /// [`crate::classifier::mean_steps`] — the single implementation of
    /// the §6 accounting.
    pub fn mean_steps(&self, data: &Dataset) -> f64 {
        crate::classifier::mean_steps(self, data)
            .expect("diagram evaluation is infallible")
            .expect("diagram steps are always meterable")
    }

    /// Accuracy against dataset labels.
    pub fn accuracy(&self, data: &Dataset) -> f64 {
        crate::classifier::accuracy(self, data).expect("diagram evaluation is infallible")
    }

    /// Fraction of rows where this diagram and `forest` agree — the
    /// semantics-preservation check (must be 1.0).
    pub fn agreement(&self, forest: &RandomForest, data: &Dataset) -> f64 {
        crate::classifier::agreement(self, forest, data)
            .expect("native evaluation is infallible")
    }

    /// Aggregation reads the abstraction still pays per classification at
    /// runtime: `n` for class words, `|C|` for class vectors, `0` after
    /// the majority abstraction (§3–§4).
    pub fn aggregation_reads(&self) -> usize {
        match self.abstraction() {
            Abstraction::Word => self.stats.trees,
            Abstraction::Vector => self.schema.n_classes(),
            Abstraction::Majority => 0,
        }
    }

    /// Flatten into the immutable struct-of-arrays serving form.
    ///
    /// The [`FrozenDD`] carries the same diagram — identical predictions
    /// and §6 step counts on every row — but stores it as topologically
    /// ordered node arrays with inlined predicates and terminals, evaluates
    /// without touching the arena, and serialises to the `fdd-v2` binary
    /// snapshot ([`FrozenDD::save`]) that replicas load with a single
    /// contiguous read.
    pub fn freeze(&self) -> FrozenDD {
        let trees = self.stats.trees;
        let n_classes = self.schema.n_classes();
        match &self.model {
            Model::Word { mgr, root } => freeze_cone(
                mgr,
                *root,
                &self.schema,
                Abstraction::Word,
                self.unsat_elim,
                trees,
                FrozenTerminals::empty_word(),
                &mut |w: &ClassWord, t| t.push_word(&w.0),
            ),
            Model::Vector { mgr, root } => freeze_cone(
                mgr,
                *root,
                &self.schema,
                Abstraction::Vector,
                self.unsat_elim,
                trees,
                FrozenTerminals::empty_vector(n_classes),
                &mut |v: &ClassVector, t| t.push_vector(&v.0),
            ),
            Model::Majority { mgr, root } => freeze_cone(
                mgr,
                *root,
                &self.schema,
                Abstraction::Majority,
                self.unsat_elim,
                trees,
                FrozenTerminals::empty_majority(),
                &mut |c: &ClassLabel, t| t.push_class(*c),
            ),
        }
        .expect("freezing a live diagram yields a structurally valid FrozenDD")
    }

    /// [`CompiledDD::freeze`] plus the optional layout transforms:
    /// feature-column packing and/or f16 threshold quantisation (the
    /// `freeze --pack-features` / `--quantize-f16` flags). Falls back to
    /// an error — never a silently different diagram — when a transform
    /// cannot preserve bit-identical predictions.
    pub fn freeze_with(&self, opts: crate::frozen::FreezeOpts) -> Result<FrozenDD> {
        self.freeze().apply_freeze_opts(opts)
    }

    /// Graphviz rendering (Figs. 2–5 style).
    pub fn to_dot(&self) -> String {
        let classes = &self.schema.classes;
        match &self.model {
            Model::Word { mgr, root } => crate::add::dot::to_dot(mgr, *root, &self.schema, &|w| {
                w.0.iter()
                    .map(|&c| classes[c as usize].chars().next().unwrap_or('?').to_string())
                    .collect::<Vec<_>>()
                    .join("")
            }),
            Model::Vector { mgr, root } => {
                crate::add::dot::to_dot(mgr, *root, &self.schema, &|v| format!("{:?}", v.0))
            }
            Model::Majority { mgr, root } => {
                crate::add::dot::to_dot(mgr, *root, &self.schema, &|c| {
                    classes[*c as usize].clone()
                })
            }
        }
    }
}

/// The paper's backend: one root-to-terminal walk through the compiled
/// diagram, identical in all three [`Abstraction`] variants up to the
/// aggregation reads still paid at runtime.
impl Classifier for CompiledDD {
    fn info(&self) -> ClassifierInfo {
        let size = self.size();
        ClassifierInfo {
            backend: BackendKind::Dd,
            label: self.label(),
            n_features: self.schema.n_features(),
            n_classes: self.n_classes(),
            size_nodes: size.total(),
            cost: CostModel {
                // One decision per distinct predicate level at most, plus
                // the abstraction's runtime aggregation reads.
                max_steps: Some(self.stats.predicates + self.aggregation_reads()),
                aggregation_reads: self.aggregation_reads(),
                preferred_batch: 1,
            },
        }
    }

    fn classify_with_steps(&self, x: &[f32]) -> Result<(u32, Option<usize>)> {
        let (class, steps) = CompiledDD::classify_with_steps(self, x);
        Ok((class, Some(steps)))
    }

    fn votes(&self, x: &[f32]) -> Result<Vec<u32>> {
        CompiledDD::votes(self, x)
    }

    fn task_values(&self) -> Option<Vec<f32>> {
        self.schema.values().map(<[f32]>::to_vec)
    }
}

/// Outcome of a [`ForestCompiler::sweep`].
#[derive(Debug, Clone)]
pub struct SweepOutcome {
    /// Checkpoints that produced a snapshot.
    pub completed: Vec<usize>,
    /// `(checkpoint, reason)` when the sweep stopped early (node budget).
    pub cutoff: Option<(usize, String)>,
}

/// The forest→DD compiler.
#[derive(Debug, Clone, Default)]
pub struct ForestCompiler {
    opts: CompileOptions,
}

impl ForestCompiler {
    /// Compiler with the given options.
    pub fn new(opts: CompileOptions) -> Self {
        ForestCompiler { opts }
    }

    /// Compile an entire forest.
    pub fn compile(&self, forest: &RandomForest) -> Result<CompiledDD> {
        let mut out = None;
        let outcome = self.run(forest, &[forest.n_trees()], &mut |_, dd| out = Some(dd))?;
        if let Some((at, reason)) = outcome.cutoff {
            return Err(Error::Capacity(format!(
                "node budget exceeded after {at} trees: {reason}"
            )));
        }
        Ok(out.expect("sweep must produce the final checkpoint"))
    }

    /// Compile an entire forest straight to the frozen serving form
    /// (`compile` + [`CompiledDD::freeze`]) — the artifact-build path
    /// behind `forest-add freeze` and `compile --format fdd`.
    pub fn compile_frozen(&self, forest: &RandomForest) -> Result<FrozenDD> {
        Ok(self.compile(forest)?.freeze())
    }

    /// Aggregate incrementally, producing an independent [`CompiledDD`]
    /// snapshot at every checkpoint (ascending tree counts). Used by the
    /// Fig. 6/7 sweeps; on node-budget exhaustion the sweep stops and
    /// reports the cutoff instead of failing (the paper's truncated series).
    pub fn sweep(
        &self,
        forest: &RandomForest,
        checkpoints: &[usize],
        f: &mut dyn FnMut(usize, CompiledDD),
    ) -> Result<SweepOutcome> {
        self.run(forest, checkpoints, f)
    }

    fn run(
        &self,
        forest: &RandomForest,
        checkpoints: &[usize],
        emit: &mut dyn FnMut(usize, CompiledDD),
    ) -> Result<SweepOutcome> {
        if forest.n_trees() == 0 {
            return Err(Error::invalid("cannot compile an empty forest"));
        }
        for w in checkpoints.windows(2) {
            if w[0] >= w[1] {
                return Err(Error::invalid("checkpoints must be strictly ascending"));
            }
        }
        if *checkpoints.last().unwrap_or(&0) > forest.n_trees() {
            return Err(Error::invalid(format!(
                "checkpoint beyond forest size {}",
                forest.n_trees()
            )));
        }
        let pool = Arc::new(PredicatePool::from_forest(forest, self.opts.order));
        let n_classes = forest.n_classes();
        match self.opts.abstraction {
            Abstraction::Word => self.aggregate::<ClassWord>(
                forest,
                pool,
                ClassWord::empty(),
                &|c| ClassWord::singleton(c as u16),
                checkpoints,
                &mut |mgr, root, stats| {
                    let (mgr, root) = mgr.rebuild(root);
                    CompiledDD {
                        model: Model::Word { mgr, root },
                        schema: forest.schema.clone(),
                        unsat_elim: self.opts.unsat_elim,
                        stats,
                    }
                },
                emit,
            ),
            Abstraction::Vector => self.aggregate::<ClassVector>(
                forest,
                pool,
                ClassVector::zero(n_classes),
                &|c| ClassVector::unit(c as u16, n_classes),
                checkpoints,
                &mut |mgr, root, stats| {
                    let (mgr, root) = mgr.rebuild(root);
                    CompiledDD {
                        model: Model::Vector { mgr, root },
                        schema: forest.schema.clone(),
                        unsat_elim: self.opts.unsat_elim,
                        stats,
                    }
                },
                emit,
            ),
            Abstraction::Majority => {
                let unsat = self.opts.unsat_elim;
                self.aggregate::<ClassVector>(
                    forest,
                    pool,
                    ClassVector::zero(n_classes),
                    &|c| ClassVector::unit(c as u16, n_classes),
                    checkpoints,
                    &mut |mgr, root, mut stats| {
                        // The non-compositional step (§4.2): mv at the end.
                        let mut label_mgr: Manager<ClassLabel> =
                            Manager::new(mgr.pool().clone());
                        let mut mapped = mgr.map_into(&mut label_mgr, root, &|v| v.majority());
                        if unsat {
                            // mv merges terminals, exposing fresh entailed
                            // decisions — reduce once more (§5 ordering).
                            mapped = reduce_feasible(&mut label_mgr, mapped);
                            stats.reduces += 1;
                        }
                        let (label_mgr, mapped) = label_mgr.rebuild(mapped);
                        stats.final_size = label_mgr.size(mapped);
                        CompiledDD {
                            model: Model::Majority {
                                mgr: label_mgr,
                                root: mapped,
                            },
                            schema: forest.schema.clone(),
                            unsat_elim: unsat,
                            stats,
                        }
                    },
                    emit,
                )
            }
        }
    }

    /// Shared incremental aggregation loop over a monoid co-domain.
    #[allow(clippy::too_many_arguments)]
    fn aggregate<T: Monoid>(
        &self,
        forest: &RandomForest,
        pool: Arc<PredicatePool>,
        empty: T,
        inject: &dyn Fn(u32) -> T,
        checkpoints: &[usize],
        snapshot: &mut dyn FnMut(&Manager<T>, NodeId, CompileStats) -> CompiledDD,
        emit: &mut dyn FnMut(usize, CompiledDD),
    ) -> Result<SweepOutcome> {
        let start = Instant::now();
        // The stats-trace flag is fixed for the process lifetime: read it
        // once per compile instead of hitting the environment (and its
        // lock) on every tree of the hot aggregation loop.
        let trace_stats = std::env::var("FOREST_ADD_COMPILE_STATS").is_ok();
        let mut mgr: Manager<T> = Manager::new(pool.clone());
        // Persistent reducer: after `combine`, the diagram shares almost all
        // structure with the previously reduced one, so keeping the memo
        // across trees makes the per-tree reduction incremental (§Perf).
        let mut reducer = Reducer::new(pool.clone());
        // At cadence 1 the combine+reduce pair is fused: entailed branches
        // are pruned while the product is built (see reduce::FusedCombiner).
        let mut fused = if self.opts.unsat_elim && self.opts.reduce_every == 1 {
            Some(FusedCombiner::new(pool.clone()))
        } else {
            None
        };
        let mut acc = mgr.terminal(empty);
        let mut stats = CompileStats {
            predicates: pool.len(),
            ..Default::default()
        };
        let mut outcome = SweepOutcome {
            completed: Vec::new(),
            cutoff: None,
        };
        let mut next_ckpt = 0usize;
        // checkpoint 0 = the empty forest's diagram (the ε terminal)
        while next_ckpt < checkpoints.len() && checkpoints[next_ckpt] == 0 {
            let mut s = stats.clone();
            s.elapsed = start.elapsed();
            s.final_size = mgr.size(acc);
            emit(0, snapshot(&mgr, acc, s));
            outcome.completed.push(0);
            next_ckpt += 1;
        }
        for (i, tree) in forest.trees.iter().enumerate() {
            if next_ckpt >= checkpoints.len() {
                break; // nothing left to produce
            }
            if let Some(tb) = self.opts.time_budget {
                if start.elapsed() > tb {
                    outcome.cutoff = Some((
                        i,
                        format!("time budget {tb:?} exhausted after {i} trees"),
                    ));
                    return Ok(outcome);
                }
            }
            let t = mgr.from_tree(tree, inject)?;
            stats.trees = i + 1;
            if let Some(fc) = fused.as_mut() {
                acc = fc.combine(&mut mgr, acc, t);
                stats.reduces += 1;
                // Product-memo entries cannot hit across trees (both the
                // accumulator and the tree operand change); dropping them
                // keeps the table cache-resident.
                fc.clear_memo();
            } else {
                acc = mgr.combine(acc, t);
                if self.opts.unsat_elim
                    && self.opts.reduce_every > 0
                    && (i + 1) % self.opts.reduce_every == 0
                {
                    acc = reducer.reduce(&mut mgr, acc);
                    stats.reduces += 1;
                    if reducer.cache_len() > 6_000_000 {
                        reducer.clear();
                    }
                }
            }
            // The live-size DFS is only paid when a budget needs enforcing;
            // otherwise the arena high-water mark tracks the peak cheaply.
            if self.opts.node_budget > 0 {
                let live = mgr.size(acc);
                stats.peak_live = stats.peak_live.max(live.total());
                if live.total() > self.opts.node_budget {
                    outcome.cutoff = Some((
                        i + 1,
                        format!(
                            "live diagram has {} nodes (budget {})",
                            live.total(),
                            self.opts.node_budget
                        ),
                    ));
                    return Ok(outcome);
                }
            } else {
                stats.peak_live = stats.peak_live.max(mgr.arena_sizes().0);
            }
            if self.opts.gc_arena_threshold > 0
                && mgr.arena_sizes().0 > self.opts.gc_arena_threshold
            {
                let (m2, a2) = mgr.rebuild(acc);
                mgr = m2;
                acc = a2;
                stats.gcs += 1;
                // Node ids changed: all cached reduction results are stale.
                reducer.clear();
                if let Some(fc) = fused.as_mut() {
                    fc.clear();
                }
            }
            if trace_stats && (i + 1) % 25 == 0 {
                if let Some(fc) = fused.as_ref() {
                    crate::log_info!(
                        "[compile] tree {}: visits {} hits {} skips {} arena {}",
                        i + 1,
                        fc.visits,
                        fc.hits,
                        fc.skips,
                        mgr.arena_sizes().0
                    );
                }
            }
            if checkpoints[next_ckpt] == i + 1 {
                let mut fin = acc;
                // End-of-pipeline reduction for checkpoints that fall between
                // cadence points (and for reduce_every == 0).
                if self.opts.unsat_elim {
                    fin = reducer.reduce(&mut mgr, fin);
                    stats.reduces += 1;
                }
                let mut s = stats.clone();
                s.elapsed = start.elapsed();
                s.final_size = mgr.size(fin);
                emit(i + 1, snapshot(&mgr, fin, s));
                outcome.completed.push(i + 1);
                next_ckpt += 1;
            }
        }
        Ok(outcome)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;
    use crate::forest::ForestLearner;

    fn iris_forest(n: usize) -> (crate::data::Dataset, RandomForest) {
        let ds = datasets::iris();
        let f = ForestLearner::default().trees(n).seed(42).fit(&ds);
        (ds, f)
    }

    fn opts(a: Abstraction, unsat: bool) -> CompileOptions {
        CompileOptions {
            abstraction: a,
            unsat_elim: unsat,
            ..Default::default()
        }
    }

    #[test]
    fn all_variants_preserve_forest_semantics() {
        let (ds, forest) = iris_forest(10);
        for abstraction in [Abstraction::Word, Abstraction::Vector, Abstraction::Majority] {
            for unsat in [false, true] {
                let dd = ForestCompiler::new(opts(abstraction, unsat))
                    .compile(&forest)
                    .unwrap();
                assert_eq!(
                    dd.agreement(&forest, &ds),
                    1.0,
                    "{abstraction:?} unsat={unsat} changed semantics"
                );
            }
        }
    }

    #[test]
    fn word_dd_preserves_exact_words() {
        let (ds, forest) = iris_forest(7);
        let dd = ForestCompiler::new(opts(Abstraction::Word, true))
            .compile(&forest)
            .unwrap();
        // word steps include n reads
        let (_, steps) = dd.classify_with_steps(ds.row(0));
        assert!(steps >= 7);
        if let Model::Word { mgr, root } = &dd.model {
            for i in [0, 60, 120] {
                let x = ds.row(i);
                let (w, _) = mgr.eval(*root, x);
                let expect: Vec<u16> =
                    forest.trees.iter().map(|t| t.predict(x) as u16).collect();
                assert_eq!(w.0, expect, "row {i}: word must list per-tree decisions in order");
            }
        } else {
            panic!("expected word model");
        }
    }

    #[test]
    fn vector_dd_carries_exact_vote_counts() {
        let (ds, forest) = iris_forest(12);
        let dd = ForestCompiler::new(opts(Abstraction::Vector, true))
            .compile(&forest)
            .unwrap();
        if let Model::Vector { mgr, root } = &dd.model {
            for i in [3, 77, 140] {
                let x = ds.row(i);
                let (v, _) = mgr.eval(*root, x);
                let expect = forest.votes(x);
                assert_eq!(v.0, expect, "row {i}");
            }
        } else {
            panic!("expected vector model");
        }
    }

    #[test]
    fn votes_surface_matches_forest_where_retained() {
        let (ds, forest) = iris_forest(11);
        for abstraction in [Abstraction::Word, Abstraction::Vector] {
            let dd = ForestCompiler::new(opts(abstraction, true))
                .compile(&forest)
                .unwrap();
            for i in (0..ds.n_rows()).step_by(19) {
                assert_eq!(
                    dd.votes(ds.row(i)).unwrap(),
                    forest.votes(ds.row(i)),
                    "{abstraction:?} row {i}"
                );
            }
        }
        // the majority abstraction has discarded the distribution
        let mv = ForestCompiler::new(opts(Abstraction::Majority, true))
            .compile(&forest)
            .unwrap();
        assert!(mv.votes(ds.row(0)).is_err());
        // and the trait surface agrees with the inherent one
        let dd = ForestCompiler::new(opts(Abstraction::Vector, true))
            .compile(&forest)
            .unwrap();
        let c: &dyn Classifier = &dd;
        assert_eq!(c.votes(ds.row(5)).unwrap(), forest.votes(ds.row(5)));
    }

    #[test]
    fn unsat_elimination_shrinks_the_diagram() {
        let (_, forest) = iris_forest(12);
        let plain = ForestCompiler::new(CompileOptions {
            abstraction: Abstraction::Majority,
            unsat_elim: false,
            ..Default::default()
        })
        .compile(&forest)
        .unwrap();
        let star = ForestCompiler::new(CompileOptions {
            abstraction: Abstraction::Majority,
            unsat_elim: true,
            ..Default::default()
        })
        .compile(&forest)
        .unwrap();
        assert!(
            star.size().total() < plain.size().total(),
            "{} !< {}",
            star.size().total(),
            plain.size().total()
        );
    }

    #[test]
    fn majority_dd_steps_beat_forest_steps() {
        let (ds, forest) = iris_forest(60);
        let dd = ForestCompiler::new(opts(Abstraction::Majority, true))
            .compile(&forest)
            .unwrap();
        let dd_steps = dd.mean_steps(&ds);
        let rf_steps = forest.mean_steps(&ds);
        // At 60 trees the gap is already several-fold; it grows with n (the
        // orders-of-magnitude factors of Table 1 appear at thousands of
        // trees — regenerated by `cargo bench --bench table1_steps`).
        assert!(
            dd_steps * 3.0 < rf_steps,
            "DD* {dd_steps} not ≫ faster than RF {rf_steps}"
        );
        // DD* steps must be sublinear in n: far below one step per tree.
        assert!(dd_steps < 60.0, "DD* steps {dd_steps} not sublinear");
    }

    #[test]
    fn node_budget_cuts_off_cleanly() {
        let (_, forest) = iris_forest(40);
        let err = ForestCompiler::new(CompileOptions {
            abstraction: Abstraction::Word,
            unsat_elim: false,
            node_budget: 50,
            ..Default::default()
        })
        .compile(&forest)
        .unwrap_err();
        assert!(matches!(err, Error::Capacity(_)), "{err}");
    }

    #[test]
    fn sweep_checkpoints_match_individual_compiles() {
        let (ds, forest) = iris_forest(10);
        let compiler = ForestCompiler::new(opts(Abstraction::Majority, true));
        let mut snaps = Vec::new();
        let outcome = compiler
            .sweep(&forest, &[2, 5, 10], &mut |n, dd| snaps.push((n, dd)))
            .unwrap();
        assert_eq!(outcome.completed, vec![2, 5, 10]);
        assert!(outcome.cutoff.is_none());
        for (n, dd) in &snaps {
            let direct = compiler.compile(&forest.prefix(*n)).unwrap();
            for i in (0..ds.n_rows()).step_by(13) {
                assert_eq!(
                    dd.classify(ds.row(i)),
                    direct.classify(ds.row(i)),
                    "n={n} row={i}"
                );
            }
        }
    }

    #[test]
    fn sweep_cutoff_reports_partial_results() {
        let (_, forest) = iris_forest(30);
        let compiler = ForestCompiler::new(CompileOptions {
            abstraction: Abstraction::Word,
            unsat_elim: false,
            node_budget: 200,
            ..Default::default()
        });
        let mut seen = Vec::new();
        let outcome = compiler
            .sweep(&forest, &[1, 2, 30], &mut |n, _| seen.push(n))
            .unwrap();
        assert!(outcome.cutoff.is_some());
        assert_eq!(seen, outcome.completed);
        assert!(outcome.completed.len() < 3);
    }

    #[test]
    fn empty_forest_rejected_and_zero_checkpoint_works() {
        let (_, forest) = iris_forest(3);
        let compiler = ForestCompiler::new(opts(Abstraction::Vector, false));
        let mut sizes = Vec::new();
        compiler
            .sweep(&forest, &[0, 3], &mut |n, dd| sizes.push((n, dd.size().total())))
            .unwrap();
        assert_eq!(sizes[0].1, 1, "empty forest = single ε/0 terminal");
        let empty = RandomForest {
            trees: vec![],
            schema: forest.schema.clone(),
        };
        assert!(compiler.compile(&empty).is_err());
    }

    #[test]
    fn accuracy_matches_forest_accuracy() {
        let (ds, forest) = iris_forest(30);
        let dd = ForestCompiler::new(opts(Abstraction::Majority, true))
            .compile(&forest)
            .unwrap();
        assert!((dd.accuracy(&ds) - forest.accuracy(&ds)).abs() < 1e-12);
    }

    #[test]
    fn stats_are_populated() {
        let (_, forest) = iris_forest(8);
        let dd = ForestCompiler::new(opts(Abstraction::Majority, true))
            .compile(&forest)
            .unwrap();
        assert_eq!(dd.stats.trees, 8);
        assert!(dd.stats.predicates > 0);
        assert!(dd.stats.reduces >= 8);
        assert!(dd.stats.peak_live > 0);
        assert!(dd.stats.final_size.total() > 0);
        assert_eq!(dd.label(), "Most frequent class DD*");
    }

    #[test]
    fn classifier_trait_covers_all_abstractions() {
        let (ds, forest) = iris_forest(10);
        for (abstraction, reads) in [
            (Abstraction::Word, 10),
            (Abstraction::Vector, 3),
            (Abstraction::Majority, 0),
        ] {
            let dd = ForestCompiler::new(opts(abstraction, true))
                .compile(&forest)
                .unwrap();
            assert_eq!(dd.aggregation_reads(), reads, "{abstraction:?}");
            let info = Classifier::info(&dd);
            assert_eq!(info.backend, BackendKind::Dd);
            assert_eq!(info.label, dd.label());
            assert_eq!(info.size_nodes, dd.size().total());
            assert_eq!(info.cost.aggregation_reads, reads);
            let c: &dyn Classifier = &dd;
            for i in (0..ds.n_rows()).step_by(31) {
                let (class, steps) = c.classify_with_steps(ds.row(i)).unwrap();
                let (want_c, want_s) = dd.classify_with_steps(ds.row(i));
                assert_eq!((class, steps), (want_c, Some(want_s)));
            }
        }
    }

    #[test]
    fn compile_frozen_matches_compile_then_freeze() {
        let (ds, forest) = iris_forest(6);
        let compiler = ForestCompiler::new(opts(Abstraction::Majority, true));
        let frozen = compiler.compile_frozen(&forest).unwrap();
        let dd = compiler.compile(&forest).unwrap();
        assert_eq!(frozen.size(), dd.size());
        for i in (0..ds.n_rows()).step_by(17) {
            assert_eq!(
                frozen.classify_with_steps(ds.row(i)),
                dd.classify_with_steps(ds.row(i)),
                "row {i}"
            );
        }
    }

    #[test]
    fn dot_export_renders_class_names() {
        let (_, forest) = iris_forest(5);
        let dd = ForestCompiler::new(opts(Abstraction::Majority, true))
            .compile(&forest)
            .unwrap();
        let dot = dd.to_dot();
        assert!(dot.contains("digraph"));
        assert!(dot.contains("setosa") || dot.contains("versicolor") || dot.contains("virginica"));
    }
}
