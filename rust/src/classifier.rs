//! The backend-polymorphic classification API.
//!
//! The paper's whole point is that one compiled decision diagram is
//! *semantically equivalent* to the `n`-tree forest it came from. This
//! module makes that equivalence a first-class contract: every evaluator —
//! the naive forest walker, the compiled ADD in all three
//! [`Abstraction`](crate::compile::Abstraction) variants, and the XLA/PJRT
//! tensorised batch engine — implements the same [`Classifier`] trait, so
//! the serving router, the CLI, benches, and conformance tests dispatch
//! uniformly through trait objects instead of hard-coding a backend.
//!
//! The trait is **batch-first by default**: `classify_with_steps` is the
//! one required evaluation method, and `classify`/`classify_batch` come
//! for free, so a new backend (sharded DD, quantised forest, …) is a
//! drop-in impl. Batches travel as one borrowed flat
//! [`RowMatrix`](crate::batch::RowMatrix) — no per-row heap allocation
//! anywhere on the pipeline. Batch-native engines (XLA) override
//! `classify_batch` with their fused path and advertise it via
//! [`CostModel::preferred_batch`], which the router's dynamic batcher
//! uses to decide which traffic to coalesce; the forest and frozen
//! backends override it to shard large batches across the evaluation
//! worker pool ([`crate::runtime::pool`]).

use crate::batch::RowMatrix;
use crate::data::Dataset;
use crate::error::{Error, Result};

/// Which execution backend a classifier represents.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// Naive forest walk (baseline).
    Forest,
    /// Compiled decision diagram (the paper's system) in its live,
    /// arena-backed form.
    Dd,
    /// The same diagram flattened into the immutable struct-of-arrays
    /// serving form ([`FrozenDD`](crate::frozen::FrozenDD)) — identical
    /// predictions, cache-friendly walk, snapshot startup.
    Frozen,
    /// Batched XLA/PJRT tensorised evaluator.
    Xla,
}

impl BackendKind {
    /// Parse from a request/config string.
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s.to_ascii_lowercase().as_str() {
            "forest" | "rf" => Ok(BackendKind::Forest),
            "dd" | "add" | "diagram" => Ok(BackendKind::Dd),
            "frozen" | "fdd" => Ok(BackendKind::Frozen),
            "xla" | "pjrt" => Ok(BackendKind::Xla),
            other => Err(Error::invalid(format!(
                "unknown backend '{other}' (forest|dd|frozen|xla)"
            ))),
        }
    }

    /// Stable name for metrics/JSON.
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Forest => "forest",
            BackendKind::Dd => "dd",
            BackendKind::Frozen => "frozen",
            BackendKind::Xla => "xla",
        }
    }
}

/// Static cost model of a backend, in the paper's §6 units where they
/// apply. Lets callers reason about a backend without probing it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CostModel {
    /// Upper bound on §6 steps for one classification (`None` when the
    /// backend cannot meter steps, e.g. tensorised evaluation).
    pub max_steps: Option<usize>,
    /// Aggregation reads still paid at runtime per classification: `n`
    /// for class-word DDs and the forest vote, `|C|` for class-vector
    /// DDs, `0` after the majority abstraction.
    pub aggregation_reads: usize,
    /// Batch size at which the backend is most efficient (`1` =
    /// single-row evaluator; `>1` means the router should coalesce
    /// traffic through the dynamic batcher).
    pub preferred_batch: usize,
}

/// Metadata describing a classifier: backend kind, size statistics, and
/// cost model. Returned by [`Classifier::info`].
#[derive(Debug, Clone)]
pub struct ClassifierInfo {
    /// Execution backend.
    pub backend: BackendKind,
    /// Human-readable description (paper-style series label where one
    /// exists, e.g. `Most frequent class DD*`).
    pub label: String,
    /// Feature arity the classifier expects.
    pub n_features: usize,
    /// Number of classes it can emit.
    pub n_classes: usize,
    /// Structure size in nodes (Fig. 7 / Table 2 measure; `0` when the
    /// backend is not node-based).
    pub size_nodes: usize,
    /// Static cost model.
    pub cost: CostModel,
}

/// A classification backend: forest walker, compiled DD, or tensorised
/// engine — anything that maps a feature row to a class index with the
/// forest's majority-vote semantics.
///
/// `Send + Sync` is required: classifiers are shared across serving
/// threads as `Arc<dyn Classifier>` and hot-swapped through the
/// [`ModelRegistry`](crate::engine::ModelRegistry).
pub trait Classifier: Send + Sync {
    /// Backend metadata: kind, label, size stats, cost model.
    fn info(&self) -> ClassifierInfo;

    /// Classify one row, reporting the §6 step count when the backend can
    /// meter it. This is the one required evaluation method.
    fn classify_with_steps(&self, x: &[f32]) -> Result<(u32, Option<usize>)>;

    /// Classify one row.
    fn classify(&self, x: &[f32]) -> Result<u32> {
        Ok(self.classify_with_steps(x)?.0)
    }

    /// Classify a batch of rows (borrowed flat row-major matrix). The
    /// default loops `classify`, so every backend gets batched evaluation
    /// for free; batch-native engines override this with their fused
    /// and/or multi-core sharded path.
    fn classify_batch(&self, rows: RowMatrix<'_>) -> Result<Vec<u32>> {
        rows.iter().map(|r| self.classify(r)).collect()
    }

    /// Classify a batch reporting the §6 step count per row, so cost
    /// metering survives the batch path. Returns `None` steps when the
    /// backend cannot meter (decided on the first row; its classes then
    /// come from the native batch path). This default walks rows
    /// serially — metering is a diagnostic surface, and only backends
    /// whose batch pass can record steps natively (the frozen sweep)
    /// override it to keep sharding; unmetered requests should use
    /// [`Classifier::classify_batch`].
    fn classify_batch_with_steps(&self, rows: RowMatrix<'_>) -> Result<(Vec<u32>, Option<Vec<u32>>)> {
        if rows.is_empty() {
            return Ok((Vec::new(), Some(Vec::new())));
        }
        // The cost model already says whether this backend meters; an
        // unmetered one keeps its native batch path at zero extra cost.
        if self.info().cost.max_steps.is_none() {
            return Ok((self.classify_batch(rows)?, None));
        }
        let mut classes = Vec::with_capacity(rows.n_rows());
        let mut steps = Vec::with_capacity(rows.n_rows());
        for r in rows.iter() {
            let (c, s) = self.classify_with_steps(r)?;
            classes.push(c);
            steps.push(s.unwrap_or(0) as u32);
        }
        Ok((classes, Some(steps)))
    }

    /// Per-class vote counts for one row — the full terminal payload
    /// before any decision rule (`counts[c]` = trees voting class `c`,
    /// length [`ClassifierInfo::n_classes`]). Probabilities, weighted
    /// decisions, and regression means are all pure post-maps over this
    /// vector ([`crate::add::terminal`]), so one method funds every
    /// decision surface. The default refuses: backends whose terminals
    /// went through the majority abstraction have already discarded the
    /// distribution and cannot reconstruct it.
    fn votes(&self, _x: &[f32]) -> Result<Vec<u32>> {
        Err(Error::invalid(format!(
            "backend '{}' does not expose vote distributions \
             (majority-abstracted terminals discard them)",
            self.info().label
        )))
    }

    /// Per-class vote counts for a batch, flattened row-major with
    /// stride [`ClassifierInfo::n_classes`] (row `r`'s vector is
    /// `out[r*k..(r+1)*k]`). The default loops [`Classifier::votes`];
    /// backends with a native batch sweep override it to keep their
    /// tiling/SIMD path.
    fn votes_batch(&self, rows: RowMatrix<'_>) -> Result<Vec<u32>> {
        let k = self.info().n_classes;
        let mut out = Vec::with_capacity(rows.n_rows() * k);
        for r in rows.iter() {
            out.extend_from_slice(&self.votes(r)?);
        }
        Ok(out)
    }

    /// The per-class regression value table this model was trained with
    /// (`None` for classification models). When present, the model's
    /// regression prediction is
    /// [`expected_value`](crate::add::terminal::expected_value) of
    /// [`Classifier::votes`] under this table — a pure post-map, so the
    /// serving layer applies it uniformly across backends.
    fn task_values(&self) -> Option<Vec<f32>> {
        None
    }

    /// Concrete-type escape hatch for tooling that needs more than the
    /// classification contract (e.g. exporting a registered frozen model
    /// as a snapshot file). The default opts out; backends that want to be
    /// downcastable return `Some(self)`.
    fn as_any(&self) -> Option<&dyn std::any::Any> {
        None
    }
}

/// Mean §6 step count over a dataset; `None` when the backend cannot
/// meter steps.
pub fn mean_steps(c: &dyn Classifier, data: &Dataset) -> Result<Option<f64>> {
    let mut total = 0usize;
    for i in 0..data.n_rows() {
        match c.classify_with_steps(data.row(i))?.1 {
            Some(s) => total += s,
            None => return Ok(None),
        }
    }
    Ok(Some(total as f64 / data.n_rows() as f64))
}

/// Classification accuracy against dataset labels.
pub fn accuracy(c: &dyn Classifier, data: &Dataset) -> Result<f64> {
    let mut ok = 0usize;
    for i in 0..data.n_rows() {
        if c.classify(data.row(i))? == data.label(i) {
            ok += 1;
        }
    }
    Ok(ok as f64 / data.n_rows() as f64)
}

/// Fraction of rows on which two classifiers agree — the
/// semantics-preservation check (must be 1.0 for backends compiled from
/// the same forest).
pub fn agreement(a: &dyn Classifier, b: &dyn Classifier, data: &Dataset) -> Result<f64> {
    let mut ok = 0usize;
    for i in 0..data.n_rows() {
        if a.classify(data.row(i))? == b.classify(data.row(i))? {
            ok += 1;
        }
    }
    Ok(ok as f64 / data.n_rows() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parse_and_names() {
        assert_eq!(BackendKind::parse("dd").unwrap(), BackendKind::Dd);
        assert_eq!(BackendKind::parse("RF").unwrap(), BackendKind::Forest);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Xla);
        assert_eq!(BackendKind::parse("frozen").unwrap(), BackendKind::Frozen);
        assert_eq!(BackendKind::parse("fdd").unwrap(), BackendKind::Frozen);
        assert!(BackendKind::parse("gpu").is_err());
        assert_eq!(BackendKind::Xla.name(), "xla");
        assert_eq!(BackendKind::Frozen.name(), "frozen");
    }

    /// A fixed-answer classifier for exercising the default methods.
    struct Constant {
        class: u32,
        features: usize,
    }

    impl Classifier for Constant {
        fn info(&self) -> ClassifierInfo {
            ClassifierInfo {
                backend: BackendKind::Forest,
                label: "constant".into(),
                n_features: self.features,
                n_classes: 2,
                size_nodes: 1,
                cost: CostModel {
                    max_steps: Some(0),
                    aggregation_reads: 0,
                    preferred_batch: 1,
                },
            }
        }

        fn classify_with_steps(&self, _x: &[f32]) -> Result<(u32, Option<usize>)> {
            Ok((self.class, Some(0)))
        }
    }

    #[test]
    fn default_methods_derive_from_classify_with_steps() {
        let c = Constant {
            class: 1,
            features: 2,
        };
        assert_eq!(c.classify(&[0.0, 0.0]).unwrap(), 1);
        let cells = [0.0f32, 0.0, 1.0, 1.0, 2.0, 2.0];
        let batch = c
            .classify_batch(RowMatrix::new(&cells, 2).unwrap())
            .unwrap();
        assert_eq!(batch, vec![1, 1, 1]);
        assert!(c.classify_batch(RowMatrix::empty()).unwrap().is_empty());
        // the default metered batch derives per-row steps
        let (classes, steps) = c
            .classify_batch_with_steps(RowMatrix::new(&cells, 2).unwrap())
            .unwrap();
        assert_eq!(classes, vec![1, 1, 1]);
        assert_eq!(steps, Some(vec![0, 0, 0]));
    }

    /// A classifier that cannot meter steps (XLA-shaped).
    struct Unmetered;

    impl Classifier for Unmetered {
        fn info(&self) -> ClassifierInfo {
            ClassifierInfo {
                backend: BackendKind::Xla,
                label: "unmetered".into(),
                n_features: 2,
                n_classes: 2,
                size_nodes: 0,
                cost: CostModel {
                    max_steps: None,
                    aggregation_reads: 0,
                    preferred_batch: 8,
                },
            }
        }

        fn classify_with_steps(&self, _x: &[f32]) -> Result<(u32, Option<usize>)> {
            Ok((0, None))
        }
    }

    #[test]
    fn votes_default_refuses_and_batch_derives_from_single() {
        // a backend without vote support refuses, singly and batched
        let c = Constant {
            class: 1,
            features: 2,
        };
        assert!(c.votes(&[0.0, 0.0]).is_err());
        let cells = [0.0f32, 0.0, 1.0, 1.0];
        assert!(c.votes_batch(RowMatrix::new(&cells, 2).unwrap()).is_err());

        /// A two-class backend with a fixed vote vector.
        struct Voting;
        impl Classifier for Voting {
            fn info(&self) -> ClassifierInfo {
                ClassifierInfo {
                    backend: BackendKind::Forest,
                    label: "voting".into(),
                    n_features: 2,
                    n_classes: 2,
                    size_nodes: 1,
                    cost: CostModel {
                        max_steps: Some(0),
                        aggregation_reads: 2,
                        preferred_batch: 1,
                    },
                }
            }
            fn classify_with_steps(&self, _x: &[f32]) -> Result<(u32, Option<usize>)> {
                Ok((1, Some(0)))
            }
            fn votes(&self, _x: &[f32]) -> Result<Vec<u32>> {
                Ok(vec![2, 5])
            }
        }
        // the default batch flattens row vectors at stride n_classes
        let flat = Voting
            .votes_batch(RowMatrix::new(&cells, 2).unwrap())
            .unwrap();
        assert_eq!(flat, vec![2, 5, 2, 5]);
        assert!(Voting.votes_batch(RowMatrix::empty()).unwrap().is_empty());
    }

    #[test]
    fn unmetered_backends_report_no_batch_steps() {
        let cells = [0.0f32, 0.0, 1.0, 1.0];
        let (classes, steps) = Unmetered
            .classify_batch_with_steps(RowMatrix::new(&cells, 2).unwrap())
            .unwrap();
        assert_eq!(classes, vec![0, 0]);
        assert_eq!(steps, None);
    }

}
