//! The engine facade: one entry point that owns the model registry and
//! builds classifiers for every backend.
//!
//! [`Engine::builder`] is the quickstart path — give it a dataset and it
//! trains the forest, compiles the paper's DD, freezes it into the flat
//! serving form, optionally loads the XLA/PJRT artifact, and registers
//! everything as one named model ([`Engine::register_snapshot`] is the
//! training-free alternative for replicas that start from an `fdd`
//! artifact):
//!
//! ```no_run
//! use forest_add::engine::Engine;
//!
//! let data = forest_add::data::datasets::load("iris").unwrap();
//! let engine = Engine::builder()
//!     .dataset(data.clone())
//!     .trees(100)
//!     .seed(7)
//!     .build()
//!     .unwrap();
//! let class = engine.classify(None, None, data.row(0)).unwrap();
//! # let _ = class;
//! ```
//!
//! Beyond the builder, the engine exposes the [`ModelRegistry`] directly:
//! register additional named models, hot-swap a retrained version under
//! the same name, and select model + backend per request. The serving
//! router shares the same registry, so a swap through the engine is
//! immediately visible to HTTP traffic.

pub mod registry;

pub use registry::{BackendSlot, ModelId, ModelRegistry, ModelSpec, ModelVersion};

use crate::batch::RowMatrix;
use crate::classifier::{BackendKind, Classifier, ClassifierInfo};
use crate::compile::{Abstraction, CompileOptions, ForestCompiler};
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::forest::{ForestLearner, RandomForest};
use crate::frozen::bundle::{self, Bundle, BundleEntrySpec};
use crate::frozen::FrozenDD;
use crate::serve::xla_backend::XlaBackend;
use std::sync::Arc;

/// The classification engine: a facade over a [`ModelRegistry`] of
/// versioned models whose backends all speak [`Classifier`].
pub struct Engine {
    registry: Arc<ModelRegistry>,
}

impl Default for Engine {
    fn default() -> Self {
        Engine::new()
    }
}

impl Engine {
    /// An engine with an empty registry (register models manually).
    pub fn new() -> Engine {
        Engine {
            registry: Arc::new(ModelRegistry::new()),
        }
    }

    /// An engine wrapping an existing (possibly shared) registry.
    pub fn with_registry(registry: Arc<ModelRegistry>) -> Engine {
        Engine { registry }
    }

    /// Builder: train + compile + register one model.
    pub fn builder() -> EngineBuilder {
        EngineBuilder::default()
    }

    /// The shared model registry.
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// Train a forest on `data`, compile it under `opts`, and register
    /// the forest + DD + frozen-DD trio under `name` (hot-swapping any
    /// existing version). Returns the issued [`ModelId`].
    pub fn train_and_register(
        &self,
        name: &str,
        data: &Dataset,
        trees: usize,
        max_depth: usize,
        seed: u64,
        opts: CompileOptions,
    ) -> Result<ModelId> {
        let (forest, dd) = train_forest_and_dd(data, trees, max_depth, seed, opts)?;
        let frozen = dd.freeze();
        let schema = forest.schema.clone();
        self.registry.register(
            name,
            schema,
            vec![
                (BackendKind::Forest, Arc::new(forest) as Arc<dyn Classifier>),
                (BackendKind::Dd, Arc::new(dd) as Arc<dyn Classifier>),
                (BackendKind::Frozen, Arc::new(frozen) as Arc<dyn Classifier>),
            ],
        )
    }

    /// Register a model straight from an `fdd` snapshot file — the
    /// replica-startup path: no training, no compilation, no JSON. On
    /// 64-bit unix the artifact is `mmap`ed and the v2 node/terminal
    /// sections back the runtime arrays in place (zero copies, zero
    /// per-node allocations — checksum and structural validation still
    /// run); elsewhere one buffered read replaces the map, and legacy
    /// `fdd-v1` artifacts upgrade on load. Hot-swaps any existing
    /// version under `name`.
    pub fn register_snapshot(&self, name: &str, path: &str) -> Result<ModelId> {
        let frozen = FrozenDD::load(path)?;
        let schema = frozen.schema().clone();
        self.registry.register(
            name,
            schema,
            vec![(BackendKind::Frozen, Arc::new(frozen) as Arc<dyn Classifier>)],
        )
    }

    /// Write the frozen backend of a registered model (`None` = default
    /// model) to an `fdd-v2` snapshot file — the build-pipeline
    /// counterpart of [`Engine::register_snapshot`], so callers never
    /// re-train a model the engine already owns.
    pub fn save_snapshot(&self, model: Option<&str>, path: &str) -> Result<()> {
        let (version, slot) = self.registry.resolve(model, Some(BackendKind::Frozen))?;
        let frozen = slot
            .classifier
            .as_any()
            .and_then(|a| a.downcast_ref::<FrozenDD>())
            .ok_or_else(|| {
                Error::invalid(format!(
                    "model '{}' frozen backend is not a FrozenDD",
                    version.id
                ))
            })?;
        frozen.save(path)
    }

    /// Register every model of a `fab-v1` artifact bundle — the
    /// fleet-replica startup path. The file is mapped **once**
    /// (`MADV_WILLNEED`-hinted) and each entry boots as a zero-copy
    /// [`FrozenDD`] borrowing its slice of the shared mapping; names and
    /// versions then land in the [`ModelRegistry`] in one atomic
    /// hot-swap ([`ModelRegistry::register_many`]), so traffic never
    /// observes half the bundle. Per-request `model` selection routes
    /// straight into bundle entries; `GET /models` reports each entry's
    /// bundle provenance. Returns the issued ids in manifest order (the
    /// first entry becomes the default model on a fresh registry).
    pub fn register_bundle(&self, path: &str) -> Result<Vec<ModelId>> {
        let bundle = Bundle::load(path)?;
        let mut specs = Vec::with_capacity(bundle.len());
        for (i, entry) in bundle.entries().iter().enumerate() {
            let frozen = bundle.boot(i)?;
            let schema = frozen.schema().clone();
            let shard = if entry.shard.is_empty() {
                String::new()
            } else {
                format!(" shard={}", entry.shard)
            };
            specs.push(ModelSpec {
                name: entry.name.clone(),
                schema,
                backends: vec![(BackendKind::Frozen, Arc::new(frozen) as Arc<dyn Classifier>)],
                provenance: Some(format!("{path}#{}@v{}{shard}", entry.name, entry.version)),
            });
        }
        self.registry.register_many(specs)
    }

    /// Pack the frozen backends of `models` (empty slice = every
    /// registered model, in registry order) into a `fab-v1` bundle at
    /// `path` — the build-pipeline counterpart of
    /// [`Engine::register_bundle`]. Entry versions are the registry's
    /// current versions; the write is atomic (temp file + rename).
    pub fn save_bundle(&self, models: &[&str], path: &str) -> Result<()> {
        let names: Vec<String> = if models.is_empty() {
            self.registry.list().iter().map(|v| v.id.name.clone()).collect()
        } else {
            models.iter().map(|s| s.to_string()).collect()
        };
        if names.is_empty() {
            return Err(Error::invalid("save_bundle: no models registered"));
        }
        // Hold every resolved classifier first so the specs below can
        // borrow the concrete FrozenDDs.
        let mut held: Vec<(String, u64, Arc<dyn Classifier>)> = Vec::with_capacity(names.len());
        for name in &names {
            let (version, slot) = self.registry.resolve(Some(name), Some(BackendKind::Frozen))?;
            held.push((name.clone(), version.id.version, slot.classifier));
        }
        let specs: Vec<BundleEntrySpec<'_>> = held
            .iter()
            .map(|(name, version, classifier)| {
                let dd = classifier
                    .as_any()
                    .and_then(|a| a.downcast_ref::<FrozenDD>())
                    .ok_or_else(|| {
                        Error::invalid(format!(
                            "model '{name}' frozen backend is not a FrozenDD"
                        ))
                    })?;
                Ok(BundleEntrySpec {
                    name: name.clone(),
                    version: *version,
                    shard: String::new(),
                    dd,
                })
            })
            .collect::<Result<_>>()?;
        bundle::save(path, &bundle::pack(&specs)?)
    }

    /// Classify one row on `model`/`backend` (`None` = defaults).
    pub fn classify(
        &self,
        model: Option<&str>,
        backend: Option<BackendKind>,
        x: &[f32],
    ) -> Result<u32> {
        let (version, slot) = self.registry.resolve(model, backend)?;
        version.check_row(x)?;
        slot.classifier.classify(x)
    }

    /// Classify a flat row-major batch on `model`/`backend`.
    pub fn classify_batch(
        &self,
        model: Option<&str>,
        backend: Option<BackendKind>,
        rows: RowMatrix<'_>,
    ) -> Result<Vec<u32>> {
        let (version, slot) = self.registry.resolve(model, backend)?;
        version.check_matrix(rows)?;
        slot.classifier.classify_batch(rows)
    }

    /// Classify a batch *with the §6 step count per row* (`None` when
    /// the backend cannot meter, e.g. XLA) — cost accounting over the
    /// batch path, same semantics as per-row
    /// [`Engine::classify`] + steps.
    pub fn classify_batch_steps(
        &self,
        model: Option<&str>,
        backend: Option<BackendKind>,
        rows: RowMatrix<'_>,
    ) -> Result<(Vec<u32>, Option<Vec<u32>>)> {
        let (version, slot) = self.registry.resolve(model, backend)?;
        version.check_matrix(rows)?;
        slot.classifier.classify_batch_with_steps(rows)
    }

    /// Per-class vote counts for one row on `model`/`backend` — the raw
    /// distribution behind every decision rule. Errors with
    /// [`Error::InvalidArgument`] on backends that fold votes away at
    /// compile time (the default majority abstraction, XLA); compile
    /// with [`Abstraction::Vector`] (or query the forest backend) to
    /// serve distributions.
    pub fn votes(
        &self,
        model: Option<&str>,
        backend: Option<BackendKind>,
        x: &[f32],
    ) -> Result<Vec<u32>> {
        let (version, slot) = self.registry.resolve(model, backend)?;
        version.check_row(x)?;
        slot.classifier.votes(x)
    }

    /// Per-class vote fractions for one row (`votes` normalised to sum
    /// to 1) — same backend requirements as [`Engine::votes`].
    pub fn probabilities(
        &self,
        model: Option<&str>,
        backend: Option<BackendKind>,
        x: &[f32],
    ) -> Result<Vec<f64>> {
        Ok(crate::add::terminal::probabilities(&self.votes(
            model, backend, x,
        )?))
    }

    /// Regression prediction for one row: the vote-weighted mean of the
    /// model's bin value table. Errors when the model's schema carries
    /// no value table (a classification model) or the backend cannot
    /// expose votes.
    pub fn predict_value(
        &self,
        model: Option<&str>,
        backend: Option<BackendKind>,
        x: &[f32],
    ) -> Result<f64> {
        let (version, slot) = self.registry.resolve(model, backend)?;
        version.check_row(x)?;
        let values = version.schema.values().ok_or_else(|| {
            Error::invalid(format!(
                "model '{}' has no value table (not a regression model)",
                version.id
            ))
        })?;
        let votes = slot.classifier.votes(x)?;
        Ok(crate::add::terminal::expected_value(&votes, values))
    }

    /// Per-backend metadata for a model (`None` = default model).
    pub fn info(&self, model: Option<&str>) -> Result<Vec<ClassifierInfo>> {
        let version = self.registry.get(model)?;
        Ok(version.slots().iter().map(|s| s.classifier.info()).collect())
    }
}

/// Builder for [`Engine`]: dataset in, trained + compiled + registered
/// model out.
#[derive(Debug, Clone)]
pub struct EngineBuilder {
    name: String,
    dataset: Option<Dataset>,
    dataset_spec: Option<String>,
    trees: usize,
    max_depth: usize,
    seed: u64,
    compile: CompileOptions,
    xla: Option<(String, String)>,
}

impl Default for EngineBuilder {
    fn default() -> Self {
        EngineBuilder {
            name: "default".into(),
            dataset: None,
            dataset_spec: None,
            trees: 100,
            max_depth: 0,
            seed: 42,
            compile: CompileOptions::default(),
            xla: None,
        }
    }
}

impl EngineBuilder {
    /// Name the registered model (default `"default"`).
    pub fn model_name(mut self, name: impl Into<String>) -> Self {
        self.name = name.into();
        self
    }

    /// Train on this in-memory dataset.
    pub fn dataset(mut self, data: Dataset) -> Self {
        self.dataset = Some(data);
        self
    }

    /// Train on a dataset spec: a built-in name or a `.csv`/`.arff` path
    /// (resolved at [`build`](Self::build) time).
    pub fn dataset_spec(mut self, spec: impl Into<String>) -> Self {
        self.dataset_spec = Some(spec.into());
        self
    }

    /// Forest size (default 100).
    pub fn trees(mut self, n: usize) -> Self {
        self.trees = n;
        self
    }

    /// Per-tree depth cap (`0` = unlimited; the XLA path needs a cap that
    /// fits the artifact depth).
    pub fn max_depth(mut self, d: usize) -> Self {
        self.max_depth = d;
        self
    }

    /// Training seed (default 42).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Abstraction of the compiled diagram (default majority, the
    /// paper's `Most frequent class DD*`).
    pub fn abstraction(mut self, a: Abstraction) -> Self {
        self.compile.abstraction = a;
        self
    }

    /// Enable/disable unsatisfiable-path elimination (default on).
    pub fn unsat_elim(mut self, on: bool) -> Self {
        self.compile.unsat_elim = on;
        self
    }

    /// Replace the full compiler configuration.
    pub fn compile_options(mut self, opts: CompileOptions) -> Self {
        self.compile = opts;
        self
    }

    /// Also load the XLA/PJRT backend from `artifacts_dir`/`variant`.
    /// Load failures fall back to the native backends with a warning
    /// (DESIGN.md §7) — they never fail the build.
    pub fn xla_artifacts(mut self, artifacts_dir: impl Into<String>, variant: impl Into<String>) -> Self {
        self.xla = Some((artifacts_dir.into(), variant.into()));
        self
    }

    /// Train, compile, optionally load XLA, and register the model.
    pub fn build(self) -> Result<Engine> {
        let data = match (self.dataset, self.dataset_spec) {
            (Some(d), _) => d,
            (None, Some(spec)) => crate::data::resolve(&spec)?,
            (None, None) => {
                return Err(Error::invalid(
                    "EngineBuilder needs a dataset (use .dataset(..) or .dataset_spec(..))",
                ))
            }
        };
        let (forest, dd) =
            train_forest_and_dd(&data, self.trees, self.max_depth, self.seed, self.compile)?;
        let schema = forest.schema.clone();
        let mut backends: Vec<(BackendKind, Arc<dyn Classifier>)> = Vec::new();
        let xla = match &self.xla {
            Some((dir, variant)) => match XlaBackend::start(dir, variant, &forest) {
                Ok(b) => Some(b),
                Err(e) => {
                    // Per DESIGN.md §7: incompatible forests fall back to
                    // the native DD backend rather than silently changing
                    // semantics.
                    crate::log_warn!("engine: xla backend unavailable, falling back to dd: {e}");
                    None
                }
            },
            None => None,
        };
        let frozen = dd.freeze();
        backends.push((BackendKind::Forest, Arc::new(forest) as Arc<dyn Classifier>));
        backends.push((BackendKind::Dd, Arc::new(dd) as Arc<dyn Classifier>));
        backends.push((BackendKind::Frozen, Arc::new(frozen) as Arc<dyn Classifier>));
        if let Some(b) = xla {
            backends.push((BackendKind::Xla, Arc::new(b) as Arc<dyn Classifier>));
        }
        let engine = Engine::new();
        engine.registry.register(self.name.as_str(), schema, backends)?;
        Ok(engine)
    }
}

/// Shared train→compile step of [`EngineBuilder::build`] and
/// [`Engine::train_and_register`].
fn train_forest_and_dd(
    data: &Dataset,
    trees: usize,
    max_depth: usize,
    seed: u64,
    opts: CompileOptions,
) -> Result<(RandomForest, crate::compile::CompiledDD)> {
    if trees == 0 {
        return Err(Error::invalid("trees must be positive"));
    }
    let forest = ForestLearner::default()
        .trees(trees)
        .max_depth(max_depth)
        .seed(seed)
        .fit(data);
    let dd = ForestCompiler::new(opts).compile(&forest)?;
    Ok((forest, dd))
}

/// Register a standalone forest as a single-backend model (helper for
/// tools that evaluate the baseline through the registry).
pub fn register_forest(
    registry: &ModelRegistry,
    name: &str,
    forest: RandomForest,
) -> Result<ModelId> {
    let schema = forest.schema.clone();
    registry.register(
        name,
        schema,
        vec![(BackendKind::Forest, Arc::new(forest) as Arc<dyn Classifier>)],
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;

    #[test]
    fn builder_trains_compiles_and_registers() {
        let data = datasets::iris();
        let engine = Engine::builder()
            .dataset(data.clone())
            .trees(12)
            .seed(3)
            .build()
            .unwrap();
        let version = engine.registry().get(None).unwrap();
        assert_eq!(version.id.to_string(), "default@v1");
        assert_eq!(version.default_backend, BackendKind::Dd);
        assert!(version.has(BackendKind::Forest));
        assert!(version.has(BackendKind::Dd));
        assert!(version.has(BackendKind::Frozen));
        // all native backends agree through the facade on every row
        for i in (0..data.n_rows()).step_by(17) {
            let rf = engine
                .classify(None, Some(BackendKind::Forest), data.row(i))
                .unwrap();
            let dd = engine
                .classify(None, Some(BackendKind::Dd), data.row(i))
                .unwrap();
            let frozen = engine
                .classify(None, Some(BackendKind::Frozen), data.row(i))
                .unwrap();
            assert_eq!(rf, dd, "row {i}");
            assert_eq!(dd, frozen, "row {i}");
        }
    }

    #[test]
    fn builder_validates_inputs() {
        assert!(Engine::builder().build().is_err(), "dataset required");
        assert!(Engine::builder()
            .dataset(datasets::iris())
            .trees(0)
            .build()
            .is_err());
        assert!(Engine::builder()
            .dataset_spec("no-such-dataset")
            .build()
            .is_err());
    }

    #[test]
    fn builder_resolves_dataset_specs() {
        let engine = Engine::builder()
            .dataset_spec("lenses")
            .trees(5)
            .build()
            .unwrap();
        assert_eq!(engine.registry().len(), 1);
    }

    #[test]
    fn engine_batch_and_info() {
        let data = datasets::iris();
        let engine = Engine::builder()
            .dataset(data.clone())
            .trees(8)
            .seed(1)
            .build()
            .unwrap();
        let mut buf = crate::batch::RowMatrixBuf::with_capacity(data.n_features(), 12);
        for i in 0..12 {
            buf.push_row(data.row(i * 11)).unwrap();
        }
        let rows = buf.as_matrix();
        let batch = engine.classify_batch(None, None, rows).unwrap();
        assert_eq!(batch.len(), 12);
        for (row, &c) in rows.iter().zip(&batch) {
            assert_eq!(c, engine.classify(None, None, row).unwrap());
        }
        // §6 metering survives the facade's batch path on every native
        // backend
        for backend in [BackendKind::Forest, BackendKind::Dd, BackendKind::Frozen] {
            let (classes, steps) = engine
                .classify_batch_steps(None, Some(backend), rows)
                .unwrap();
            assert_eq!(classes, batch, "{backend:?}");
            let steps = steps.expect("native backends meter steps");
            assert_eq!(steps.len(), 12, "{backend:?}");
            assert!(steps.iter().all(|&s| s > 0), "{backend:?}");
        }
        // batches are checked against the model schema at the facade too
        let bad = [1.0f32, 2.0];
        assert!(engine
            .classify_batch(None, None, RowMatrix::new(&bad, 2).unwrap())
            .is_err());
        let infos = engine.info(None).unwrap();
        assert_eq!(infos.len(), 3);
        assert!(infos.iter().any(|i| i.backend == BackendKind::Forest));
        assert!(infos.iter().any(|i| i.backend == BackendKind::Dd));
        assert!(infos.iter().any(|i| i.backend == BackendKind::Frozen));
        // arity violations are rejected at the facade
        assert!(engine.classify(None, None, &[1.0]).is_err());
    }

    #[test]
    fn register_snapshot_serves_without_training() {
        let data = datasets::lenses();
        // Offline: build and freeze the artifact.
        let builder_engine = Engine::builder()
            .dataset(data.clone())
            .trees(9)
            .seed(4)
            .build()
            .unwrap();
        let (_, dd) = builder_engine
            .registry()
            .resolve(None, Some(BackendKind::Dd))
            .unwrap();
        let path = std::env::temp_dir().join(format!("engine-snap-{}.fdd", std::process::id()));
        let path_s = path.to_str().unwrap().to_string();
        let expected: Vec<u32> = (0..data.n_rows())
            .map(|i| dd.classifier.classify(data.row(i)).unwrap())
            .collect();
        // export the engine's own frozen backend — no re-training
        builder_engine.save_snapshot(None, &path_s).unwrap();

        // Replica: snapshot in, answers out — no dataset, no compiler.
        let replica = Engine::new();
        let id = replica.register_snapshot("lenses", &path_s).unwrap();
        assert_eq!(id.to_string(), "lenses@v1");
        for (i, &want) in expected.iter().enumerate() {
            assert_eq!(
                replica.classify(Some("lenses"), None, data.row(i)).unwrap(),
                want,
                "row {i}"
            );
        }
        // hot-swap: re-registering the snapshot bumps the version
        let id2 = replica.register_snapshot("lenses", &path_s).unwrap();
        assert_eq!(id2.version, 2);
        assert!(replica.register_snapshot("lenses", "/no/such/file.fdd").is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn bundle_roundtrip_through_the_engine() {
        // Build two distinct models on one engine …
        let iris = datasets::iris();
        let lenses = datasets::lenses();
        let engine = Engine::new();
        engine
            .train_and_register("iris", &iris, 8, 0, 3, CompileOptions::default())
            .unwrap();
        engine
            .train_and_register("lenses", &lenses, 6, 0, 5, CompileOptions::default())
            .unwrap();
        let path = std::env::temp_dir().join(format!("engine-bundle-{}.fab", std::process::id()));
        let path_s = path.to_str().unwrap().to_string();
        engine.save_bundle(&[], &path_s).unwrap();

        // … and boot a fleet replica from the single artifact: one file,
        // both models, no training.
        let replica = Engine::new();
        let ids = replica.register_bundle(&path_s).unwrap();
        assert_eq!(ids.len(), 2);
        // save_bundle([]) walks the registry in name order
        assert_eq!(ids[0].to_string(), "iris@v1");
        assert_eq!(ids[1].to_string(), "lenses@v1");
        for (ds, name) in [(&iris, "iris"), (&lenses, "lenses")] {
            for i in (0..ds.n_rows()).step_by(7) {
                assert_eq!(
                    replica.classify(Some(name), None, ds.row(i)).unwrap(),
                    engine
                        .classify(Some(name), Some(BackendKind::Frozen), ds.row(i))
                        .unwrap(),
                    "{name} row {i}"
                );
            }
        }
        let version = replica.registry().get(Some("lenses")).unwrap();
        let provenance = version.provenance.as_deref().unwrap();
        assert!(provenance.contains(".fab#lenses@v1"), "{provenance}");
        // explicit model subsets bundle too, and bad inputs fail cleanly
        engine.save_bundle(&["lenses"], &path_s).unwrap();
        assert_eq!(replica.register_bundle(&path_s).unwrap().len(), 1);
        assert!(engine.save_bundle(&["nope"], &path_s).is_err());
        assert!(Engine::new().save_bundle(&[], &path_s).is_err());
        assert!(replica.register_bundle("/no/such/file.fab").is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn votes_and_values_through_the_facade() {
        let spec = crate::data::synth::RegressionSpec {
            rows: 140,
            bins: 8,
            ..Default::default()
        };
        let data = crate::data::synth::regression(&spec).unwrap();
        let engine = Engine::builder()
            .dataset(data.clone())
            .trees(7)
            .seed(5)
            .abstraction(Abstraction::Vector)
            .build()
            .unwrap();
        for i in (0..data.n_rows()).step_by(19) {
            let forest = engine
                .votes(None, Some(BackendKind::Forest), data.row(i))
                .unwrap();
            let dd = engine.votes(None, Some(BackendKind::Dd), data.row(i)).unwrap();
            let frozen = engine
                .votes(None, Some(BackendKind::Frozen), data.row(i))
                .unwrap();
            assert_eq!(forest, dd, "row {i}");
            assert_eq!(dd, frozen, "row {i}");
            assert_eq!(forest.iter().sum::<u32>(), 7, "one vote per tree");
            let probs = engine
                .probabilities(None, Some(BackendKind::Dd), data.row(i))
                .unwrap();
            assert!((probs.iter().sum::<f64>() - 1.0).abs() < 1e-9, "row {i}");
            let value = engine.predict_value(None, None, data.row(i)).unwrap();
            assert!(value.is_finite(), "row {i}");
        }
        // a classification model has no value table
        let iris = Engine::builder()
            .dataset(datasets::iris())
            .trees(5)
            .seed(1)
            .build()
            .unwrap();
        let err = iris
            .predict_value(None, Some(BackendKind::Forest), datasets::iris().row(0))
            .unwrap_err();
        assert!(err.to_string().contains("value table"), "{err}");
        // the default majority abstraction folds votes away
        let err = iris.votes(None, Some(BackendKind::Dd), datasets::iris().row(0)).unwrap_err();
        assert!(err.to_string().contains("vote"), "{err}");
    }

    #[test]
    fn train_and_register_hot_swaps_named_models() {
        let data = datasets::lenses();
        let engine = Engine::new();
        let id1 = engine
            .train_and_register("lenses", &data, 6, 0, 1, CompileOptions::default())
            .unwrap();
        assert_eq!(id1.version, 1);
        let id2 = engine
            .train_and_register("lenses", &data, 10, 0, 2, CompileOptions::default())
            .unwrap();
        assert_eq!(id2.version, 2);
        let version = engine.registry().get(Some("lenses")).unwrap();
        assert_eq!(version.id.version, 2);
    }
}
