//! Versioned model registry: named models, monotonic versions, atomic
//! hot-swap, per-request backend selection.
//!
//! The registry is the serving layer's source of truth. Each *name* maps
//! to the current [`ModelVersion`]; registering under an existing name
//! atomically replaces it with a bumped version (requests already holding
//! the old `Arc` finish against the old version — classic RCU). Every
//! backend of a version is a [`Classifier`] trait object, so the router
//! never touches a concrete evaluator type.

use crate::batch::RowMatrix;
use crate::classifier::{BackendKind, Classifier};
use crate::data::Schema;
use crate::error::{Error, Result};
use std::collections::HashMap;
use std::fmt;
use std::sync::{Arc, RwLock};

/// Identity of one registered model version: name + monotonic version.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ModelId {
    /// Registry name (request-addressable).
    pub name: String,
    /// Monotonic version, starting at 1 and bumped by every hot-swap of
    /// the same name (never reset, even across remove/re-register).
    pub version: u64,
}

impl fmt::Display for ModelId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}@v{}", self.name, self.version)
    }
}

/// One backend of a model version: the classifier trait object plus
/// routing metadata cached at registration time (so the request hot path
/// never calls [`Classifier::info`], which allocates).
#[derive(Clone)]
pub struct BackendSlot {
    /// Execution backend kind.
    pub kind: BackendKind,
    /// The evaluator.
    pub classifier: Arc<dyn Classifier>,
    /// True when the backend prefers batched dispatch
    /// (`info().cost.preferred_batch > 1`) — the router coalesces such
    /// traffic through the dynamic batcher.
    pub batch_first: bool,
}

impl fmt::Debug for BackendSlot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("BackendSlot")
            .field("kind", &self.kind)
            .field("batch_first", &self.batch_first)
            .finish()
    }
}

/// An immutable, atomically-swappable model version: schema plus one
/// classifier per available backend.
pub struct ModelVersion {
    /// Identity (name + version).
    pub id: ModelId,
    /// Schema of the training data (feature arity, class labels).
    pub schema: Schema,
    /// Backend used when a request names none (`dd` when present,
    /// otherwise the first registered backend).
    pub default_backend: BackendKind,
    /// Where the model came from, when registered from an artifact (e.g.
    /// the `fab` bundle path + entry + shard tag). Surfaced by
    /// `GET /models`; `None` for models trained or registered in-process.
    pub provenance: Option<String>,
    slots: Vec<BackendSlot>,
}

impl fmt::Debug for ModelVersion {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ModelVersion")
            .field("id", &self.id)
            .field("default_backend", &self.default_backend)
            .field("slots", &self.slots)
            .finish()
    }
}

impl ModelVersion {
    /// All backends of this version.
    pub fn slots(&self) -> &[BackendSlot] {
        &self.slots
    }

    /// The slot for a backend kind.
    pub fn slot(&self, kind: BackendKind) -> Result<&BackendSlot> {
        self.slots.iter().find(|s| s.kind == kind).ok_or_else(|| {
            Error::Serve(format!(
                "backend '{}' not available for model '{}'",
                kind.name(),
                self.id
            ))
        })
    }

    /// Whether a backend kind is available.
    pub fn has(&self, kind: BackendKind) -> bool {
        self.slots.iter().any(|s| s.kind == kind)
    }

    /// Human-readable class label for a class index.
    pub fn label_of(&self, class: u32) -> String {
        self.schema
            .classes
            .get(class as usize)
            .cloned()
            .unwrap_or_else(|| format!("class-{class}"))
    }

    /// Validate a request row against the model schema.
    pub fn check_row(&self, features: &[f32]) -> Result<()> {
        let want = self.schema.n_features();
        if features.len() != want {
            return Err(Error::Serve(format!(
                "request has {} features, model expects {want}",
                features.len()
            )));
        }
        if features.iter().any(|v| !v.is_finite()) {
            return Err(Error::Serve("request contains non-finite features".into()));
        }
        Ok(())
    }

    /// Validate a flat batch against the model schema: one arity check
    /// for the whole matrix plus one linear finiteness scan — no per-row
    /// work on the batch hot path.
    pub fn check_matrix(&self, rows: RowMatrix<'_>) -> Result<()> {
        if rows.is_empty() {
            return Ok(());
        }
        let want = self.schema.n_features();
        if rows.n_features() != want {
            return Err(Error::Serve(format!(
                "batch rows have {} features, model expects {want}",
                rows.n_features()
            )));
        }
        if rows.data().iter().any(|v| !v.is_finite()) {
            return Err(Error::Serve("batch contains non-finite features".into()));
        }
        Ok(())
    }
}

#[derive(Default)]
struct RegistryState {
    models: HashMap<String, Arc<ModelVersion>>,
    /// Last version issued per name; survives removal so re-registering a
    /// name keeps the version monotonic.
    versions: HashMap<String, u64>,
    /// Model served when a request names none (first registered, unless
    /// overridden with [`ModelRegistry::set_default`]).
    default_model: Option<String>,
}

/// Thread-safe registry of named, versioned models.
#[derive(Default)]
pub struct ModelRegistry {
    inner: RwLock<RegistryState>,
}

/// Everything needed to register one model — the unit of
/// [`ModelRegistry::register_many`], which lands a whole artifact
/// bundle's worth of names and versions in one atomic hot-swap.
pub struct ModelSpec {
    /// Registry name (request-addressable; must be non-empty and unique
    /// within one `register_many` batch).
    pub name: String,
    /// Schema every backend must agree with.
    pub schema: Schema,
    /// The backends, each a [`Classifier`] trait object.
    pub backends: Vec<(BackendKind, Arc<dyn Classifier>)>,
    /// Optional artifact provenance (surfaced by `GET /models`).
    pub provenance: Option<String>,
}

impl ModelRegistry {
    /// Empty registry.
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    /// Validate one model's backends against its schema and derive the
    /// routing slots + default backend (shared by [`Self::register`] and
    /// [`Self::register_many`]; runs before any lock is taken).
    fn prepare(
        name: &str,
        schema: &Schema,
        backends: Vec<(BackendKind, Arc<dyn Classifier>)>,
    ) -> Result<(Vec<BackendSlot>, BackendKind)> {
        if name.is_empty() {
            return Err(Error::invalid("model name must be non-empty"));
        }
        if backends.is_empty() {
            return Err(Error::invalid(format!(
                "model '{name}' needs at least one backend"
            )));
        }
        let mut slots = Vec::with_capacity(backends.len());
        for (kind, classifier) in backends {
            let info = classifier.info();
            if info.n_features != schema.n_features() || info.n_classes != schema.n_classes() {
                return Err(Error::SchemaMismatch(format!(
                    "model '{name}' backend '{}' is {}x{} but the schema is {}x{}",
                    kind.name(),
                    info.n_features,
                    info.n_classes,
                    schema.n_features(),
                    schema.n_classes()
                )));
            }
            if slots.iter().any(|s: &BackendSlot| s.kind == kind) {
                return Err(Error::invalid(format!(
                    "model '{name}' registers backend '{}' twice",
                    kind.name()
                )));
            }
            slots.push(BackendSlot {
                kind,
                batch_first: info.cost.preferred_batch > 1,
                classifier,
            });
        }
        let default_backend = if slots.iter().any(|s| s.kind == BackendKind::Dd) {
            BackendKind::Dd
        } else {
            slots[0].kind
        };
        Ok((slots, default_backend))
    }

    /// Register (or atomically hot-swap) a model under `name`.
    ///
    /// Backends must agree with the schema on arity and class count —
    /// that is the semantic-equivalence contract this API is built on.
    /// Returns the issued [`ModelId`].
    pub fn register(
        &self,
        name: impl Into<String>,
        schema: Schema,
        backends: Vec<(BackendKind, Arc<dyn Classifier>)>,
    ) -> Result<ModelId> {
        let ids = self.register_many(vec![ModelSpec {
            name: name.into(),
            schema,
            backends,
            provenance: None,
        }])?;
        Ok(ids.into_iter().next().expect("one spec yields one id"))
    }

    /// Register (or hot-swap) several models in **one** atomic step: all
    /// specs are validated up front, then inserted under a single write
    /// lock — the bundle boot path, where no request may ever observe
    /// half a fleet swapped. All-or-nothing: any invalid spec fails the
    /// whole batch before the registry changes.
    pub fn register_many(&self, specs: Vec<ModelSpec>) -> Result<Vec<ModelId>> {
        if specs.is_empty() {
            return Err(Error::invalid("register_many needs at least one model"));
        }
        let mut prepared = Vec::with_capacity(specs.len());
        let mut batch_names: Vec<String> = Vec::with_capacity(specs.len());
        for spec in specs {
            if batch_names.contains(&spec.name) {
                return Err(Error::invalid(format!(
                    "model '{}' appears twice in one registration",
                    spec.name
                )));
            }
            batch_names.push(spec.name.clone());
            let (slots, default_backend) = Self::prepare(&spec.name, &spec.schema, spec.backends)?;
            prepared.push((spec.name, spec.schema, spec.provenance, slots, default_backend));
        }
        let mut state = self.inner.write().unwrap();
        let mut ids = Vec::with_capacity(prepared.len());
        for (name, schema, provenance, slots, default_backend) in prepared {
            let version = state.versions.get(&name).copied().unwrap_or(0) + 1;
            state.versions.insert(name.clone(), version);
            let id = ModelId {
                name: name.clone(),
                version,
            };
            let entry = Arc::new(ModelVersion {
                id: id.clone(),
                schema,
                default_backend,
                provenance,
                slots,
            });
            state.models.insert(name.clone(), entry);
            if state.default_model.is_none() {
                state.default_model = Some(name);
            }
            ids.push(id);
        }
        Ok(ids)
    }

    /// Fetch a model by name (`None` = the default model).
    pub fn get(&self, model: Option<&str>) -> Result<Arc<ModelVersion>> {
        let state = self.inner.read().unwrap();
        let name = match model {
            Some(n) => n,
            None => state
                .default_model
                .as_deref()
                .ok_or_else(|| Error::Serve("no models registered".into()))?,
        };
        state
            .models
            .get(name)
            .cloned()
            .ok_or_else(|| Error::Serve(format!("unknown model '{name}'")))
    }

    /// Resolve a model + backend selection to a classifier slot.
    ///
    /// `model = None` uses the default model; `backend = None` uses the
    /// model's default backend. This is the single dispatch point the
    /// router and the CLI go through.
    pub fn resolve(
        &self,
        model: Option<&str>,
        backend: Option<BackendKind>,
    ) -> Result<(Arc<ModelVersion>, BackendSlot)> {
        let version = self.get(model)?;
        let kind = backend.unwrap_or(version.default_backend);
        let slot = version.slot(kind)?.clone();
        Ok((version, slot))
    }

    /// Make `name` the default model for requests that name none.
    pub fn set_default(&self, name: &str) -> Result<()> {
        let mut state = self.inner.write().unwrap();
        if !state.models.contains_key(name) {
            return Err(Error::Serve(format!("unknown model '{name}'")));
        }
        state.default_model = Some(name.to_string());
        Ok(())
    }

    /// Remove a model; returns its id. The default-model pointer moves to
    /// any remaining model (or clears).
    pub fn remove(&self, name: &str) -> Result<ModelId> {
        let mut state = self.inner.write().unwrap();
        let entry = state
            .models
            .remove(name)
            .ok_or_else(|| Error::Serve(format!("unknown model '{name}'")))?;
        if state.default_model.as_deref() == Some(name) {
            state.default_model = state.models.keys().next().cloned();
        }
        Ok(entry.id.clone())
    }

    /// Snapshot of all registered models, sorted by name.
    pub fn list(&self) -> Vec<Arc<ModelVersion>> {
        let state = self.inner.read().unwrap();
        let mut out: Vec<_> = state.models.values().cloned().collect();
        out.sort_by(|a, b| a.id.name.cmp(&b.id.name));
        out
    }

    /// Name of the default model, if any.
    pub fn default_model(&self) -> Option<String> {
        self.inner.read().unwrap().default_model.clone()
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.inner.read().unwrap().models.len()
    }

    /// True when no models are registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifier::{ClassifierInfo, CostModel};

    struct Fixed {
        class: u32,
        features: usize,
        classes: usize,
        batch: usize,
    }

    impl Classifier for Fixed {
        fn info(&self) -> ClassifierInfo {
            ClassifierInfo {
                backend: BackendKind::Forest,
                label: format!("fixed-{}", self.class),
                n_features: self.features,
                n_classes: self.classes,
                size_nodes: 1,
                cost: CostModel {
                    max_steps: Some(0),
                    aggregation_reads: 0,
                    preferred_batch: self.batch,
                },
            }
        }

        fn classify_with_steps(&self, _x: &[f32]) -> crate::error::Result<(u32, Option<usize>)> {
            Ok((self.class, Some(0)))
        }
    }

    fn schema(features: usize, classes: usize) -> Schema {
        Schema {
            features: (0..features)
                .map(|i| crate::data::Feature {
                    name: format!("f{i}"),
                    kind: crate::data::FeatureKind::Numeric,
                })
                .collect(),
            classes: (0..classes).map(|c| format!("c{c}")).collect(),
            task: crate::data::Task::Classification,
        }
    }

    fn fixed(class: u32, batch: usize) -> Arc<dyn Classifier> {
        Arc::new(Fixed {
            class,
            features: 2,
            classes: 3,
            batch,
        })
    }

    #[test]
    fn register_resolve_and_default_model() {
        let reg = ModelRegistry::new();
        assert!(reg.is_empty());
        assert!(reg.get(None).is_err());
        let id = reg
            .register(
                "alpha",
                schema(2, 3),
                vec![(BackendKind::Forest, fixed(1, 1))],
            )
            .unwrap();
        assert_eq!(id.to_string(), "alpha@v1");
        assert_eq!(reg.default_model().as_deref(), Some("alpha"));
        let (version, slot) = reg.resolve(None, None).unwrap();
        assert_eq!(version.id, id);
        assert_eq!(slot.kind, BackendKind::Forest);
        assert!(!slot.batch_first);
        assert_eq!(slot.classifier.classify(&[0.0, 0.0]).unwrap(), 1);
        assert_eq!(version.label_of(1), "c1");
        assert_eq!(version.label_of(99), "class-99");
    }

    #[test]
    fn hot_swap_bumps_version_and_serves_new_model() {
        let reg = ModelRegistry::new();
        reg.register("m", schema(2, 3), vec![(BackendKind::Forest, fixed(0, 1))])
            .unwrap();
        let held = reg.get(Some("m")).unwrap(); // in-flight request holds v1
        let id2 = reg
            .register("m", schema(2, 3), vec![(BackendKind::Forest, fixed(2, 1))])
            .unwrap();
        assert_eq!(id2.version, 2);
        // new resolutions see v2; the held Arc still answers as v1
        let (_, slot) = reg.resolve(Some("m"), None).unwrap();
        assert_eq!(slot.classifier.classify(&[0.0, 0.0]).unwrap(), 2);
        let old = held.slot(BackendKind::Forest).unwrap();
        assert_eq!(old.classifier.classify(&[0.0, 0.0]).unwrap(), 0);
        // versions stay monotonic across remove/re-register
        reg.remove("m").unwrap();
        let id3 = reg
            .register("m", schema(2, 3), vec![(BackendKind::Forest, fixed(1, 1))])
            .unwrap();
        assert_eq!(id3.version, 3);
    }

    #[test]
    fn backend_selection_and_batch_first_flag() {
        let reg = ModelRegistry::new();
        reg.register(
            "m",
            schema(2, 3),
            vec![
                (BackendKind::Forest, fixed(0, 1)),
                (BackendKind::Xla, fixed(0, 64)),
            ],
        )
        .unwrap();
        let (_, xla) = reg.resolve(Some("m"), Some(BackendKind::Xla)).unwrap();
        assert!(xla.batch_first);
        let err = reg.resolve(Some("m"), Some(BackendKind::Dd)).unwrap_err();
        assert!(err.to_string().contains("not available"));
        // no dd backend -> default falls back to the first registered
        let (version, slot) = reg.resolve(Some("m"), None).unwrap();
        assert_eq!(version.default_backend, BackendKind::Forest);
        assert_eq!(slot.kind, BackendKind::Forest);
    }

    #[test]
    fn registration_validates_contracts() {
        let reg = ModelRegistry::new();
        assert!(reg.register("", schema(2, 3), vec![]).is_err());
        assert!(reg.register("m", schema(2, 3), vec![]).is_err());
        // arity mismatch between backend and schema
        let err = reg
            .register("m", schema(5, 3), vec![(BackendKind::Forest, fixed(0, 1))])
            .unwrap_err();
        assert!(matches!(err, Error::SchemaMismatch(_)), "{err}");
        // duplicate backend kind
        let err = reg
            .register(
                "m",
                schema(2, 3),
                vec![
                    (BackendKind::Forest, fixed(0, 1)),
                    (BackendKind::Forest, fixed(1, 1)),
                ],
            )
            .unwrap_err();
        assert!(err.to_string().contains("twice"));
        assert!(reg.is_empty(), "failed registrations must not partially apply");
    }

    #[test]
    fn register_many_is_atomic_and_records_provenance() {
        let reg = ModelRegistry::new();
        let ids = reg
            .register_many(vec![
                ModelSpec {
                    name: "a".into(),
                    schema: schema(2, 3),
                    backends: vec![(BackendKind::Forest, fixed(0, 1))],
                    provenance: Some("fleet.fab#a@v1".into()),
                },
                ModelSpec {
                    name: "b".into(),
                    schema: schema(2, 3),
                    backends: vec![(BackendKind::Forest, fixed(1, 1))],
                    provenance: None,
                },
            ])
            .unwrap();
        assert_eq!(ids.len(), 2);
        assert_eq!(ids[0].to_string(), "a@v1");
        assert_eq!(ids[1].to_string(), "b@v1");
        assert_eq!(
            reg.default_model().as_deref(),
            Some("a"),
            "the batch's first model becomes the default"
        );
        assert_eq!(
            reg.get(Some("a")).unwrap().provenance.as_deref(),
            Some("fleet.fab#a@v1")
        );
        assert!(reg.get(Some("b")).unwrap().provenance.is_none());
        // a duplicate name within the batch fails the whole batch
        let err = reg
            .register_many(vec![
                ModelSpec {
                    name: "c".into(),
                    schema: schema(2, 3),
                    backends: vec![(BackendKind::Forest, fixed(0, 1))],
                    provenance: None,
                },
                ModelSpec {
                    name: "c".into(),
                    schema: schema(2, 3),
                    backends: vec![(BackendKind::Forest, fixed(1, 1))],
                    provenance: None,
                },
            ])
            .unwrap_err();
        assert!(err.to_string().contains("appears twice"), "{err}");
        assert!(reg.get(Some("c")).is_err(), "failed batches must not partially apply");
        // one invalid spec rolls back the valid ones too
        let err = reg
            .register_many(vec![
                ModelSpec {
                    name: "d".into(),
                    schema: schema(2, 3),
                    backends: vec![(BackendKind::Forest, fixed(0, 1))],
                    provenance: None,
                },
                ModelSpec {
                    name: "e".into(),
                    schema: schema(5, 3),
                    backends: vec![(BackendKind::Forest, fixed(0, 1))],
                    provenance: None,
                },
            ])
            .unwrap_err();
        assert!(matches!(err, Error::SchemaMismatch(_)), "{err}");
        assert!(reg.get(Some("d")).is_err());
        assert!(reg.register_many(vec![]).is_err(), "empty batch");
    }

    #[test]
    fn check_row_enforces_arity_and_finiteness() {
        let reg = ModelRegistry::new();
        reg.register("m", schema(2, 3), vec![(BackendKind::Forest, fixed(0, 1))])
            .unwrap();
        let version = reg.get(None).unwrap();
        assert!(version.check_row(&[1.0, 2.0]).is_ok());
        assert!(version.check_row(&[1.0]).is_err());
        assert!(version.check_row(&[f32::NAN, 0.0]).is_err());
        assert!(version.check_row(&[f32::INFINITY, 0.0]).is_err());
        // flat batches: one stride check + one finiteness scan
        let good = [1.0f32, 2.0, 3.0, 4.0];
        assert!(version
            .check_matrix(RowMatrix::new(&good, 2).unwrap())
            .is_ok());
        assert!(version
            .check_matrix(RowMatrix::new(&good, 4).unwrap())
            .is_err());
        let nan = [1.0f32, f32::NAN];
        assert!(version.check_matrix(RowMatrix::new(&nan, 2).unwrap()).is_err());
        assert!(version.check_matrix(RowMatrix::empty()).is_ok());
    }

    #[test]
    fn list_and_default_transfer_on_remove() {
        let reg = ModelRegistry::new();
        reg.register("b", schema(2, 3), vec![(BackendKind::Forest, fixed(0, 1))])
            .unwrap();
        reg.register("a", schema(2, 3), vec![(BackendKind::Forest, fixed(1, 1))])
            .unwrap();
        let names: Vec<String> = reg.list().iter().map(|m| m.id.name.clone()).collect();
        assert_eq!(names, vec!["a", "b"]);
        assert_eq!(reg.default_model().as_deref(), Some("b"));
        reg.set_default("a").unwrap();
        assert!(reg.set_default("zzz").is_err());
        reg.remove("a").unwrap();
        assert_eq!(reg.default_model().as_deref(), Some("b"));
        assert_eq!(reg.len(), 1);
    }
}
