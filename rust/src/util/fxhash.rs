//! FxHash: the Firefox/rustc multiply-xor hasher (public-domain algorithm,
//! reimplemented — the `rustc-hash` crate is unavailable offline).
//!
//! The ADD manager's unique table and operation caches hash tens of
//! millions of small fixed-size keys; SipHash (std's default, DoS-hardened)
//! costs ~3× more than needed for these internal, attacker-free tables.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// Multiply-rotate hasher for small keys (not DoS-resistant — internal use).
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, v: u8) {
        self.add(v as u64);
    }

    #[inline]
    fn write_u16(&mut self, v: u16) {
        self.add(v as u64);
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.add(v as u64);
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.add(v);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.add(v as u64);
    }
}

/// `HashMap` keyed with [`FxHasher`].
pub type FxHashMap<K, V> = HashMap<K, V, BuildHasherDefault<FxHasher>>;
/// `HashSet` keyed with [`FxHasher`].
pub type FxHashSet<T> = HashSet<T, BuildHasherDefault<FxHasher>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distributes_and_roundtrips() {
        let mut m: FxHashMap<(u32, u32, u32), u32> = FxHashMap::default();
        for i in 0..10_000u32 {
            m.insert((i, i / 3, i % 7), i);
        }
        assert_eq!(m.len(), 10_000);
        for i in (0..10_000u32).step_by(37) {
            assert_eq!(m[&(i, i / 3, i % 7)], i);
        }
    }

    #[test]
    fn hashes_differ_for_similar_keys() {
        use std::hash::{BuildHasher, BuildHasherDefault};
        let b: BuildHasherDefault<FxHasher> = BuildHasherDefault::default();
        let h1 = b.hash_one((1u32, 2u32, 3u32));
        let h2 = b.hash_one((1u32, 2u32, 4u32));
        let h3 = b.hash_one((2u32, 2u32, 3u32));
        assert_ne!(h1, h2);
        assert_ne!(h1, h3);
    }

    #[test]
    fn byte_slices_and_strings() {
        let mut s: FxHashSet<String> = FxHashSet::default();
        for w in ["a", "ab", "abc", "abcdefgh", "abcdefghi", ""] {
            s.insert(w.to_string());
        }
        assert_eq!(s.len(), 6);
        assert!(s.contains("abcdefgh"));
    }
}
