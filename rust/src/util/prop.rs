//! Tiny property-based testing engine (proptest is unavailable offline).
//!
//! A property is a closure over a [`Gen`]; the runner executes it for a
//! configurable number of random cases and, on failure, retries the same
//! seed with progressively smaller size budgets — a cheap stand-in for
//! shrinking that in practice reproduces failures at the smallest size that
//! still triggers them. Failures report the seed so a case can be replayed
//! exactly (`FOREST_ADD_PROP_SEED=<n>`).

use crate::util::rng::Rng;

/// Case generator handed to properties: a seeded RNG plus a size budget.
pub struct Gen {
    /// Seeded random source for this case.
    pub rng: Rng,
    /// Size budget; generators should scale structure size with it.
    pub size: usize,
}

impl Gen {
    /// Integer in `[lo, hi]`.
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.range_i64(lo, hi)
    }

    /// usize in `[lo, hi]`.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_i64(lo as i64, hi as i64) as usize
    }

    /// Float in `[lo, hi)`.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Vector with size-scaled length, elements from `f`.
    pub fn vec<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let cap = max_len.min(self.size.max(1));
        let len = self.rng.below_usize(cap + 1);
        (0..len).map(|_| f(self)).collect()
    }

    /// One of the provided choices.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below_usize(xs.len())]
    }
}

/// Configuration for a property run.
pub struct Config {
    /// Number of random cases.
    pub cases: usize,
    /// Maximum size budget (cases sweep sizes `1..=max_size` cyclically).
    pub max_size: usize,
    /// Base seed; `FOREST_ADD_PROP_SEED` overrides.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        let seed = std::env::var("FOREST_ADD_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xF0E2_57AD);
        Config {
            cases: 100,
            max_size: 20,
            seed,
        }
    }
}

/// Run a property; panics with the failing seed/size on the first failure.
///
/// The property returns `Err(description)` (or panics) to signal failure.
pub fn check<F>(name: &str, cfg: Config, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let size = 1 + case % cfg.max_size;
        let mut g = Gen {
            rng: Rng::new(case_seed),
            size,
        };
        if let Err(msg) = prop(&mut g) {
            // "Shrink": replay the same seed at smaller sizes, report the
            // smallest size that still fails.
            let mut smallest = (size, msg);
            for s in 1..size {
                let mut g = Gen {
                    rng: Rng::new(case_seed),
                    size: s,
                };
                if let Err(m) = prop(&mut g) {
                    smallest = (s, m);
                    break;
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {case_seed:#x}, size {}):\n  {}",
                smallest.0, smallest.1
            );
        }
    }
}

/// `check` with default configuration.
pub fn quickcheck<F>(name: &str, prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    check(name, Config::default(), prop)
}

/// Assertion helper for properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err(format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut runs = 0;
        check(
            "addition commutes",
            Config {
                cases: 50,
                ..Config::default()
            },
            |g| {
                runs += 1;
                let a = g.int(-1000, 1000);
                let b = g.int(-1000, 1000);
                prop_assert!(a + b == b + a, "a={a} b={b}");
                Ok(())
            },
        );
        assert_eq!(runs, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        quickcheck("always fails", |g| {
            let v = g.usize(0, 10);
            prop_assert!(v > 100, "v={v}");
            Ok(())
        });
    }

    #[test]
    fn vec_respects_size_budget() {
        check(
            "vec size",
            Config {
                cases: 30,
                max_size: 5,
                seed: 1,
            },
            |g| {
                let v = g.vec(100, |g| g.int(0, 1));
                prop_assert!(v.len() <= 5, "len={}", v.len());
                Ok(())
            },
        );
    }
}
