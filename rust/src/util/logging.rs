//! Legacy home of the leveled logger — the implementation lives in
//! [`crate::obs::log`] now (where it grew JSON-lines output and
//! `serve --log-level` wiring). This shim keeps the `log_*!` macro
//! expansion paths (`$crate::util::logging::emit`) and historical
//! imports resolving; the macros themselves are still exported from
//! here so every existing call site compiles unchanged.

pub use crate::obs::log::{emit, enabled, init, max_level, set_max_level, Level};

/// Log at error level.
#[macro_export]
macro_rules! log_error { ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*)) } }
/// Log at warn level.
#[macro_export]
macro_rules! log_warn { ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*)) } }
/// Log at info level.
#[macro_export]
macro_rules! log_info { ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*)) } }
/// Log at debug level.
#[macro_export]
macro_rules! log_debug { ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*)) } }
/// Log at trace level.
#[macro_export]
macro_rules! log_trace { ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Trace, module_path!(), format_args!($($arg)*)) } }
