//! Minimal leveled logger (the `log`/`env_logger` pairing is unavailable
//! offline; `log` alone ships no emitter).
//!
//! Level is controlled by the `FOREST_ADD_LOG` environment variable
//! (`error|warn|info|debug|trace`, default `info`). Output goes to stderr
//! with elapsed-time stamps so serving traces are greppable.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity, ordered.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn from_env() -> Level {
        match std::env::var("FOREST_ADD_LOG").as_deref() {
            Ok("error") => Level::Error,
            Ok("warn") => Level::Warn,
            Ok("debug") => Level::Debug,
            Ok("trace") => Level::Trace,
            _ => Level::Info,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static START: OnceLock<Instant> = OnceLock::new();

/// Current max level, lazily initialised from the environment.
pub fn max_level() -> Level {
    let raw = MAX_LEVEL.load(Ordering::Relaxed);
    if raw == u8::MAX {
        let lvl = Level::from_env();
        MAX_LEVEL.store(lvl as u8, Ordering::Relaxed);
        lvl
    } else {
        match raw {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            3 => Level::Debug,
            _ => Level::Trace,
        }
    }
}

/// Override the level programmatically (tests, `--quiet`).
pub fn set_max_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// True when `level` would be emitted.
pub fn enabled(level: Level) -> bool {
    level <= max_level()
}

/// Emit a record (used via the `log_*!` macros).
pub fn emit(level: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let start = START.get_or_init(Instant::now);
    let t = start.elapsed();
    let mut err = std::io::stderr().lock();
    let _ = writeln!(
        err,
        "[{:>8.3}s {} {}] {}",
        t.as_secs_f64(),
        level.tag(),
        target,
        msg
    );
}

/// Log at error level.
#[macro_export]
macro_rules! log_error { ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*)) } }
/// Log at warn level.
#[macro_export]
macro_rules! log_warn { ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*)) } }
/// Log at info level.
#[macro_export]
macro_rules! log_info { ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*)) } }
/// Log at debug level.
#[macro_export]
macro_rules! log_debug { ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*)) } }
/// Log at trace level.
#[macro_export]
macro_rules! log_trace { ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Trace, module_path!(), format_args!($($arg)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Trace);
    }

    #[test]
    fn set_level_gates() {
        set_max_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_max_level(Level::Info);
        assert!(enabled(Level::Info));
    }

    #[test]
    fn macros_compile_and_run() {
        set_max_level(Level::Error);
        log_info!("hidden {}", 1);
        log_error!("shown {}", 2);
        set_max_level(Level::Info);
    }
}
