//! Command-line argument parsing substrate (clap is unavailable offline).
//!
//! Declarative enough for this project's CLI: subcommands with typed flags
//! (`--name value`, `--name=value`, boolean switches), positionals, defaults,
//! and generated `--help` text.

use crate::error::{Error, Result};
use std::collections::HashMap;

/// Kind of a declared argument.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    /// `--flag` (no value, presence = true)
    Switch,
    /// `--opt <value>`
    Value,
    /// bare positional argument
    Positional,
}

#[derive(Debug, Clone)]
struct Spec {
    name: &'static str,
    kind: Kind,
    help: &'static str,
    default: Option<String>,
    required: bool,
}

/// A declarative command-line parser for one (sub)command.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    command: String,
    about: &'static str,
    specs: Vec<Spec>,
}

/// Parsed argument values.
#[derive(Debug, Clone)]
pub struct Args {
    values: HashMap<&'static str, String>,
    switches: HashMap<&'static str, bool>,
}

impl ArgSpec {
    /// New spec for a command (used in help output).
    pub fn new(command: impl Into<String>, about: &'static str) -> Self {
        ArgSpec {
            command: command.into(),
            about,
            specs: Vec::new(),
        }
    }

    /// Declare a boolean switch `--name`.
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(Spec {
            name,
            kind: Kind::Switch,
            help,
            default: None,
            required: false,
        });
        self
    }

    /// Declare `--name <value>` with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.specs.push(Spec {
            name,
            kind: Kind::Value,
            help,
            default: Some(default.to_string()),
            required: false,
        });
        self
    }

    /// Declare a required `--name <value>`.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(Spec {
            name,
            kind: Kind::Value,
            help,
            default: None,
            required: true,
        });
        self
    }

    /// Declare a positional argument (filled in declaration order).
    pub fn positional(mut self, name: &'static str, help: &'static str, required: bool) -> Self {
        self.specs.push(Spec {
            name,
            kind: Kind::Positional,
            help,
            default: None,
            required,
        });
        self
    }

    /// Render `--help` text.
    pub fn help_text(&self) -> String {
        let mut out = format!("{}\n\n{}\n\nUSAGE:\n  {}", self.about, "", self.command);
        for s in &self.specs {
            if s.kind == Kind::Positional {
                out.push_str(&format!(
                    " {}",
                    if s.required {
                        format!("<{}>", s.name)
                    } else {
                        format!("[{}]", s.name)
                    }
                ));
            }
        }
        out.push_str(" [OPTIONS]\n\nOPTIONS:\n");
        for s in &self.specs {
            let left = match s.kind {
                Kind::Switch => format!("--{}", s.name),
                Kind::Value => format!("--{} <v>", s.name),
                Kind::Positional => format!("<{}>", s.name),
            };
            let default = match &s.default {
                Some(d) if !d.is_empty() => format!(" [default: {d}]"),
                _ => String::new(),
            };
            out.push_str(&format!("  {left:<24} {}{}\n", s.help, default));
        }
        out
    }

    /// Parse a token list (without the program/subcommand names).
    pub fn parse(&self, tokens: &[String]) -> Result<Args> {
        let mut values: HashMap<&'static str, String> = HashMap::new();
        let mut switches: HashMap<&'static str, bool> = HashMap::new();
        for s in &self.specs {
            if let Some(d) = &s.default {
                values.insert(s.name, d.clone());
            }
            if s.kind == Kind::Switch {
                switches.insert(s.name, false);
            }
        }
        let positionals: Vec<&Spec> = self
            .specs
            .iter()
            .filter(|s| s.kind == Kind::Positional)
            .collect();
        let mut next_positional = 0;

        let mut i = 0;
        while i < tokens.len() {
            let tok = &tokens[i];
            if tok == "--help" || tok == "-h" {
                return Err(Error::invalid(self.help_text()));
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name && s.kind != Kind::Positional)
                    .ok_or_else(|| {
                        Error::invalid(format!(
                            "unknown option --{name} for '{}' (try --help)",
                            self.command
                        ))
                    })?;
                match spec.kind {
                    Kind::Switch => {
                        if inline.is_some() {
                            return Err(Error::invalid(format!("--{name} takes no value")));
                        }
                        switches.insert(spec.name, true);
                    }
                    Kind::Value => {
                        let v = match inline {
                            Some(v) => v,
                            None => {
                                i += 1;
                                tokens
                                    .get(i)
                                    .cloned()
                                    .ok_or_else(|| Error::invalid(format!("--{name} needs a value")))?
                            }
                        };
                        values.insert(spec.name, v);
                    }
                    Kind::Positional => unreachable!(),
                }
            } else {
                let spec = positionals.get(next_positional).ok_or_else(|| {
                    Error::invalid(format!("unexpected positional argument '{tok}'"))
                })?;
                values.insert(spec.name, tok.clone());
                next_positional += 1;
            }
            i += 1;
        }

        for s in &self.specs {
            if s.required && !values.contains_key(s.name) {
                return Err(Error::invalid(format!(
                    "missing required argument --{} (try --help)",
                    s.name
                )));
            }
        }
        Ok(Args { values, switches })
    }
}

impl Args {
    /// String value (panics only on undeclared names — programmer error).
    pub fn get(&self, name: &'static str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Required-at-declaration or defaulted string value.
    pub fn str(&self, name: &'static str) -> &str {
        self.get(name)
            .unwrap_or_else(|| panic!("argument --{name} was not declared with a default"))
    }

    /// Parsed integer value.
    pub fn usize(&self, name: &'static str) -> Result<usize> {
        self.str(name)
            .parse()
            .map_err(|_| Error::invalid(format!("--{name} must be an unsigned integer")))
    }

    /// Parsed u64 value.
    pub fn u64(&self, name: &'static str) -> Result<u64> {
        self.str(name)
            .parse()
            .map_err(|_| Error::invalid(format!("--{name} must be an unsigned integer")))
    }

    /// Parsed float value.
    pub fn f64(&self, name: &'static str) -> Result<f64> {
        self.str(name)
            .parse()
            .map_err(|_| Error::invalid(format!("--{name} must be a number")))
    }

    /// Switch presence.
    pub fn flag(&self, name: &'static str) -> bool {
        *self.switches.get(name).unwrap_or(&false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("forest-add train", "Train a random forest")
            .req("dataset", "dataset name")
            .opt("trees", "100", "number of trees")
            .opt("seed", "42", "rng seed")
            .switch("quiet", "suppress logs")
            .positional("out", "output path", false)
    }

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_values_defaults_switches() {
        let a = spec()
            .parse(&toks(&["--dataset", "iris", "--trees=500", "--quiet", "model.json"]))
            .unwrap();
        assert_eq!(a.str("dataset"), "iris");
        assert_eq!(a.usize("trees").unwrap(), 500);
        assert_eq!(a.u64("seed").unwrap(), 42);
        assert!(a.flag("quiet"));
        assert_eq!(a.get("out"), Some("model.json"));
    }

    #[test]
    fn missing_required_rejected() {
        let err = spec().parse(&toks(&["--trees", "5"])).unwrap_err();
        assert!(err.to_string().contains("--dataset"));
    }

    #[test]
    fn unknown_option_rejected() {
        let err = spec()
            .parse(&toks(&["--dataset", "iris", "--bogus", "1"]))
            .unwrap_err();
        assert!(err.to_string().contains("--bogus"));
    }

    #[test]
    fn bad_int_rejected() {
        let a = spec()
            .parse(&toks(&["--dataset", "iris", "--trees", "many"]))
            .unwrap();
        assert!(a.usize("trees").is_err());
    }

    #[test]
    fn help_lists_options() {
        let h = spec().help_text();
        assert!(h.contains("--trees"));
        assert!(h.contains("[default: 100]"));
        assert!(h.contains("--dataset <v>"));
        assert!(h.contains("[out]"));
    }

    #[test]
    fn extra_positional_rejected() {
        let err = spec()
            .parse(&toks(&["--dataset", "iris", "a", "b"]))
            .unwrap_err();
        assert!(err.to_string().contains("unexpected positional"));
    }
}
