//! Minimal JSON substrate (serde is unavailable offline).
//!
//! Covers the needs of this system: artifact `meta.json` sidecars, server
//! configuration files, the HTTP API payloads, and bench result dumps.
//! Full RFC 8259 parsing (strings with escapes incl. `\uXXXX`, numbers,
//! nesting) and a writer with stable key order (objects preserve insertion
//! order).

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always carried as `f64`).
    Num(f64),
    /// A string (escapes already decoded).
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// Objects keep insertion order for stable, diff-friendly output.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::parse(format!(
                "trailing characters at byte {} in JSON document",
                p.pos
            )));
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Numeric accessor.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer accessor (numbers that round-trip exactly).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9.0e15 => Some(*n as i64),
            _ => None,
        }
    }

    /// Convenience: `get(key)` then `as_i64`.
    pub fn get_i64(&self, key: &str) -> Option<i64> {
        self.get(key).and_then(Json::as_i64)
    }

    /// Convenience: `get(key)` then `as_str`.
    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Json::as_str)
    }

    /// String accessor.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Bool accessor.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array accessor.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Compact single-line rendering.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty rendering with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                write_seq(out, indent, depth, '[', ']', items.len(), |out, i, d| {
                    items[i].write(out, indent, d)
                })
            }
            Json::Obj(fields) => {
                write_seq(out, indent, depth, '{', '}', fields.len(), |out, i, d| {
                    let (k, v) = &fields[i];
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, d)
                })
            }
        }
    }
}

fn write_seq(
    out: &mut String,
    indent: Option<usize>,
    depth: usize,
    open: char,
    close: char,
    len: usize,
    mut item: impl FnMut(&mut String, usize, usize),
) {
    out.push(open);
    if len == 0 {
        out.push(close);
        return;
    }
    for i in 0..len {
        if i > 0 {
            out.push(',');
        }
        if let Some(w) = indent {
            out.push('\n');
            for _ in 0..(w * (depth + 1)) {
                out.push(' ');
            }
        }
        item(out, i, depth + 1);
    }
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..(w * depth) {
            out.push(' ');
        }
    }
    out.push(close);
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; serialise as null like most tolerant writers.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::parse(format!("JSON at byte {}: {msg}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let start = self.pos;
            while let Some(c) = self.peek() {
                if c == b'"' || c == b'\\' {
                    break;
                }
                self.pos += 1;
            }
            s.push_str(
                std::str::from_utf8(&self.bytes[start..self.pos])
                    .map_err(|_| self.err("invalid UTF-8 in string"))?,
            );
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("dangling escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                // surrogate pair
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let c =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| self.err("invalid \\u escape"))?);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => return Err(self.err("unterminated string")),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

/// Builder helpers for constructing objects in code.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Numeric literal helper.
pub fn num(n: f64) -> Json {
    Json::Num(n)
}

/// String literal helper.
pub fn s(v: impl Into<String>) -> Json {
    Json::Str(v.into())
}

/// Sorted-key object from a BTreeMap (stable output regardless of build order).
pub fn obj_sorted(map: BTreeMap<String, Json>) -> Json {
    Json::Obj(map.into_iter().collect())
}

/// A copy with every occurrence of `key` removed from objects at any
/// depth (e.g. dropping per-request `latency_us` before comparing
/// responses for bit-identity).
pub fn strip_key(v: &Json, key: &str) -> Json {
    match v {
        Json::Obj(fields) => Json::Obj(
            fields
                .iter()
                .filter(|(k, _)| k != key)
                .map(|(k, x)| (k.clone(), strip_key(x, key)))
                .collect(),
        ),
        Json::Arr(items) => Json::Arr(items.iter().map(|x| strip_key(x, key)).collect()),
        other => other.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strip_key_removes_at_every_depth() {
        let v = Json::parse(
            r#"{"a": 1, "latency_us": 9, "nested": {"latency_us": 3, "b": [{"latency_us": 4, "c": 2}]}}"#,
        )
        .unwrap();
        let stripped = strip_key(&v, "latency_us");
        assert_eq!(
            stripped.to_string_compact(),
            r#"{"a":1,"nested":{"b":[{"c":2}]}}"#
        );
        // untouched values compare equal after stripping on both sides
        assert_eq!(strip_key(&stripped, "latency_us"), stripped);
    }

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_i64(), Some(2));
        assert_eq!(arr[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\ é 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\ é 😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"base","trees":128,"ok":true,"xs":[1.5,-2,null]}"#;
        let v = Json::parse(src).unwrap();
        let compact = v.to_string_compact();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
        assert!(pretty.contains("\n  \"trees\": 128"));
    }

    #[test]
    fn real_meta_json_parses() {
        let src = r#"{
  "batch": 64, "block_trees": 16, "classes": 8, "depth": 8,
  "features": 16, "hlo_chars": 26907, "hlo_file": "forest_base.hlo.txt",
  "n_leaves": 256, "n_nodes": 255, "name": "base", "trees": 128,
  "vmem_block_bytes": 67456
}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get_i64("trees"), Some(128));
        assert_eq!(v.get_str("name"), Some("base"));
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(128.0).to_string_compact(), "128");
        assert_eq!(Json::Num(1.25).to_string_compact(), "1.25");
    }

    #[test]
    fn builder_helpers() {
        let v = obj(vec![("a", num(1.0)), ("b", s("x"))]);
        assert_eq!(v.to_string_compact(), r#"{"a":1,"b":"x"}"#);
    }
}
