//! Fixed-capacity bitset used for predicate support sets on ADD nodes.
//!
//! Support sets drive the memo-key canonicalisation in unsatisfiable-path
//! elimination (only the store dimensions a node actually tests may appear
//! in its cache key), so this type is on the compilation hot path: it is a
//! plain `Vec<u64>` with word-wise ops and no bounds remapping.

/// A fixed-size set of small integers backed by 64-bit words.
#[derive(Debug, Clone, PartialEq, Eq, Hash, Default)]
pub struct BitSet {
    words: Vec<u64>,
    len: usize,
}

impl BitSet {
    /// Empty set with capacity for values `0..len`.
    pub fn new(len: usize) -> Self {
        BitSet {
            words: vec![0; len.div_ceil(64)],
            len,
        }
    }

    /// Capacity (maximum value + 1).
    pub fn capacity(&self) -> usize {
        self.len
    }

    /// Insert `i`.
    #[inline]
    pub fn insert(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / 64] |= 1u64 << (i % 64);
    }

    /// Remove `i`.
    #[inline]
    pub fn remove(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / 64] &= !(1u64 << (i % 64));
    }

    /// Membership test.
    #[inline]
    pub fn contains(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        self.words[i / 64] >> (i % 64) & 1 == 1
    }

    /// Number of elements.
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// True when no element is present.
    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// In-place union.
    pub fn union_with(&mut self, other: &BitSet) {
        debug_assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= *b;
        }
    }

    /// In-place intersection.
    pub fn intersect_with(&mut self, other: &BitSet) {
        debug_assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= *b;
        }
    }

    /// True when `self` and `other` share at least one element.
    pub fn intersects(&self, other: &BitSet) -> bool {
        self.words.iter().zip(&other.words).any(|(a, b)| a & b != 0)
    }

    /// True when every element of `self` is in `other`.
    pub fn is_subset(&self, other: &BitSet) -> bool {
        self.words.iter().zip(&other.words).all(|(a, b)| a & !b == 0)
    }

    /// Iterate set elements in increasing order.
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut w = w;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let b = w.trailing_zeros() as usize;
                    w &= w - 1;
                    Some(wi * 64 + b)
                }
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_contains_remove() {
        let mut s = BitSet::new(130);
        for i in [0, 1, 63, 64, 65, 128, 129] {
            assert!(!s.contains(i));
            s.insert(i);
            assert!(s.contains(i));
        }
        assert_eq!(s.count(), 7);
        s.remove(64);
        assert!(!s.contains(64));
        assert_eq!(s.count(), 6);
    }

    #[test]
    fn union_and_intersection() {
        let mut a = BitSet::new(100);
        let mut b = BitSet::new(100);
        a.insert(3);
        a.insert(70);
        b.insert(70);
        b.insert(99);
        assert!(a.intersects(&b));
        let mut u = a.clone();
        u.union_with(&b);
        assert_eq!(u.iter().collect::<Vec<_>>(), vec![3, 70, 99]);
        let mut i = a.clone();
        i.intersect_with(&b);
        assert_eq!(i.iter().collect::<Vec<_>>(), vec![70]);
    }

    #[test]
    fn subset() {
        let mut a = BitSet::new(10);
        let mut b = BitSet::new(10);
        a.insert(2);
        b.insert(2);
        b.insert(5);
        assert!(a.is_subset(&b));
        assert!(!b.is_subset(&a));
        assert!(BitSet::new(10).is_subset(&a));
    }

    #[test]
    fn iter_order() {
        let mut s = BitSet::new(256);
        for i in [255, 0, 64, 63, 100] {
            s.insert(i);
        }
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![0, 63, 64, 100, 255]);
    }

    #[test]
    fn empty() {
        let s = BitSet::new(0);
        assert!(s.is_empty());
        assert_eq!(s.count(), 0);
        let mut t = BitSet::new(65);
        assert!(t.is_empty());
        t.insert(64);
        assert!(!t.is_empty());
    }
}
