//! Deterministic, seedable PRNG substrate.
//!
//! The crates.io registry is unavailable in this environment, so the library
//! ships its own small generator: **xoshiro256++** seeded via **SplitMix64**
//! (the reference seeding procedure from Blackman & Vigna). Every stochastic
//! component in the system (bootstrap sampling, random feature subsets,
//! synthetic datasets, workload generators, property tests) draws from this
//! type, so a run is fully reproducible from one `u64` seed.

/// xoshiro256++ PRNG with SplitMix64 seeding and deterministic stream forks.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent child stream; `(seed, id)` pairs map to
    /// distinct, reproducible streams (used to give every tree in a forest
    /// its own generator regardless of build order or parallelism).
    pub fn fork(&self, id: u64) -> Rng {
        // Mix the current state with the id through SplitMix64 so forks of
        // forks stay decorrelated.
        let mut sm = self
            .s
            .iter()
            .fold(id ^ 0xA076_1D64_78BD_642F, |acc, &w| {
                acc.rotate_left(17) ^ w.wrapping_mul(0xE703_7ED1_A0B4_28DB)
            });
        Rng::new(splitmix64(&mut sm))
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next raw 32 random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform integer in `[0, bound)` (Lemire's multiply-shift with
    /// rejection; unbiased). `bound` must be non-zero.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0, "Rng::below called with bound 0");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform `usize` in `[0, bound)`.
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + self.below(span) as i64
    }

    /// Uniform float in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform float in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.unit_f64()
    }

    /// Bernoulli draw with success probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.unit_f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast
    /// here — the normal path is only used by synthetic data generators).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.unit_f64();
            if u > 1e-12 {
                let v = self.unit_f64();
                return (-2.0 * u.ln()).sqrt() * (std::f64::consts::TAU * v).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose one element uniformly (None on empty input).
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> Option<&'a T> {
        if xs.is_empty() {
            None
        } else {
            Some(&xs[self.below_usize(xs.len())])
        }
    }

    /// `k` distinct indices drawn from `[0, n)` (partial Fisher–Yates),
    /// in random order. Used for random feature subsets.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below_usize(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// `n` bootstrap indices (sampling with replacement from `[0, n)`).
    pub fn bootstrap(&mut self, n: usize) -> Vec<usize> {
        (0..n).map(|_| self.below_usize(n)).collect()
    }

    /// Weighted categorical draw; `weights` need not be normalised.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut u = self.unit_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            if u < w {
                return i;
            }
            u -= w;
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn forks_are_independent_and_reproducible() {
        let root = Rng::new(99);
        let mut c1 = root.fork(0);
        let mut c2 = root.fork(1);
        let mut c1b = root.fork(0);
        assert_eq!(c1.next_u64(), c1b.next_u64());
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn unit_f64_in_range_and_roughly_uniform() {
        let mut r = Rng::new(11);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let u = r.unit_f64();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        for _ in 0..50 {
            let s = r.sample_indices(20, 7);
            assert_eq!(s.len(), 7);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 7);
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn bootstrap_len_and_range() {
        let mut r = Rng::new(6);
        let b = r.bootstrap(100);
        assert_eq!(b.len(), 100);
        assert!(b.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(17);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let p2 = counts[2] as f64 / 30_000.0;
        assert!((p2 - 0.7).abs() < 0.03, "p2 {p2}");
    }
}
