//! General-purpose substrates built in-tree because the crates.io registry
//! is unreachable in this environment: RNG, JSON, bitsets, CLI parsing,
//! logging, a property-testing engine, and table formatting.

pub mod argparse;
pub mod bitset;
pub mod fxhash;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod table;
