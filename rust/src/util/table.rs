//! Console table formatting for the bench harness and CLI reports.
//!
//! Produces the aligned plain-text tables printed by `cargo bench` (the rows
//! that mirror the paper's Tables 1/2 and the Fig. 6/7 series) plus CSV and
//! Markdown renderings for EXPERIMENTS.md.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    /// Pad on the right (text columns).
    Left,
    /// Pad on the left (numeric columns).
    Right,
}

/// A simple text table builder.
#[derive(Debug, Clone)]
pub struct Table {
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with column headers (all right-aligned except the first).
    pub fn new(headers: &[&str]) -> Self {
        let aligns = headers
            .iter()
            .enumerate()
            .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
            .collect();
        Table {
            headers: headers.iter().map(|h| h.to_string()).collect(),
            aligns,
            rows: Vec::new(),
        }
    }

    /// Override column alignments.
    pub fn aligns(mut self, aligns: &[Align]) -> Self {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns.to_vec();
        self
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row arity does not match headers"
        );
        self.rows.push(cells);
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when the table has no rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    fn fmt_cell(cell: &str, width: usize, align: Align) -> String {
        let pad = width.saturating_sub(cell.chars().count());
        match align {
            Align::Left => format!("{cell}{}", " ".repeat(pad)),
            Align::Right => format!("{}{cell}", " ".repeat(pad)),
        }
    }

    /// Aligned plain-text rendering.
    pub fn to_text(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let render = |cells: &[String], out: &mut String| {
            let line: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| Self::fmt_cell(c, w[i], self.aligns[i]))
                .collect();
            out.push_str(line.join("  ").trim_end());
            out.push('\n');
        };
        render(&self.headers, &mut out);
        out.push_str(&format!(
            "{}\n",
            w.iter()
                .map(|n| "-".repeat(*n))
                .collect::<Vec<_>>()
                .join("  ")
        ));
        for row in &self.rows {
            render(row, &mut out);
        }
        out
    }

    /// CSV rendering (quotes cells containing separators).
    pub fn to_csv(&self) -> String {
        let esc = |c: &str| {
            if c.contains([',', '"', '\n']) {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// GitHub-flavoured Markdown rendering.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        let seps: Vec<&str> = self
            .aligns
            .iter()
            .map(|a| match a {
                Align::Left => ":--",
                Align::Right => "--:",
            })
            .collect();
        out.push_str(&format!("| {} |\n", seps.join(" | ")));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }
}

/// Format a float with thousands separators and `digits` decimals
/// (e.g. `69,216.62` — the paper's table style).
pub fn fmt_thousands(v: f64, digits: usize) -> String {
    let neg = v < 0.0;
    let s = format!("{:.*}", digits, v.abs());
    let (int_part, frac) = match s.split_once('.') {
        Some((i, f)) => (i.to_string(), Some(f.to_string())),
        None => (s, None),
    };
    let mut grouped = String::new();
    let chars: Vec<char> = int_part.chars().collect();
    for (i, c) in chars.iter().enumerate() {
        if i > 0 && (chars.len() - i) % 3 == 0 {
            grouped.push(',');
        }
        grouped.push(*c);
    }
    let mut out = String::new();
    if neg {
        out.push('-');
    }
    out.push_str(&grouped);
    if let Some(f) = frac {
        out.push('.');
        out.push_str(&f);
    }
    out
}

/// Percent-reduction cell in the paper's style, e.g. `-99.99%`.
pub fn fmt_reduction(before: f64, after: f64) -> String {
    if before <= 0.0 {
        return "n/a".to_string();
    }
    let pct = (after - before) / before * 100.0;
    format!("{pct:+.2}%")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligned_text() {
        let mut t = Table::new(&["Dataset", "RF", "DD*"]);
        t.row(vec!["Iris".into(), "42,860.96".into(), "7.01".into()]);
        t.row(vec!["Vote".into(), "69,216.62".into(), "8.30".into()]);
        let text = t.to_text();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("Dataset"));
        assert!(lines[2].contains("42,860.96"));
        // right alignment: numbers end at the same column
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        assert_eq!(t.to_csv(), "a,b\n\"x,y\",\"q\"\"z\"\n");
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new(&["k", "v"]);
        t.row(vec!["a".into(), "1".into()]);
        let md = t.to_markdown();
        assert!(md.starts_with("| k | v |\n| :-- | --: |\n| a | 1 |\n"));
    }

    #[test]
    fn thousands_formatting() {
        assert_eq!(fmt_thousands(69216.62, 2), "69,216.62");
        assert_eq!(fmt_thousands(8.3, 2), "8.30");
        assert_eq!(fmt_thousands(988358.0, 0), "988,358");
        assert_eq!(fmt_thousands(-1234.5, 1), "-1,234.5");
        assert_eq!(fmt_thousands(999.0, 0), "999");
        assert_eq!(fmt_thousands(1000.0, 0), "1,000");
    }

    #[test]
    fn reduction_formatting() {
        assert_eq!(fmt_reduction(100.0, 0.01), "-99.99%");
        assert_eq!(fmt_reduction(0.0, 5.0), "n/a");
        assert_eq!(fmt_reduction(10.0, 15.0), "+50.00%");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only".into()]);
    }
}
