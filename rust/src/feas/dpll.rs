//! DPLL(T): a small propositional solver with theory propagation.
//!
//! This is the generic "SMT solving" interface of the paper's §5. The
//! production reducer talks to the [`IntervalStore`](super::IntervalStore)
//! directly (the occurring theory is a conjunction of interval literals,
//! decidable without search), but this solver provides:
//!
//! - the general entry point for richer predicate theories (disjunctive
//!   side conditions, cross-feature constraints),
//! - an independent oracle the test suite uses to cross-check the reducer
//!   (every surviving DD path must be T-satisfiable, every eliminated one
//!   T-unsatisfiable).
//!
//! Implementation: iterative DPLL with unit propagation over CNF clauses;
//! every assignment is forwarded to the theory, whose veto triggers
//! backtracking.

use crate::predicate::{Domain, Predicate};

use super::IntervalStore;

/// A literal: variable index with polarity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Lit {
    /// Variable index.
    pub var: usize,
    /// True for the positive literal.
    pub positive: bool,
}

impl Lit {
    /// Positive literal.
    pub fn pos(var: usize) -> Lit {
        Lit {
            var,
            positive: true,
        }
    }

    /// Negative literal.
    pub fn neg(var: usize) -> Lit {
        Lit {
            var,
            positive: false,
        }
    }
}

/// Theory hook: observes assignments, can veto (report T-conflict).
pub trait Theory {
    /// Called on every assignment; return `false` to signal a conflict.
    fn on_assign(&mut self, var: usize, value: bool) -> bool;
    /// Snapshot for backtracking.
    fn mark(&self) -> usize;
    /// Restore a snapshot.
    fn undo_to(&mut self, mark: usize);
}

/// A trivially-true theory (pure SAT).
pub struct NoTheory;

impl Theory for NoTheory {
    fn on_assign(&mut self, _var: usize, _value: bool) -> bool {
        true
    }
    fn mark(&self) -> usize {
        0
    }
    fn undo_to(&mut self, _mark: usize) {}
}

/// Interval theory over threshold predicates: variable `i` ⇔ `preds[i]`.
pub struct IntervalTheory {
    preds: Vec<Predicate>,
    store: IntervalStore,
}

impl IntervalTheory {
    /// Theory where propositional variable `i` denotes `preds[i]`.
    pub fn new(domains: &[Domain], preds: Vec<Predicate>) -> Self {
        IntervalTheory {
            preds,
            store: IntervalStore::new(domains),
        }
    }
}

impl Theory for IntervalTheory {
    fn on_assign(&mut self, var: usize, value: bool) -> bool {
        let p = self.preds[var];
        match self.store.implied(p) {
            Some(v) => v == value,
            None => {
                self.store.assume(p, value);
                true
            }
        }
    }
    fn mark(&self) -> usize {
        self.store.mark()
    }
    fn undo_to(&mut self, mark: usize) {
        self.store.undo_to(mark)
    }
}

/// CNF formula + DPLL search.
pub struct Solver {
    n_vars: usize,
    clauses: Vec<Vec<Lit>>,
}

impl Solver {
    /// Solver over `n_vars` variables.
    pub fn new(n_vars: usize) -> Self {
        Solver {
            n_vars,
            clauses: Vec::new(),
        }
    }

    /// Add a disjunctive clause.
    pub fn add_clause(&mut self, lits: Vec<Lit>) {
        debug_assert!(lits.iter().all(|l| l.var < self.n_vars));
        self.clauses.push(lits);
    }

    /// Add a unit (forced literal).
    pub fn add_unit(&mut self, lit: Lit) {
        self.add_clause(vec![lit]);
    }

    /// Find a T-satisfying assignment, or `None` when T-unsatisfiable.
    pub fn solve<T: Theory>(&self, theory: &mut T) -> Option<Vec<bool>> {
        let mut assign: Vec<Option<bool>> = vec![None; self.n_vars];
        if self.search(&mut assign, theory) {
            Some(assign.into_iter().map(|a| a.unwrap_or(false)).collect())
        } else {
            None
        }
    }

    /// Clause status under partial assignment: `Some(true)` satisfied,
    /// `Some(false)` conflicting, `None` undecided.
    fn clause_state(&self, clause: &[Lit], assign: &[Option<bool>]) -> Option<bool> {
        let mut undecided = false;
        for l in clause {
            match assign[l.var] {
                Some(v) if v == l.positive => return Some(true),
                Some(_) => {}
                None => undecided = true,
            }
        }
        if undecided {
            None
        } else {
            Some(false)
        }
    }

    fn unit_literal(&self, clause: &[Lit], assign: &[Option<bool>]) -> Option<Lit> {
        let mut unit = None;
        for l in clause {
            match assign[l.var] {
                Some(v) if v == l.positive => return None, // satisfied
                Some(_) => {}
                None => {
                    if unit.is_some() {
                        return None; // two unassigned
                    }
                    unit = Some(*l);
                }
            }
        }
        unit
    }

    fn search<T: Theory>(&self, assign: &mut Vec<Option<bool>>, theory: &mut T) -> bool {
        let t_mark = theory.mark();
        let mut trail: Vec<usize> = Vec::new();

        // Unit propagation to fixpoint.
        loop {
            let mut progressed = false;
            for clause in &self.clauses {
                match self.clause_state(clause, assign) {
                    Some(false) => {
                        self.rollback(assign, theory, &trail, t_mark);
                        return false;
                    }
                    Some(true) => {}
                    None => {
                        if let Some(l) = self.unit_literal(clause, assign) {
                            assign[l.var] = Some(l.positive);
                            trail.push(l.var);
                            if !theory.on_assign(l.var, l.positive) {
                                self.rollback(assign, theory, &trail, t_mark);
                                return false;
                            }
                            progressed = true;
                        }
                    }
                }
            }
            if !progressed {
                break;
            }
        }

        // Pick a branch variable.
        let var = match assign.iter().position(|a| a.is_none()) {
            Some(v) => v,
            None => return true, // complete assignment, all clauses satisfied
        };
        for value in [true, false] {
            let inner_mark = theory.mark();
            assign[var] = Some(value);
            if theory.on_assign(var, value) && self.search(assign, theory) {
                return true;
            }
            assign[var] = None;
            theory.undo_to(inner_mark);
        }
        self.rollback(assign, theory, &trail, t_mark);
        false
    }

    fn rollback<T: Theory>(
        &self,
        assign: &mut [Option<bool>],
        theory: &mut T,
        trail: &[usize],
        t_mark: usize,
    ) {
        for &v in trail {
            assign[v] = None;
        }
        theory.undo_to(t_mark);
    }
}

/// T-satisfiability of a conjunction of predicate literals — the exact
/// query unsatisfiable-path elimination asks, expressed through DPLL(T)
/// (used as the cross-check oracle in tests).
pub fn conjunction_sat(domains: &[Domain], literals: &[(Predicate, bool)]) -> bool {
    let preds: Vec<Predicate> = literals.iter().map(|&(p, _)| p).collect();
    let mut solver = Solver::new(preds.len());
    for (i, &(_, v)) in literals.iter().enumerate() {
        solver.add_unit(if v { Lit::pos(i) } else { Lit::neg(i) });
    }
    let mut theory = IntervalTheory::new(domains, preds);
    solver.solve(&mut theory).is_some()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pure_sat_simple() {
        // (a ∨ b) ∧ (¬a ∨ b) ∧ (¬b ∨ c)
        let mut s = Solver::new(3);
        s.add_clause(vec![Lit::pos(0), Lit::pos(1)]);
        s.add_clause(vec![Lit::neg(0), Lit::pos(1)]);
        s.add_clause(vec![Lit::neg(1), Lit::pos(2)]);
        let model = s.solve(&mut NoTheory).unwrap();
        assert!(model[1] && model[2]);
    }

    #[test]
    fn pure_unsat() {
        let mut s = Solver::new(1);
        s.add_unit(Lit::pos(0));
        s.add_unit(Lit::neg(0));
        assert!(s.solve(&mut NoTheory).is_none());
    }

    #[test]
    fn unit_propagation_chains() {
        // a ∧ (¬a ∨ b) ∧ (¬b ∨ ¬c) ∧ c  -> UNSAT
        let mut s = Solver::new(3);
        s.add_unit(Lit::pos(0));
        s.add_clause(vec![Lit::neg(0), Lit::pos(1)]);
        s.add_clause(vec![Lit::neg(1), Lit::neg(2)]);
        s.add_unit(Lit::pos(2));
        assert!(s.solve(&mut NoTheory).is_none());
    }

    #[test]
    fn theory_vetoes_propositionally_sat_formula() {
        // Propositionally: v0 ∧ ¬v1 is fine. Theory: v0 = (x < 2.45),
        // v1 = (x < 2.7) -> x < 2.45 ∧ x >= 2.7 is T-unsat.
        let preds = vec![
            Predicate {
                feature: 0,
                threshold: 2.45,
            },
            Predicate {
                feature: 0,
                threshold: 2.7,
            },
        ];
        let mut s = Solver::new(2);
        s.add_unit(Lit::pos(0));
        s.add_unit(Lit::neg(1));
        let mut t = IntervalTheory::new(&[Domain::Real], preds.clone());
        assert!(s.solve(&mut t).is_none());

        // The reverse polarity is T-sat.
        let mut s = Solver::new(2);
        s.add_unit(Lit::neg(0));
        s.add_unit(Lit::pos(1));
        let mut t = IntervalTheory::new(&[Domain::Real], preds);
        assert!(s.solve(&mut t).is_some());
    }

    #[test]
    fn search_navigates_theory_conflicts() {
        // (v0 ∨ v1) with a theory where v0's positive literal is impossible:
        // x < 1 ∧ x >= 2 forced elsewhere.
        let preds = vec![
            Predicate {
                feature: 0,
                threshold: 1.0,
            },
            Predicate {
                feature: 1,
                threshold: 1.0,
            },
            Predicate {
                feature: 0,
                threshold: 2.0,
            },
        ];
        let mut s = Solver::new(3);
        s.add_unit(Lit::neg(2)); // x0 >= 2
        s.add_clause(vec![Lit::pos(0), Lit::pos(1)]); // (x0<1) ∨ (x1<1)
        let mut t = IntervalTheory::new(&[Domain::Real, Domain::Real], preds);
        let model = s.solve(&mut t).unwrap();
        assert!(!model[0], "x0 < 1 contradicts x0 >= 2");
        assert!(model[1]);
    }

    #[test]
    fn conjunction_sat_agrees_with_interval_module() {
        use crate::feas::conjunction_feasible;
        let d = vec![Domain::Real, Domain::Grid { cardinality: 3 }];
        let p = |f: u32, t: f32| Predicate {
            feature: f,
            threshold: t,
        };
        let cases: Vec<Vec<(Predicate, bool)>> = vec![
            vec![(p(0, 2.45), true), (p(0, 2.7), false)],
            vec![(p(0, 2.7), true), (p(0, 2.45), false)],
            vec![(p(1, 1.2), false), (p(1, 1.8), true)],
            vec![(p(1, 0.5), false), (p(1, 1.5), true), (p(0, 1.0), true)],
        ];
        for lits in cases {
            assert_eq!(
                conjunction_sat(&d, &lits),
                conjunction_feasible(&d, &lits),
                "{lits:?}"
            );
        }
    }
}
