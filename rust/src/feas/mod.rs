//! Feasibility reasoning over predicate conjunctions — the "SMT solving"
//! of the paper's §5, specialised to the theory that actually occurs.
//!
//! Every predicate is an axis-aligned threshold `x[f] < t`, so a path
//! constraint is a conjunction of interval bounds per feature; feasibility
//! is decidable in O(1) per assumption by maintaining one interval per
//! feature ([`interval::IntervalStore`]). Ordinal-encoded categorical
//! features additionally restrict values to an integer grid, which the
//! store exploits for strictly stronger pruning (footnote 2 of the paper:
//! the theory here is polynomial).
//!
//! A generic DPLL solver with theory propagation ([`dpll`]) provides the
//! general interface an off-the-shelf SMT solver would and serves as an
//! independent cross-check oracle in the test suite.

pub mod dpll;
pub mod interval;

pub use interval::IntervalStore;

use crate::predicate::{Domain, Predicate};

/// Decide feasibility of a conjunction of predicate literals
/// (`(predicate, assumed-value)` pairs) over the given feature domains.
///
/// This is the one-shot convenience entry point; the reducer uses the
/// incremental [`IntervalStore`] directly.
pub fn conjunction_feasible(domains: &[Domain], literals: &[(Predicate, bool)]) -> bool {
    let mut store = IntervalStore::new(domains);
    for &(p, v) in literals {
        match store.implied(p) {
            Some(iv) if iv != v => return false,
            Some(_) => {}
            None => store.assume(p, v),
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(feature: u32, threshold: f32) -> Predicate {
        Predicate { feature, threshold }
    }

    #[test]
    fn contradicting_thresholds_detected() {
        let d = vec![Domain::Real];
        // x < 2.45 and NOT (x < 2.7) is the paper's §5 example — infeasible.
        assert!(!conjunction_feasible(&d, &[(p(0, 2.45), true), (p(0, 2.7), false)]));
        // the satisfiable variant
        assert!(conjunction_feasible(&d, &[(p(0, 2.7), true), (p(0, 2.45), false)]));
    }

    #[test]
    fn independent_features_do_not_interact() {
        let d = vec![Domain::Real, Domain::Real];
        assert!(conjunction_feasible(
            &d,
            &[(p(0, 1.0), true), (p(1, 1.0), false), (p(0, 2.0), true)]
        ));
    }

    #[test]
    fn grid_domains_prune_harder() {
        let d = vec![Domain::Grid { cardinality: 3 }]; // values {0, 1, 2}
        // 0.5 <= x < 1.5 pins x = 1: feasible.
        assert!(conjunction_feasible(&d, &[(p(0, 0.5), false), (p(0, 1.5), true)]));
        // 1.2 <= x < 1.8 contains no grid point: infeasible on the grid
        // (but satisfiable over the reals — the grid rule is what catches it).
        assert!(!conjunction_feasible(&d, &[(p(0, 1.2), false), (p(0, 1.8), true)]));
        // x >= 2.5 exceeds the cardinality-3 grid {0,1,2}: infeasible.
        assert!(!conjunction_feasible(&d, &[(p(0, 2.5), false)]));
    }
}
