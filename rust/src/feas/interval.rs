//! Incremental per-feature interval store with trail-based backtracking.
//!
//! The feasible set of each feature under a conjunction of threshold
//! literals is a half-open interval `[lo, hi)` (intersected with the
//! feature's grid for ordinal domains). The store supports O(1)
//! `assume`/`implied` and O(assumptions) backtracking via an undo trail —
//! the access pattern of the DFS in unsatisfiable-path elimination.

use crate::predicate::{Domain, Predicate};

/// Compact canonical store projection used as a memoisation key.
///
/// Almost every projection touches a handful of features, so the common
/// case is stored inline (no heap allocation on the reducer/combiner hot
/// path); larger projections spill to a heap vector. Unused inline slots
/// hold a fixed sentinel so the derived `Eq`/`Hash` stay consistent.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum CtxKey {
    /// Up to 10 constrained features, inline.
    Inline {
        /// Number of used slots.
        len: u8,
        /// `(feature, lo, hi)` entries; unused slots are the sentinel.
        items: [(u32, u32, u32); 10],
    },
    /// Spill for wide projections.
    Heap(Vec<(u32, u32, u32)>),
}

const CTX_SENTINEL: (u32, u32, u32) = (u32::MAX, 0, 0);

impl CtxKey {
    fn from_iter(mut items: impl Iterator<Item = (u32, u32, u32)>) -> CtxKey {
        let mut inline = [CTX_SENTINEL; 10];
        let mut len = 0usize;
        for it in items.by_ref() {
            if len == 10 {
                let mut v: Vec<(u32, u32, u32)> = inline.to_vec();
                v.push(it);
                v.extend(items);
                return CtxKey::Heap(v);
            }
            inline[len] = it;
            len += 1;
        }
        CtxKey::Inline {
            len: len as u8,
            items: inline,
        }
    }

    /// Number of constrained features in the key.
    pub fn len(&self) -> usize {
        match self {
            CtxKey::Inline { len, .. } => *len as usize,
            CtxKey::Heap(v) => v.len(),
        }
    }

    /// True when no feature is constrained.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Current feasible interval `[lo, hi)` of one feature.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Bound {
    lo: f32,
    hi: f32,
}

const FULL: Bound = Bound {
    lo: f32::NEG_INFINITY,
    hi: f32::INFINITY,
};

/// Incremental feasibility store (see module docs).
#[derive(Debug, Clone)]
pub struct IntervalStore {
    bounds: Vec<Bound>,
    domains: Vec<Domain>,
    trail: Vec<(u32, Bound)>,
}

impl IntervalStore {
    /// Unconstrained store over the given feature domains.
    pub fn new(domains: &[Domain]) -> Self {
        IntervalStore {
            bounds: vec![FULL; domains.len()],
            domains: domains.to_vec(),
            trail: Vec::new(),
        }
    }

    /// Smallest and largest *feasible* value of a feature under the current
    /// bounds, as a closed range; `None` when the feasible set is empty.
    fn feasible_range(&self, f: usize) -> Option<(f32, f32)> {
        let b = self.bounds[f];
        match self.domains[f] {
            Domain::Real => {
                if b.lo < b.hi {
                    // open above: supremum is hi, but no max; report hi as the
                    // exclusive upper bound handled by callers via `implied`.
                    Some((b.lo, b.hi))
                } else {
                    None
                }
            }
            Domain::Grid { cardinality } => {
                let min = ceil_clamped(b.lo, 0.0);
                // x < hi on integers means x <= ceil(hi) - 1
                let max = (ceil_f32(b.hi) - 1.0).min(cardinality as f32 - 1.0);
                if min <= max {
                    Some((min, max))
                } else {
                    None
                }
            }
        }
    }

    /// Tri-state entailment of `x[f] < t` under the current constraints:
    /// `Some(true)` when every feasible value satisfies it, `Some(false)`
    /// when none does, `None` when both outcomes remain possible.
    pub fn implied(&self, p: Predicate) -> Option<bool> {
        let f = p.feature as usize;
        let t = p.threshold;
        match self.domains[f] {
            Domain::Real => {
                let b = self.bounds[f];
                if b.hi <= t {
                    Some(true) // all x < hi <= t
                } else if b.lo >= t {
                    Some(false) // all x >= lo >= t
                } else {
                    None
                }
            }
            Domain::Grid { .. } => {
                let (min, max) = self
                    .feasible_range(f)
                    .expect("grid store became infeasible — assume() contract violated");
                if max < t {
                    Some(true)
                } else if min >= t {
                    Some(false)
                } else {
                    None
                }
            }
        }
    }

    /// Record an assumption `x[f] < t == value`. Callers must only assume
    /// predicates whose [`implied`](Self::implied) answer is `None` — that
    /// keeps the store feasible by construction (the reducer's invariant).
    pub fn assume(&mut self, p: Predicate, value: bool) {
        let f = p.feature as usize;
        let b = self.bounds[f];
        self.trail.push((p.feature, b));
        if value {
            self.bounds[f].hi = b.hi.min(p.threshold);
        } else {
            self.bounds[f].lo = b.lo.max(p.threshold);
        }
        debug_assert!(
            self.feasible_range(f).is_some(),
            "assumed an implied-impossible literal"
        );
    }

    /// Trail position for later [`undo_to`](Self::undo_to).
    pub fn mark(&self) -> usize {
        self.trail.len()
    }

    /// Backtrack to a previous [`mark`](Self::mark).
    pub fn undo_to(&mut self, mark: usize) {
        while self.trail.len() > mark {
            let (f, b) = self.trail.pop().unwrap();
            self.bounds[f as usize] = b;
        }
    }

    /// True when every feature still has a feasible value.
    pub fn is_feasible(&self) -> bool {
        (0..self.bounds.len()).all(|f| self.feasible_range(f).is_some())
    }

    /// Allocation-free canonical projection into a [`CtxKey`] (hot-path
    /// variant of [`project_key`](Self::project_key)).
    pub fn project_ctx(&self, features: impl Iterator<Item = u32>) -> CtxKey {
        CtxKey::from_iter(features.filter_map(|f| self.project_one(f)))
    }

    /// Projection of a single feature; `None` when unconstrained.
    #[inline]
    fn project_one(&self, f: u32) -> Option<(u32, u32, u32)> {
        let fi = f as usize;
        let b = self.bounds[fi];
        if b == FULL {
            return None;
        }
        match self.domains[fi] {
            Domain::Real => Some((f, b.lo.to_bits(), b.hi.to_bits())),
            Domain::Grid { cardinality } => {
                let (min, max) = self
                    .feasible_range(fi)
                    .expect("infeasible grid store in project_ctx");
                if min == 0.0 && max == cardinality as f32 - 1.0 {
                    None
                } else {
                    Some((f, min as u32, max as u32))
                }
            }
        }
    }

    /// Canonical projection of the store onto a feature subset, for use as
    /// a memoisation key. Grid features canonicalise to their integer range
    /// (different real bounds with the same feasible grid values produce the
    /// same key — strictly more cache hits). Unconstrained features are
    /// omitted.
    pub fn project_key(&self, features: impl Iterator<Item = u32>) -> Vec<(u32, u32, u32)> {
        features.filter_map(|f| self.project_one(f)).collect()
    }
}

fn ceil_f32(v: f32) -> f32 {
    if v.is_finite() {
        v.ceil()
    } else {
        v
    }
}

fn ceil_clamped(v: f32, min: f32) -> f32 {
    if v == f32::NEG_INFINITY {
        min
    } else {
        v.ceil().max(min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(feature: u32, threshold: f32) -> Predicate {
        Predicate { feature, threshold }
    }

    #[test]
    fn real_implication_chain() {
        let mut s = IntervalStore::new(&[Domain::Real]);
        assert_eq!(s.implied(p(0, 2.45)), None);
        s.assume(p(0, 2.45), true);
        // x < 2.45 -> x < 2.7 implied true; x < 2.0 unknown
        assert_eq!(s.implied(p(0, 2.7)), Some(true));
        assert_eq!(s.implied(p(0, 2.45)), Some(true)); // self
        assert_eq!(s.implied(p(0, 2.0)), None);
        s.assume(p(0, 2.0), false);
        // now 2.0 <= x < 2.45
        assert_eq!(s.implied(p(0, 1.5)), Some(false));
        assert_eq!(s.implied(p(0, 2.0)), Some(false));
    }

    #[test]
    fn backtracking_restores_bounds() {
        let mut s = IntervalStore::new(&[Domain::Real, Domain::Real]);
        let m0 = s.mark();
        s.assume(p(0, 1.0), true);
        let m1 = s.mark();
        s.assume(p(1, 5.0), false);
        s.assume(p(0, 0.5), false);
        assert_eq!(s.implied(p(1, 4.0)), Some(false));
        s.undo_to(m1);
        assert_eq!(s.implied(p(1, 4.0)), None);
        assert_eq!(s.implied(p(0, 1.5)), Some(true));
        s.undo_to(m0);
        assert_eq!(s.implied(p(0, 1.5)), None);
    }

    #[test]
    fn boundary_semantics_half_open() {
        let mut s = IntervalStore::new(&[Domain::Real]);
        s.assume(p(0, 3.0), false); // x >= 3.0
        // x < 3.0 is exactly false, not unknown
        assert_eq!(s.implied(p(0, 3.0)), Some(false));
        let mut s = IntervalStore::new(&[Domain::Real]);
        s.assume(p(0, 3.0), true); // x < 3.0
        assert_eq!(s.implied(p(0, 3.0)), Some(true));
    }

    #[test]
    fn grid_entailment_is_stronger_than_real() {
        let d = [Domain::Grid { cardinality: 5 }]; // {0..4}
        let mut s = IntervalStore::new(&d);
        s.assume(p(0, 1.5), false); // x >= 1.5 -> on grid x >= 2
        // real reasoning can't decide x < 2.2; grid reasoning: x ∈ {2,3,4}
        // so x < 2.2 iff x == 2 -> unknown; but x < 2.0 is false.
        assert_eq!(s.implied(p(0, 2.0)), Some(false));
        s.assume(p(0, 2.5), true); // x ∈ {2}
        assert_eq!(s.implied(p(0, 2.2)), Some(true));
        assert_eq!(s.implied(p(0, 2.0)), Some(false));
    }

    #[test]
    fn grid_feasibility_detects_empty_cells() {
        let d = [Domain::Grid { cardinality: 3 }];
        let mut s = IntervalStore::new(&d);
        s.assume(p(0, 1.2), false); // x >= 1.2 -> x = 2 only? no: x ∈ {2}
        assert!(s.is_feasible());
        // x < 1.8 would require a grid point in [1.2, 1.8) -> none;
        // implied() must answer false so the reducer never assumes it.
        assert_eq!(s.implied(p(0, 1.8)), Some(false));
    }

    #[test]
    fn project_key_canonicalises_grids() {
        let d = [Domain::Grid { cardinality: 5 }, Domain::Real];
        let mut a = IntervalStore::new(&d);
        a.assume(p(0, 2.3), true); // grid: x ∈ {0,1,2}
        let mut b = IntervalStore::new(&d);
        b.assume(p(0, 2.9), true); // grid: x ∈ {0,1,2} — same feasible set
        assert_eq!(
            a.project_key([0u32, 1u32].into_iter()),
            b.project_key([0u32, 1u32].into_iter())
        );
        // Real features keep exact bits (no spurious merging).
        let mut c = IntervalStore::new(&d);
        c.assume(p(1, 2.3), true);
        let mut e = IntervalStore::new(&d);
        e.assume(p(1, 2.9), true);
        assert_ne!(
            c.project_key([0u32, 1u32].into_iter()),
            e.project_key([0u32, 1u32].into_iter())
        );
    }

    #[test]
    fn project_key_omits_unconstrained() {
        let d = [Domain::Real, Domain::Real, Domain::Real];
        let mut s = IntervalStore::new(&d);
        s.assume(p(1, 4.0), true);
        let key = s.project_key([0u32, 1, 2].into_iter());
        assert_eq!(key.len(), 1);
        assert_eq!(key[0].0, 1);
        // projection respects the requested feature subset
        let key2 = s.project_key([0u32, 2].into_iter());
        assert!(key2.is_empty());
    }

    #[test]
    fn full_grid_range_is_omitted_from_key() {
        let d = [Domain::Grid { cardinality: 3 }];
        let mut s = IntervalStore::new(&d);
        s.assume(p(0, 5.0), true); // x < 5 constrains nothing on {0,1,2}
        assert!(s.project_key([0u32].into_iter()).is_empty());
    }
}
