//! Server assembly: trains the model, wires router + backends + HTTP
//! workers, and manages lifecycle.

use crate::compile::CompileOptions;
use crate::data::{arff, csv, datasets, Dataset};
use crate::error::{Error, Result};
use crate::serve::batcher::BatcherConfig;
use crate::serve::config::ServeConfig;
use crate::serve::http::handle_connection;
use crate::serve::metrics::ServerMetrics;
use crate::serve::router::Router;
use crate::serve::xla_backend::XlaBackend;
use crate::serve::ModelBundle;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Resolve a dataset spec: a built-in name, or a `.csv`/`.arff` path.
pub fn resolve_dataset(spec: &str) -> Result<Dataset> {
    if spec.ends_with(".csv") {
        csv::load_file(spec)
    } else if spec.ends_with(".arff") {
        arff::load_file(spec)
    } else {
        datasets::load(spec)
    }
}

/// A running server; dropping (or calling [`stop`](Self::stop)) shuts it
/// down and joins all threads.
pub struct ServerHandle {
    /// The bound address (useful when the config asked for port 0).
    pub addr: SocketAddr,
    /// The shared router (tests can bypass HTTP).
    pub router: Arc<Router>,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    worker_threads: Vec<JoinHandle<()>>,
}

/// Build the model and start serving (returns once the socket is bound).
pub fn start(cfg: &ServeConfig) -> Result<ServerHandle> {
    cfg.validate()?;
    let data = resolve_dataset(&cfg.dataset)?;
    crate::log_info!(
        "serve: training {} trees on '{}' ({} rows)…",
        cfg.trees,
        data.name,
        data.n_rows()
    );
    let bundle = Arc::new(ModelBundle::train(
        &data,
        cfg.trees,
        cfg.max_depth,
        cfg.seed,
        CompileOptions::default(),
    )?);
    crate::log_info!(
        "serve: forest {} nodes -> DD* {} nodes",
        bundle.forest.n_nodes(),
        bundle.dd.size().total()
    );
    let metrics = Arc::new(ServerMetrics::default());
    let xla = if cfg.enable_xla {
        match XlaBackend::start(&cfg.artifacts_dir, &cfg.variant, &bundle.forest) {
            Ok(b) => Some(Arc::new(b)),
            Err(e) => {
                // Per DESIGN.md §7: incompatible forests fall back to the
                // native DD backend rather than silently changing semantics.
                crate::log_warn!("serve: xla backend unavailable, falling back to dd: {e}");
                None
            }
        }
    } else {
        None
    };
    let router = Arc::new(Router::new(
        bundle,
        metrics,
        cfg.default_backend,
        xla,
        BatcherConfig {
            max_batch: cfg.batch_max,
            max_wait: Duration::from_millis(cfg.batch_wait_ms),
            queue_cap: (cfg.batch_max * 16).max(256),
        },
    ));

    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let shutdown = Arc::new(AtomicBool::new(false));

    // Worker pool: accept thread feeds connections through a bounded queue.
    let (conn_tx, conn_rx): (SyncSender<TcpStream>, Receiver<TcpStream>) =
        mpsc::sync_channel(cfg.http_workers * 8);
    let conn_rx = Arc::new(Mutex::new(conn_rx));
    let mut worker_threads = Vec::with_capacity(cfg.http_workers);
    for w in 0..cfg.http_workers {
        let rx = conn_rx.clone();
        let router = router.clone();
        worker_threads.push(
            std::thread::Builder::new()
                .name(format!("http-worker-{w}"))
                .spawn(move || loop {
                    let conn = rx.lock().unwrap().recv();
                    match conn {
                        Ok(stream) => handle_connection(stream, &router),
                        Err(_) => return, // accept loop gone
                    }
                })
                .map_err(|e| Error::Serve(format!("cannot spawn http worker: {e}")))?,
        );
    }
    let accept_shutdown = shutdown.clone();
    let accept_thread = std::thread::Builder::new()
        .name("http-accept".into())
        .spawn(move || {
            while !accept_shutdown.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        // Blocking handoff applies backpressure when all
                        // workers are busy.
                        if conn_tx.send(stream).is_err() {
                            return;
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(e) => {
                        crate::log_warn!("serve: accept error: {e}");
                        std::thread::sleep(Duration::from_millis(20));
                    }
                }
            }
            // dropping conn_tx stops the workers
        })
        .map_err(|e| Error::Serve(format!("cannot spawn accept thread: {e}")))?;

    crate::log_info!("serve: listening on http://{addr}");
    Ok(ServerHandle {
        addr,
        router,
        shutdown,
        accept_thread: Some(accept_thread),
        worker_threads,
    })
}

impl ServerHandle {
    /// Stop accepting, drain workers, join threads.
    pub fn stop(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        for t in self.worker_threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_dataset_built_in_and_errors() {
        assert_eq!(resolve_dataset("iris").unwrap().n_rows(), 150);
        assert!(resolve_dataset("missing.csv").is_err());
        assert!(resolve_dataset("not-a-dataset").is_err());
    }

    // Full server lifecycle is exercised over real sockets in
    // rust/tests/integration_serve.rs.
}
