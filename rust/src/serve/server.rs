//! Server assembly: trains the model, wires router + backends + the
//! selected serving front-end, and manages lifecycle.
//!
//! Two interchangeable front-ends serve the same [`respond`] handler
//! ([`crate::serve::http`]) and are therefore bit-identical on the wire:
//!
//! - **sync** — thread-per-connection: an accept thread feeds accepted
//!   sockets through a bounded queue to `http_workers` blocking workers,
//!   each serving its connection keep-alive with a per-connection read
//!   timeout;
//! - **evented** — one poller thread (`net::event_loop`) multiplexes
//!   every connection with epoll/kqueue readiness, dispatching parsed
//!   requests to `http_workers` handler workers through a bounded queue
//!   (full queue → `429` + `Retry-After`).
//!
//! [`ServeConfig::io_mode`] picks the front-end (`auto` resolves to
//! evented wherever a poller exists).

use crate::engine::Engine;
use crate::error::{Error, Result};
use crate::serve::batcher::BatcherConfig;
use crate::serve::breaker::BreakerBoard;
use crate::serve::config::ServeConfig;
use crate::serve::http::{handle_connection, respond};
use crate::serve::metrics::ServerMetrics;
use crate::serve::router::Router;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// The running front-end owned by a [`ServerHandle`].
enum FrontEnd {
    /// Thread-per-connection: accept thread + connection workers.
    Sync {
        accept_thread: JoinHandle<()>,
        worker_threads: Vec<JoinHandle<()>>,
    },
    /// The evented loop (only constructed where a poller exists).
    #[cfg(any(target_os = "linux", all(target_os = "macos", target_pointer_width = "64")))]
    Evented(crate::net::event_loop::EventLoopHandle),
}

/// A running server; dropping (or calling [`stop`](Self::stop)) shuts it
/// down and joins all threads.
pub struct ServerHandle {
    /// The bound address (useful when the config asked for port 0).
    pub addr: SocketAddr,
    /// The shared router (tests can bypass HTTP).
    pub router: Arc<Router>,
    shutdown: Arc<AtomicBool>,
    front: Option<FrontEnd>,
}

/// Build the model and start serving (returns once the socket is bound).
///
/// Three startup paths: with [`ServeConfig::bundle`] set, the replica
/// maps a `fab-v1` multi-model bundle once and registers every entry as
/// a named frozen model; with [`ServeConfig::snapshot`] set, it
/// registers a single pre-compiled `fdd` artifact (mmap'd zero-copy
/// where supported, no training); otherwise it trains and compiles from
/// the configured dataset.
pub fn start(cfg: &ServeConfig) -> Result<ServerHandle> {
    cfg.validate()?;
    // Logging policy first, so boot messages already honour it. The
    // FOREST_ADD_LOG env override wins inside init.
    crate::obs::log::init(
        crate::obs::log::Level::parse(&cfg.log_level).unwrap_or(crate::obs::log::Level::Info),
        cfg.log_json,
    );
    let evented = cfg.io_mode.resolve()?;
    // Arm deterministic fault injection before any request can run; the
    // spec was already validated, so failures here are config races.
    if !cfg.fault.is_empty() {
        crate::runtime::fault::arm(&cfg.fault).map_err(Error::invalid)?;
        crate::log_warn!("serve: fault injection armed ({})", cfg.fault);
    }
    crate::runtime::fault::arm_from_env().map_err(Error::invalid)?;
    // Size the shared evaluation pool before any batch traffic exists
    // (spawn-once; the first effective configuration wins process-wide).
    let eval_threads = crate::runtime::pool::configure(cfg.eval_threads);
    let tile_bytes = crate::frozen::configure_tile_bytes(cfg.tile_bytes);
    // Pin the frozen-sweep SIMD kernel before any batch traffic exists.
    // `FOREST_ADD_NO_SIMD` wins over the config knob inside configure.
    let simd_kernel = crate::runtime::simd::configure(cfg.simd);
    crate::log_info!(
        "serve: evaluation parallelism {eval_threads}, frozen tile budget {tile_bytes} bytes, \
         simd kernel {}",
        simd_kernel.name()
    );
    let engine = if !cfg.bundle.is_empty() {
        let engine = Engine::new();
        let ids = engine.register_bundle(&cfg.bundle)?;
        let names: Vec<String> = ids.iter().map(|id| id.to_string()).collect();
        crate::log_info!(
            "serve: loaded bundle '{}' — {} models ({})",
            cfg.bundle,
            ids.len(),
            names.join(", ")
        );
        engine
    } else if !cfg.snapshot.is_empty() {
        let engine = Engine::new();
        let id = engine.register_snapshot("default", &cfg.snapshot)?;
        crate::log_info!("serve: loaded snapshot '{}' as {id}", cfg.snapshot);
        engine
    } else {
        let data = crate::data::resolve(&cfg.dataset)?;
        crate::log_info!(
            "serve: training {} trees on '{}' ({} rows)…",
            cfg.trees,
            data.name,
            data.n_rows()
        );
        let mut builder = Engine::builder()
            .dataset(data.clone())
            .trees(cfg.trees)
            .max_depth(cfg.max_depth)
            .seed(cfg.seed);
        // Weighted decisions and regression means are post-maps over the
        // vote vector, so the compiled diagram must keep it: the default
        // majority abstraction folds votes away at compile time.
        if data.schema.task.is_regression() || !cfg.class_weights.is_empty() {
            builder = builder.abstraction(crate::compile::Abstraction::Vector);
            crate::log_info!(
                "serve: vote-preserving (vector) abstraction selected ({})",
                if data.schema.task.is_regression() {
                    "regression dataset"
                } else {
                    "class weights configured"
                }
            );
        }
        if cfg.enable_xla {
            // Load failures fall back to the native backends inside the
            // builder (DESIGN.md §7) — the server still comes up.
            builder = builder.xla_artifacts(cfg.artifacts_dir.as_str(), cfg.variant.as_str());
        }
        builder.build()?
    };
    for info in engine.info(None)? {
        crate::log_info!(
            "serve: backend '{}' ready — {} ({} nodes)",
            info.backend.name(),
            info.label,
            info.size_nodes
        );
    }
    // Config validation only checked the weights themselves; their arity
    // is a property of the loaded model, known first here.
    if !cfg.class_weights.is_empty() {
        let version = engine.registry().get(None)?;
        let k = version.schema.n_classes();
        if cfg.class_weights.len() != k {
            return Err(Error::invalid(format!(
                "class_weights has {} entries but model '{}' has {k} classes",
                cfg.class_weights.len(),
                version.id
            )));
        }
    }
    let metrics = Arc::new(ServerMetrics::default());
    metrics
        .eval_threads
        .store(eval_threads as u64, std::sync::atomic::Ordering::Relaxed);
    metrics.set_io_mode(evented);
    metrics.set_simd_kernel(simd_kernel);
    let router = Arc::new(Router::new(
        engine.registry().clone(),
        metrics.clone(),
        cfg.default_backend,
        BatcherConfig {
            max_batch: cfg.batch_max,
            max_wait: Duration::from_millis(cfg.batch_wait_ms),
            queue_cap: cfg.resolved_batch_queue_cap(),
        },
        Duration::from_millis(cfg.reply_timeout_ms),
        BreakerBoard::new(
            cfg.breaker_threshold,
            Duration::from_millis(cfg.breaker_cooldown_ms),
        ),
    )
    .with_class_weights(cfg.class_weights.clone()));

    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let front = if evented {
        start_evented(listener, cfg, &router, metrics, shutdown.clone())?
    } else {
        start_sync(listener, cfg, &router, shutdown.clone())?
    };
    crate::log_info!(
        "serve: listening on http://{addr} ({} front-end)",
        if evented { "evented" } else { "sync" }
    );
    Ok(ServerHandle {
        addr,
        router,
        shutdown,
        front: Some(front),
    })
}

/// Boot the evented front-end on targets with a poller.
#[cfg(any(target_os = "linux", all(target_os = "macos", target_pointer_width = "64")))]
fn start_evented(
    listener: TcpListener,
    cfg: &ServeConfig,
    router: &Arc<Router>,
    metrics: Arc<ServerMetrics>,
    shutdown: Arc<AtomicBool>,
) -> Result<FrontEnd> {
    use crate::net::event_loop::{self, EventLoopConfig, Handler};
    let router = router.clone();
    let handler: Handler = Arc::new(move |req, trace| respond(req, &router, trace));
    let handle = event_loop::start(
        listener,
        handler,
        metrics,
        EventLoopConfig {
            workers: cfg.http_workers,
            dispatch_cap: cfg.resolved_dispatch_cap(),
            idle_timeout: Duration::from_millis(cfg.read_timeout_ms),
            retry_after_s: 1,
            conn_max_inflight: cfg.conn_max_inflight,
        },
        shutdown,
    )?;
    Ok(FrontEnd::Evented(handle))
}

/// No poller on this target — [`IoMode::resolve`] never returns evented
/// here, so this is unreachable; it exists to keep the call site
/// cfg-free.
#[cfg(not(any(target_os = "linux", all(target_os = "macos", target_pointer_width = "64"))))]
fn start_evented(
    _listener: TcpListener,
    _cfg: &ServeConfig,
    _router: &Arc<Router>,
    _metrics: Arc<ServerMetrics>,
    _shutdown: Arc<AtomicBool>,
) -> Result<FrontEnd> {
    Err(Error::invalid(
        "evented front-end is unavailable on this target",
    ))
}

/// Boot the sync thread-per-connection front-end.
fn start_sync(
    listener: TcpListener,
    cfg: &ServeConfig,
    router: &Arc<Router>,
    shutdown: Arc<AtomicBool>,
) -> Result<FrontEnd> {
    listener.set_nonblocking(true)?;
    let read_timeout = Duration::from_millis(cfg.read_timeout_ms);
    // Worker pool: accept thread feeds connections through a bounded queue.
    let (conn_tx, conn_rx): (SyncSender<TcpStream>, Receiver<TcpStream>) =
        mpsc::sync_channel(cfg.http_workers * 8);
    let conn_rx = Arc::new(Mutex::new(conn_rx));
    let mut worker_threads = Vec::with_capacity(cfg.http_workers);
    for w in 0..cfg.http_workers {
        let rx = conn_rx.clone();
        let router = router.clone();
        worker_threads.push(
            std::thread::Builder::new()
                .name(format!("http-worker-{w}"))
                .spawn(move || loop {
                    let conn = rx.lock().unwrap().recv();
                    match conn {
                        Ok(stream) => handle_connection(stream, &router, read_timeout),
                        Err(_) => return, // accept loop gone
                    }
                })
                .map_err(|e| Error::Serve(format!("cannot spawn http worker: {e}")))?,
        );
    }
    let accept_thread = std::thread::Builder::new()
        .name("http-accept".into())
        .spawn(move || {
            while !shutdown.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        // Blocking handoff applies backpressure when all
                        // workers are busy.
                        if conn_tx.send(stream).is_err() {
                            return;
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(e) => {
                        crate::log_warn!("serve: accept error: {e}");
                        std::thread::sleep(Duration::from_millis(20));
                    }
                }
            }
            // dropping conn_tx stops the workers
        })
        .map_err(|e| Error::Serve(format!("cannot spawn accept thread: {e}")))?;
    Ok(FrontEnd::Sync {
        accept_thread,
        worker_threads,
    })
}

impl ServerHandle {
    /// Stop accepting, drain workers, join threads.
    pub fn stop(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        match self.front.take() {
            Some(FrontEnd::Sync {
                accept_thread,
                worker_threads,
            }) => {
                let _ = accept_thread.join();
                for t in worker_threads {
                    let _ = t.join();
                }
            }
            #[cfg(any(
                target_os = "linux",
                all(target_os = "macos", target_pointer_width = "64")
            ))]
            Some(FrontEnd::Evented(mut handle)) => handle.join(),
            None => {}
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

// Full server lifecycle is exercised over real sockets in
// rust/tests/integration_serve.rs and integration_net.rs; dataset-spec
// resolution is tested in `data::tests`.
