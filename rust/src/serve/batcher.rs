//! Dynamic batcher: groups individual requests into fixed-deadline batches.
//!
//! Classic serving pattern (vLLM-style continuous batching simplified to
//! the stateless-classification case): the first job opens a batch window;
//! the batch is dispatched when it reaches `max_batch` items or `max_wait`
//! elapses, whichever comes first. Dispatch happens on the batcher thread;
//! replies travel back through per-job channels.

use crate::error::{Error, Result};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TryRecvError, TrySendError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Maximum items per dispatched batch.
    pub max_batch: usize,
    /// Maximum time the first item of a batch waits.
    pub max_wait: Duration,
    /// Bounded queue capacity (backpressure: submits fail when full).
    pub queue_cap: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(2),
            queue_cap: 1024,
        }
    }
}

/// Handle to a running batcher.
pub struct Batcher<J: Send + 'static> {
    tx: SyncSender<Msg<J>>,
    handle: Option<JoinHandle<()>>,
}

enum Msg<J> {
    Job(J),
    Shutdown,
}

impl<J: Send + 'static> Batcher<J> {
    /// Start a batcher thread; `process` receives each dispatched batch.
    pub fn start(
        name: &str,
        cfg: BatcherConfig,
        mut process: impl FnMut(Vec<J>) + Send + 'static,
    ) -> Batcher<J> {
        assert!(cfg.max_batch > 0, "max_batch must be positive");
        let (tx, rx): (SyncSender<Msg<J>>, Receiver<Msg<J>>) = mpsc::sync_channel(cfg.queue_cap);
        let thread_name = format!("batcher-{name}");
        let handle = std::thread::Builder::new()
            .name(thread_name)
            .spawn(move || {
                loop {
                    // Wait for the first job of the next batch.
                    let first = match rx.recv() {
                        Ok(Msg::Job(j)) => j,
                        Ok(Msg::Shutdown) | Err(_) => return,
                    };
                    let mut batch = vec![first];
                    let deadline = Instant::now() + cfg.max_wait;
                    while batch.len() < cfg.max_batch {
                        // Under load the queue already holds the next
                        // jobs: drain them without a timed wait (one
                        // timeout syscall per queued job adds up).
                        match rx.try_recv() {
                            Ok(Msg::Job(j)) => {
                                batch.push(j);
                                continue;
                            }
                            Ok(Msg::Shutdown) => {
                                process(batch);
                                return;
                            }
                            Err(TryRecvError::Empty) => {}
                            Err(TryRecvError::Disconnected) => {
                                process(batch);
                                return;
                            }
                        }
                        let now = Instant::now();
                        if now >= deadline {
                            break;
                        }
                        match rx.recv_timeout(deadline - now) {
                            Ok(Msg::Job(j)) => batch.push(j),
                            Ok(Msg::Shutdown) => {
                                process(batch);
                                return;
                            }
                            Err(RecvTimeoutError::Timeout) => break,
                            Err(RecvTimeoutError::Disconnected) => {
                                process(batch);
                                return;
                            }
                        }
                    }
                    process(batch);
                }
            })
            .expect("failed to spawn batcher thread");
        Batcher {
            tx,
            handle: Some(handle),
        }
    }

    /// Submit a job; fails fast when the queue is full (backpressure) or
    /// the batcher has shut down.
    pub fn submit(&self, job: J) -> Result<()> {
        match self.tx.try_send(Msg::Job(job)) {
            Ok(()) => Ok(()),
            // admission control: a full queue sheds the request (HTTP
            // maps this to 429 + Retry-After) instead of queueing it
            Err(TrySendError::Full(_)) => Err(Error::Overloaded(
                "batcher queue full — retry shortly or raise queue_cap".into(),
            )),
            Err(TrySendError::Disconnected(_)) => {
                Err(Error::Serve("batcher has shut down".into()))
            }
        }
    }

    /// Stop the batcher thread (processes whatever is already queued).
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl<J: Send + 'static> Drop for Batcher<J> {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    fn collect_batches(cfg: BatcherConfig) -> (Batcher<u32>, Arc<Mutex<Vec<Vec<u32>>>>) {
        let seen = Arc::new(Mutex::new(Vec::new()));
        let s = seen.clone();
        let b = Batcher::start("test", cfg, move |batch| {
            s.lock().unwrap().push(batch);
        });
        (b, seen)
    }

    #[test]
    fn batches_fill_to_max() {
        let (b, seen) = collect_batches(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(200),
            queue_cap: 64,
        });
        for i in 0..8 {
            b.submit(i).unwrap();
        }
        // give the batcher time to form both batches
        std::thread::sleep(Duration::from_millis(50));
        b.shutdown();
        let batches = seen.lock().unwrap();
        let total: usize = batches.iter().map(|b| b.len()).sum();
        assert_eq!(total, 8);
        assert!(batches.iter().all(|b| b.len() <= 4));
        assert_eq!(batches[0].len(), 4, "first batch should fill to max");
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let (b, seen) = collect_batches(BatcherConfig {
            max_batch: 100,
            max_wait: Duration::from_millis(10),
            queue_cap: 64,
        });
        b.submit(1).unwrap();
        b.submit(2).unwrap();
        std::thread::sleep(Duration::from_millis(80));
        {
            let batches = seen.lock().unwrap();
            assert_eq!(batches.len(), 1, "deadline must flush without more input");
            assert_eq!(batches[0], vec![1, 2]);
        }
        b.shutdown();
    }

    #[test]
    fn order_is_preserved_within_batches() {
        let (b, seen) = collect_batches(BatcherConfig {
            max_batch: 1000,
            max_wait: Duration::from_millis(30),
            queue_cap: 1024,
        });
        for i in 0..100 {
            b.submit(i).unwrap();
        }
        std::thread::sleep(Duration::from_millis(100));
        b.shutdown();
        let batches = seen.lock().unwrap();
        let flat: Vec<u32> = batches.iter().flatten().copied().collect();
        assert_eq!(flat, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn queued_jobs_drain_without_waiting_for_the_deadline() {
        // A pre-filled queue must form a full batch immediately — the
        // drain loop may not stall on, drop, or duplicate queued jobs.
        let (b, seen) = collect_batches(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_secs(30), // deadline must never matter
            queue_cap: 64,
        });
        for i in 0..16 {
            b.submit(i).unwrap();
        }
        let t0 = Instant::now();
        while seen.lock().unwrap().iter().map(|v| v.len()).sum::<usize>() < 16 {
            assert!(
                t0.elapsed() < Duration::from_secs(5),
                "queued jobs were not drained promptly"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        b.shutdown();
        let batches = seen.lock().unwrap();
        let flat: Vec<u32> = batches.iter().flatten().copied().collect();
        assert_eq!(flat, (0..16).collect::<Vec<_>>());
        assert_eq!(batches[0].len(), 8, "first batch should fill from the queue");
    }

    #[test]
    fn backpressure_when_queue_full() {
        // processor blocks forever -> queue fills -> submit errors
        let gate = Arc::new(Mutex::new(()));
        let guard = gate.lock().unwrap();
        let g = gate.clone();
        let b = Batcher::start(
            "stuck",
            BatcherConfig {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
                queue_cap: 2,
            },
            move |_| {
                let _guard = g.lock().unwrap();
            },
        );
        // first submit is consumed into a batch and blocks in process();
        // the queue then holds at most queue_cap more.
        let mut errors = 0;
        for i in 0..10 {
            if b.submit(i).is_err() {
                errors += 1;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(errors > 0, "expected backpressure errors");
        drop(guard);
        b.shutdown();
    }

    #[test]
    fn shutdown_processes_queued_jobs() {
        let (b, seen) = collect_batches(BatcherConfig {
            max_batch: 10,
            max_wait: Duration::from_secs(10), // deadline never fires
            queue_cap: 64,
        });
        b.submit(7).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        b.shutdown(); // must flush the pending partial batch
        let batches = seen.lock().unwrap();
        assert_eq!(*batches, vec![vec![7]]);
    }
}
