//! Minimal HTTP/1.1 front-end over `std::net` (no async runtime is
//! available offline; a thread-pool accept loop serves the same purpose
//! for this request shape).
//!
//! Endpoints:
//! - `GET  /healthz`          → `{"ok": true}`
//! - `GET  /metrics`          → server metrics snapshot
//! - `GET  /model`            → default-model description (per-backend info)
//! - `GET  /models`           → all registered models (name, version, backends,
//!   `source` = artifact provenance for bundle-booted models)
//! - `POST /classify`         → `{"features": [...], "backend": "dd"?, "model": "name"?}`
//! - `POST /classify_batch`   → `{"rows": [[...], ...], "backend": ...?, "model": ...?,
//!   "steps": true?}` — with `"steps": true` the response carries the §6
//!   step count per row (`null` when the backend cannot meter)

use crate::batch::RowMatrixBuf;
use crate::error::{Error, Result};
use crate::serve::router::Router;
use crate::serve::{BackendKind, ClassifyRequest};
use crate::util::json::{self, Json};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

/// Maximum accepted request body (1 MiB — batches of a few thousand rows).
const MAX_BODY: usize = 1 << 20;

/// Parsed request.
struct Request {
    method: String,
    path: String,
    body: Vec<u8>,
}

fn read_request(stream: &mut TcpStream) -> Result<Request> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| Error::Serve("empty request line".into()))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| Error::Serve("request line missing path".into()))?
        .to_string();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v
                    .trim()
                    .parse()
                    .map_err(|_| Error::Serve("bad content-length".into()))?;
            }
        }
    }
    if content_length > MAX_BODY {
        return Err(Error::Serve(format!("body too large ({content_length} bytes)")));
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(Request { method, path, body })
}

fn write_response(stream: &mut TcpStream, status: u16, body: &Json) -> Result<()> {
    let body = body.to_string_compact();
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        _ => "Internal Server Error",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// Handle one connection: parse, route, respond. Errors become JSON
/// error bodies; connection-level failures are logged and dropped.
pub fn handle_connection(mut stream: TcpStream, router: &Arc<Router>) {
    let _ = stream.set_nodelay(true);
    let response = match read_request(&mut stream) {
        Ok(req) => route(&req, router),
        Err(e) => (400, json::obj(vec![("error", json::s(e.to_string()))])),
    };
    if let Err(e) = write_response(&mut stream, response.0, &response.1) {
        crate::log_debug!("http: failed to write response: {e}");
    }
}

fn route(req: &Request, router: &Arc<Router>) -> (u16, Json) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => (200, json::obj(vec![("ok", Json::Bool(true))])),
        ("GET", "/metrics") => (200, router.metrics().to_json()),
        ("GET", "/model") => match model_info(router) {
            Ok(j) => (200, j),
            Err(e) => (400, json::obj(vec![("error", json::s(e.to_string()))])),
        },
        ("GET", "/models") => (200, model_list(router)),
        ("POST", "/classify") => match classify(req, router) {
            Ok(j) => (200, j),
            Err(e) => (400, json::obj(vec![("error", json::s(e.to_string()))])),
        },
        ("POST", "/classify_batch") => match classify_batch(req, router) {
            Ok(j) => (200, j),
            Err(e) => (400, json::obj(vec![("error", json::s(e.to_string()))])),
        },
        ("GET", _) | ("POST", _) => (
            404,
            json::obj(vec![("error", json::s(format!("no such path {}", req.path)))]),
        ),
        _ => (
            405,
            json::obj(vec![("error", json::s("method not allowed"))]),
        ),
    }
}

fn model_info(router: &Arc<Router>) -> Result<Json> {
    let version = router.registry().get(None)?;
    let backends: Vec<Json> = version
        .slots()
        .iter()
        .map(|slot| {
            let info = slot.classifier.info();
            json::obj(vec![
                ("backend", json::s(info.backend.name())),
                ("label", json::s(info.label)),
                ("size_nodes", json::num(info.size_nodes as f64)),
                (
                    "max_steps",
                    info.cost
                        .max_steps
                        .map(|s| json::num(s as f64))
                        .unwrap_or(Json::Null),
                ),
                (
                    "aggregation_reads",
                    json::num(info.cost.aggregation_reads as f64),
                ),
                (
                    "preferred_batch",
                    json::num(info.cost.preferred_batch as f64),
                ),
            ])
        })
        .collect();
    Ok(json::obj(vec![
        ("model", json::s(version.id.name.clone())),
        ("version", json::num(version.id.version as f64)),
        (
            "classes",
            Json::Arr(
                version
                    .schema
                    .classes
                    .iter()
                    .map(|c| json::s(c.clone()))
                    .collect(),
            ),
        ),
        ("backends", Json::Arr(backends)),
        ("default_backend", json::s(router.default_backend().name())),
        ("xla_loaded", Json::Bool(router.has_xla())),
    ]))
}

fn model_list(router: &Arc<Router>) -> Json {
    let models: Vec<Json> = router
        .registry()
        .list()
        .iter()
        .map(|v| {
            json::obj(vec![
                ("name", json::s(v.id.name.clone())),
                ("version", json::num(v.id.version as f64)),
                (
                    "backends",
                    Json::Arr(
                        v.slots()
                            .iter()
                            .map(|s| json::s(s.kind.name()))
                            .collect(),
                    ),
                ),
                ("default_backend", json::s(v.default_backend.name())),
                // artifact provenance (bundle path + entry + shard tag)
                // for models booted from a fab bundle; null otherwise
                (
                    "source",
                    v.provenance.clone().map(json::s).unwrap_or(Json::Null),
                ),
            ])
        })
        .collect();
    json::obj(vec![
        ("models", Json::Arr(models)),
        (
            "default_model",
            router
                .registry()
                .default_model()
                .map(json::s)
                .unwrap_or(Json::Null),
        ),
    ])
}

fn parse_body(body: &[u8]) -> Result<Json> {
    let text = std::str::from_utf8(body).map_err(|_| Error::Serve("body is not UTF-8".into()))?;
    Json::parse(text)
}

fn parse_backend(v: &Json) -> Result<Option<BackendKind>> {
    match v.get_str("backend") {
        Some(s) => Ok(Some(BackendKind::parse(s)?)),
        None => Ok(None),
    }
}

fn parse_row(v: &Json) -> Result<Vec<f32>> {
    v.as_arr()
        .ok_or_else(|| Error::Serve("features must be an array".into()))?
        .iter()
        .map(|x| {
            x.as_f64()
                .map(|f| f as f32)
                .ok_or_else(|| Error::Serve("features must be numbers".into()))
        })
        .collect()
}

fn classify(req: &Request, router: &Arc<Router>) -> Result<Json> {
    let v = parse_body(&req.body)?;
    let features = parse_row(
        v.get("features")
            .ok_or_else(|| Error::Serve("missing 'features'".into()))?,
    )?;
    let backend = parse_backend(&v)?;
    let model = v.get_str("model").map(String::from);
    let resp = router.classify(&ClassifyRequest {
        features,
        backend,
        model,
    })?;
    Ok(json::obj(vec![
        ("class", json::num(resp.class as f64)),
        ("label", json::s(resp.label)),
        ("backend", json::s(resp.backend.name())),
        ("model", json::s(resp.model)),
        (
            "steps",
            resp.steps.map(|s| json::num(s as f64)).unwrap_or(Json::Null),
        ),
        ("latency_us", json::num(resp.latency_us as f64)),
    ]))
}

fn classify_batch(req: &Request, router: &Arc<Router>) -> Result<Json> {
    let v = parse_body(&req.body)?;
    let rows = v
        .get("rows")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::Serve("missing 'rows' array".into()))?;
    if rows.is_empty() {
        return Err(Error::Serve("empty batch".into()));
    }
    // Parse straight into one flat row-major buffer: the first row fixes
    // the stride, every cell is appended in place — the request body is
    // the only per-row representation that ever exists.
    let first_len = rows[0].as_arr().map(|a| a.len()).unwrap_or(0);
    if first_len == 0 {
        return Err(Error::Serve("rows must be non-empty arrays of numbers".into()));
    }
    let mut batch = RowMatrixBuf::with_capacity(first_len, rows.len());
    for row in rows {
        let cells = row
            .as_arr()
            .ok_or_else(|| Error::Serve("rows must be arrays".into()))?;
        for c in cells {
            batch.push_cell(
                c.as_f64()
                    .map(|f| f as f32)
                    .ok_or_else(|| Error::Serve("features must be numbers".into()))?,
            );
        }
        batch
            .end_row()
            .map_err(|_| Error::Serve("rows must all have the same number of features".into()))?;
    }
    let backend = parse_backend(&v)?;
    let model = v.get_str("model").map(String::from);
    let want_steps = v.get("steps").and_then(Json::as_bool).unwrap_or(false);
    let (classes, steps, version) =
        router.classify_batch(batch.as_matrix(), backend, model.as_deref(), want_steps)?;
    let mut fields = vec![
        (
            "classes",
            Json::Arr(classes.iter().map(|&c| json::num(c as f64)).collect()),
        ),
        (
            "labels",
            Json::Arr(
                classes
                    .iter()
                    .map(|&c| json::s(version.label_of(c)))
                    .collect(),
            ),
        ),
        ("model", json::s(version.id.to_string())),
    ];
    if want_steps {
        fields.push((
            "steps",
            match steps {
                Some(s) => Json::Arr(s.iter().map(|&n| json::num(n as f64)).collect()),
                None => Json::Null,
            },
        ));
    }
    Ok(json::obj(fields))
}

/// Tiny blocking HTTP client for tests, examples and the bench harness.
pub fn http_request(addr: &str, method: &str, path: &str, body: Option<&Json>) -> Result<(u16, Json)> {
    let mut stream = TcpStream::connect(addr)?;
    let body_text = body.map(|b| b.to_string_compact()).unwrap_or_default();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body_text}",
        body_text.len()
    );
    stream.write_all(req.as_bytes())?;
    let mut buf = Vec::new();
    BufReader::new(stream).read_to_end(&mut buf)?;
    let text = String::from_utf8_lossy(&buf);
    let mut lines = text.split("\r\n");
    let status: u16 = lines
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| Error::Serve("malformed response".into()))?;
    let payload = text
        .split_once("\r\n\r\n")
        .map(|(_, b)| b)
        .unwrap_or("");
    let json = if payload.trim().is_empty() {
        Json::Null
    } else {
        Json::parse(payload.trim())?
    };
    Ok((status, json))
}
