//! HTTP endpoint layer, shared by both serving front-ends.
//!
//! [`respond`] is the single transport-independent entry point: it takes
//! a parsed [`Request`] and returns a [`Response`]. The sync
//! thread-per-connection loop ([`handle_connection`]) and the evented
//! front-end (`net::event_loop` via `serve::server`) both feed it
//! through the same parser and serialiser (`net::proto`), which is what
//! makes the two modes bit-identical on the wire.
//!
//! Endpoints:
//! - `GET  /healthz`          → `{"ok": true, "models": n}` (liveness:
//!   the registry is booted and serving `n` models)
//! - `GET  /readyz`           → readiness: `200` when models are loaded
//!   and no circuit breaker is open, `503` + the open `(model, backend)`
//!   pairs otherwise (a degraded-but-serving replica keeps `/healthz`
//!   green while load balancers drain on `/readyz`)
//! - `GET  /metrics`          → server metrics snapshot (end-to-end
//!   latency quantiles, connection gauges, `429` shed count, per-backend
//!   histograms); `?format=prometheus` renders the same series in
//!   Prometheus text format
//! - `GET  /debug/trace?n=`   → the last `n` committed request traces
//!   (id, status, per-stage spans) from the in-process trace ring
//! - `GET  /model`            → default-model description (per-backend info)
//! - `GET  /models`           → all registered models (name, version, backends,
//!   `source` = artifact provenance for bundle-booted models)
//! - `POST /classify`         → `{"features": [...], "backend": "dd"?, "model": "name"?,
//!   "probs": true?}` — with `"probs": true` the response carries the
//!   per-class vote counts and vote fractions (requires a
//!   vote-preserving backend; see docs/HTTP.md)
//! - `POST /classify_batch`   → `{"rows": [[...], ...], "backend": ...?, "model": ...?,
//!   "steps": true?, "probs": true?}` — with `"steps": true` the
//!   response carries the §6 step count per row (`null` when the
//!   backend cannot meter); with `"probs": true` the per-row vote
//!   distributions
//!
//! Regression models (schemas with a bin value table) additionally
//! answer with `value`/`values`: the vote-weighted mean prediction per
//! row. Both `POST` endpoints also accept the compact binary row frame
//! (`Content-Type: application/octet-stream`, see `net::proto`) that
//! deserialises straight into a [`RowMatrixBuf`]; `backend`, `model`,
//! `steps` and `probs` then travel in the query string. Responses are
//! always JSON.
//!
//! Backpressure: [`Error::Overloaded`] (a full batcher or dispatch
//! queue) maps to `429 Too Many Requests` + `Retry-After: 1`. Fault
//! containment: an expired deadline ([`Error::DeadlineExceeded`] — the
//! configured reply timeout, capped lower by a client `X-Deadline-Ms`
//! header) maps to `504`, a quarantined eval panic with no healthy
//! fallback ([`Error::EvalPanic`]) to `500`, and a breaker-rerouted
//! request announces its actual backend via `X-Served-By`. Every other
//! handler error maps to `400`.

use crate::batch::RowMatrixBuf;
use crate::error::{Error, Result};
use crate::net::proto::{self, Request, RequestParser, Response};
use crate::obs::trace::{self as obs_trace, ReqTrace, Stage, MAX_TRACE_SHARDS};
use crate::serve::router::Router;
use crate::serve::{BackendKind, ClassifyRequest};
use crate::util::json::{self, Json};
use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// `Retry-After` seconds advertised on `429` responses.
const RETRY_AFTER_S: u32 = 1;

/// Route one parsed request to its response — the single entry point
/// shared by both front-ends. Stamps the trace's `eval`/`serialize`
/// spans and echoes the request id (client's verbatim, server-minted
/// hex otherwise) as `X-Request-Id` on every response.
pub fn respond(req: &Request, router: &Arc<Router>, trace: &mut ReqTrace) -> Response {
    // Every request gets a deadline: the configured reply timeout,
    // capped lower by the client's `X-Deadline-Ms`. It is published
    // thread-locally so the router and the frozen sweep (which run on
    // this thread) can enforce it without threading a parameter through
    // the object-safe `Classifier` trait; batcher jobs carry it
    // explicitly across the thread hop.
    let cap = router.reply_timeout();
    let budget = req
        .deadline_ms
        .map(Duration::from_millis)
        .unwrap_or(cap)
        .min(cap);
    trace.set_deadline(Instant::now() + budget);
    obs_trace::set_eval_deadline(trace.deadline());
    let mut resp = match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => Response::json(
            200,
            &json::obj(vec![
                ("ok", Json::Bool(true)),
                (
                    "models",
                    json::num(router.registry().list().len() as f64),
                ),
            ]),
        ),
        ("GET", "/readyz") => readyz(router),
        ("GET", "/metrics") => match req.param("format") {
            Some("prometheus") => Response {
                status: 200,
                body: router.metrics().to_prometheus().into_bytes(),
                content_type: "text/plain; version=0.0.4",
                retry_after_s: None,
                request_id: None,
                served_by: None,
            },
            Some(other) => {
                Response::error(400, format!("unknown metrics format '{other}'"))
            }
            None => Response::json(200, &router.metrics().to_json()),
        },
        ("GET", "/debug/trace") => {
            let n = req
                .param("n")
                .and_then(|v| v.parse::<usize>().ok())
                .unwrap_or(32);
            Response::json(200, &json::obj(vec![("traces", obs_trace::recent(n))]))
        }
        ("GET", "/model") => into_response(model_info(router), router),
        ("GET", "/models") => Response::json(200, &model_list(router)),
        ("POST", "/classify") => into_response(classify(req, router, trace), router),
        ("POST", "/classify_batch") => {
            into_response(classify_batch(req, router, trace), router)
        }
        ("GET", _) | ("POST", _) => Response::error(404, format!("no such path {}", req.path)),
        _ => Response::error(405, "method not allowed"),
    };
    // clear the thread-local so the next request on this worker thread
    // (or a non-request caller) starts without a stale deadline
    obs_trace::set_eval_deadline(None);
    trace.record(Stage::Serialize);
    resp.served_by = trace.served_by;
    resp.request_id = Some(
        req.request_id
            .clone()
            .unwrap_or_else(|| format!("{:016x}", trace.id)),
    );
    resp
}

/// Readiness probe: `200` only while models are loaded and every
/// circuit breaker is closed. A degraded replica (open breaker) keeps
/// serving — `/healthz` stays green — but reports `503` here so load
/// balancers can drain it until the breakers re-close.
fn readyz(router: &Arc<Router>) -> Response {
    let models = router.registry().list().len();
    let open = router.breakers().open_breakers();
    let ready = models > 0 && open.is_empty();
    let body = json::obj(vec![
        ("ready", Json::Bool(ready)),
        ("models", json::num(models as f64)),
        ("degraded", Json::Bool(!open.is_empty())),
        (
            "open_breakers",
            Json::Arr(
                open.iter()
                    .map(|(model, kind)| json::s(format!("{model}/{}", kind.name())))
                    .collect(),
            ),
        ),
    ]);
    Response::json(if ready { 200 } else { 503 }, &body)
}

/// Map a handler result onto the wire contract: `Overloaded` is the
/// backpressure signal (`429` + `Retry-After`), an expired deadline is
/// `504`, a quarantined eval panic that no fallback could absorb is
/// `500`, everything else `400`.
fn into_response(result: Result<Json>, router: &Arc<Router>) -> Response {
    match result {
        Ok(j) => Response::json(200, &j),
        Err(Error::Overloaded(msg)) => {
            router.metrics().observe_rejected();
            Response::overloaded(RETRY_AFTER_S, msg)
        }
        Err(e @ Error::DeadlineExceeded(_)) => {
            router.metrics().observe_deadline_dropped();
            Response::error(504, e.to_string())
        }
        Err(e @ Error::EvalPanic { .. }) => Response::error(500, e.to_string()),
        Err(e) => Response::error(400, e.to_string()),
    }
}

/// Serve one sync-mode connection until it closes: keep-alive loop with
/// a per-connection read timeout, so a stalled client cannot pin a
/// worker thread forever (it gets `408` mid-request, silence between
/// requests).
pub fn handle_connection(stream: TcpStream, router: &Arc<Router>, read_timeout: Duration) {
    router.metrics().connection_opened();
    serve_blocking(stream, router, read_timeout);
    router.metrics().connection_closed();
}

fn serve_blocking(mut stream: TcpStream, router: &Arc<Router>, read_timeout: Duration) {
    let _ = stream.set_nodelay(true);
    if stream.set_read_timeout(Some(read_timeout)).is_err() {
        return;
    }
    let mut parser = RequestParser::new();
    let mut buf = [0u8; 16 * 1024];
    loop {
        // serve every buffered request before touching the socket again
        // (pipelined requests never wait on a read)
        loop {
            // trace origin: the completing parse call, like the evented
            // front-end — socket wait never counts against a request
            let t_parse = Instant::now();
            match parser.try_next() {
                Ok(Some(req)) => {
                    let id = req
                        .request_id
                        .as_deref()
                        .map(obs_trace::id_from_header)
                        .unwrap_or_else(obs_trace::next_id);
                    let mut trace = ReqTrace::new_at(id, t_parse);
                    trace.record(Stage::Parse);
                    let resp = respond(&req, router, &mut trace);
                    // error responses hang up (the seed server's
                    // behaviour) — matches the evented front-end
                    let keep = req.keep_alive && resp.status < 400;
                    let bytes = resp.to_bytes(keep);
                    if stream.write_all(&bytes).is_err() {
                        return;
                    }
                    let _ = stream.flush();
                    router.metrics().add_bytes_written(bytes.len() as u64);
                    trace.record(Stage::Write);
                    let total_us = trace.commit(resp.status);
                    router
                        .metrics()
                        .observe_request(Duration::from_micros(total_us));
                    if !keep {
                        return;
                    }
                }
                Ok(None) => break,
                Err(e) => {
                    let resp = Response::error(400, e.to_string());
                    let _ = stream.write_all(&resp.to_bytes(false));
                    return;
                }
            }
        }
        if crate::runtime::fault::fires(crate::runtime::fault::Point::ConnReadErr) {
            return; // injected read error: drop the connection, like evented
        }
        match stream.read(&mut buf) {
            Ok(0) => return, // orderly EOF
            Ok(n) => {
                parser.push(&buf[..n]);
                router.metrics().add_bytes_read(n as u64);
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                // read timeout: answer a stalled mid-request client with
                // 408, close an idle-at-boundary connection silently
                if !parser.is_idle() {
                    let resp = Response::error(408, "request read timed out");
                    let _ = stream.write_all(&resp.to_bytes(false));
                }
                return;
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return,
        }
    }
}

fn model_info(router: &Arc<Router>) -> Result<Json> {
    let version = router.registry().get(None)?;
    let backends: Vec<Json> = version
        .slots()
        .iter()
        .map(|slot| {
            let info = slot.classifier.info();
            json::obj(vec![
                ("backend", json::s(info.backend.name())),
                ("label", json::s(info.label)),
                ("size_nodes", json::num(info.size_nodes as f64)),
                (
                    "max_steps",
                    info.cost
                        .max_steps
                        .map(|s| json::num(s as f64))
                        .unwrap_or(Json::Null),
                ),
                (
                    "aggregation_reads",
                    json::num(info.cost.aggregation_reads as f64),
                ),
                (
                    "preferred_batch",
                    json::num(info.cost.preferred_batch as f64),
                ),
            ])
        })
        .collect();
    Ok(json::obj(vec![
        ("model", json::s(version.id.name.clone())),
        ("version", json::num(version.id.version as f64)),
        (
            "classes",
            Json::Arr(
                version
                    .schema
                    .classes
                    .iter()
                    .map(|c| json::s(c.clone()))
                    .collect(),
            ),
        ),
        (
            "task",
            json::s(if version.schema.task.is_regression() {
                "regression"
            } else {
                "classification"
            }),
        ),
        (
            "values",
            version
                .schema
                .values()
                .map(|vals| Json::Arr(vals.iter().map(|&v| json::num(v as f64)).collect()))
                .unwrap_or(Json::Null),
        ),
        ("backends", Json::Arr(backends)),
        ("default_backend", json::s(router.default_backend().name())),
        ("xla_loaded", Json::Bool(router.has_xla())),
    ]))
}

fn model_list(router: &Arc<Router>) -> Json {
    let models: Vec<Json> = router
        .registry()
        .list()
        .iter()
        .map(|v| {
            json::obj(vec![
                ("name", json::s(v.id.name.clone())),
                ("version", json::num(v.id.version as f64)),
                (
                    "backends",
                    Json::Arr(
                        v.slots()
                            .iter()
                            .map(|s| json::s(s.kind.name()))
                            .collect(),
                    ),
                ),
                ("default_backend", json::s(v.default_backend.name())),
                // artifact provenance (bundle path + entry + shard tag)
                // for models booted from a fab bundle; null otherwise
                (
                    "source",
                    v.provenance.clone().map(json::s).unwrap_or(Json::Null),
                ),
            ])
        })
        .collect();
    json::obj(vec![
        ("models", Json::Arr(models)),
        (
            "default_model",
            router
                .registry()
                .default_model()
                .map(json::s)
                .unwrap_or(Json::Null),
        ),
    ])
}

fn parse_body(body: &[u8]) -> Result<Json> {
    let text = std::str::from_utf8(body).map_err(|_| Error::Serve("body is not UTF-8".into()))?;
    Json::parse(text)
}

fn parse_backend(v: &Json) -> Result<Option<BackendKind>> {
    match v.get_str("backend") {
        Some(s) => Ok(Some(BackendKind::parse(s)?)),
        None => Ok(None),
    }
}

/// Backend selection for binary-frame requests (query string).
fn backend_param(req: &Request) -> Result<Option<BackendKind>> {
    match req.param("backend") {
        Some(s) if !s.is_empty() => Ok(Some(BackendKind::parse(s)?)),
        _ => Ok(None),
    }
}

/// Model selection for binary-frame requests (query string).
fn model_param(req: &Request) -> Option<String> {
    req.param("model")
        .filter(|m| !m.is_empty())
        .map(String::from)
}

fn parse_row(v: &Json) -> Result<Vec<f32>> {
    v.as_arr()
        .ok_or_else(|| Error::Serve("features must be an array".into()))?
        .iter()
        .map(|x| {
            x.as_f64()
                .map(|f| f as f32)
                .ok_or_else(|| Error::Serve("features must be numbers".into()))
        })
        .collect()
}

/// Whether the request opted into the inline trace breakdown
/// (`"trace": true` body field or `?trace=true` query parameter).
fn wants_trace(req: &Request, body: Option<&Json>) -> bool {
    matches!(req.param("trace"), Some("true") | Some("1"))
        || body
            .and_then(|v| v.get("trace"))
            .and_then(Json::as_bool)
            .unwrap_or(false)
}

/// Whether the request opted into the vote distribution (`"probs": true`
/// body field, or `?probs=true` on binary frames).
fn wants_probs(req: &Request, body: Option<&Json>) -> bool {
    matches!(req.param("probs"), Some("true") | Some("1"))
        || body
            .and_then(|v| v.get("probs"))
            .and_then(Json::as_bool)
            .unwrap_or(false)
}

fn classify(req: &Request, router: &Arc<Router>, trace: &mut ReqTrace) -> Result<Json> {
    let (features, backend, model, probs) = if req.is_binary() {
        trace.inline = wants_trace(req, None);
        let batch = proto::decode_rows(&req.body)?;
        let m = batch.as_matrix();
        if m.n_rows() != 1 {
            return Err(Error::Serve(format!(
                "binary /classify takes exactly 1 row, frame carries {}",
                m.n_rows()
            )));
        }
        (
            m.row(0).to_vec(),
            backend_param(req)?,
            model_param(req),
            wants_probs(req, None),
        )
    } else {
        let v = parse_body(&req.body)?;
        trace.inline = wants_trace(req, Some(&v));
        (
            parse_row(
                v.get("features")
                    .ok_or_else(|| Error::Serve("missing 'features'".into()))?,
            )?,
            parse_backend(&v)?,
            v.get_str("model").map(String::from),
            wants_probs(req, Some(&v)),
        )
    };
    let resp = router.classify(&ClassifyRequest {
        features,
        backend,
        model,
        probs,
    })?;
    trace.record(Stage::Eval);
    trace.served_by = resp.served_by.map(|k| k.name());
    let mut fields = vec![
        ("class", json::num(resp.class as f64)),
        ("label", json::s(resp.label)),
        ("backend", json::s(resp.backend.name())),
        ("model", json::s(resp.model)),
        (
            "steps",
            resp.steps.map(|s| json::num(s as f64)).unwrap_or(Json::Null),
        ),
        ("latency_us", json::num(resp.latency_us as f64)),
    ];
    if let Some(votes) = resp.votes {
        fields.push((
            "votes",
            Json::Arr(votes.iter().map(|&v| json::num(v as f64)).collect()),
        ));
    }
    if let Some(p) = resp.probs {
        fields.push(("probs", Json::Arr(p.into_iter().map(json::num).collect())));
    }
    if let Some(value) = resp.value {
        fields.push(("value", json::num(value)));
    }
    if let Some(kind) = resp.served_by {
        // only degraded responses carry the field (and the header)
        fields.push(("served_by", json::s(kind.name())));
    }
    if trace.inline {
        // serialize/write spans postdate the body — they land in the
        // trace ring (/debug/trace), not in their own payload
        fields.push(("trace", trace.breakdown_json()));
    }
    Ok(json::obj(fields))
}

fn classify_batch(req: &Request, router: &Arc<Router>, trace: &mut ReqTrace) -> Result<Json> {
    let (batch, backend, model, want_steps, want_probs) = if req.is_binary() {
        trace.inline = wants_trace(req, None);
        // the binary fast path: the body deserialises straight into the
        // flat batch buffer, no JSON parser anywhere on the row path
        (
            proto::decode_rows(&req.body)?,
            backend_param(req)?,
            model_param(req),
            matches!(req.param("steps"), Some("true") | Some("1")),
            wants_probs(req, None),
        )
    } else {
        let v = parse_body(&req.body)?;
        trace.inline = wants_trace(req, Some(&v));
        let rows = v
            .get("rows")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Serve("missing 'rows' array".into()))?;
        if rows.is_empty() {
            return Err(Error::Serve("empty batch".into()));
        }
        // Parse straight into one flat row-major buffer: the first row
        // fixes the stride, every cell is appended in place — the request
        // body is the only per-row representation that ever exists.
        let first_len = rows[0].as_arr().map(|a| a.len()).unwrap_or(0);
        if first_len == 0 {
            return Err(Error::Serve("rows must be non-empty arrays of numbers".into()));
        }
        let mut batch = RowMatrixBuf::with_capacity(first_len, rows.len());
        for row in rows {
            let cells = row
                .as_arr()
                .ok_or_else(|| Error::Serve("rows must be arrays".into()))?;
            for c in cells {
                batch.push_cell(
                    c.as_f64()
                        .map(|f| f as f32)
                        .ok_or_else(|| Error::Serve("features must be numbers".into()))?,
                );
            }
            batch.end_row().map_err(|_| {
                Error::Serve("rows must all have the same number of features".into())
            })?;
        }
        (
            batch,
            parse_backend(&v)?,
            v.get_str("model").map(String::from),
            v.get("steps").and_then(Json::as_bool).unwrap_or(false),
            wants_probs(req, Some(&v)),
        )
    };
    let routed = router.classify_batch(
        batch.as_matrix(),
        backend,
        model.as_deref(),
        want_steps,
        want_probs,
    )?;
    let (classes, steps, version) = (routed.classes, routed.steps, routed.version);
    trace.record(Stage::Eval);
    trace.served_by = routed.rerouted.map(|k| k.name());
    if trace.inline {
        // best-effort sample of the most recent sharded pool run — only
        // large batches shard, so this is often empty
        let mut shard_us = [0u64; MAX_TRACE_SHARDS];
        let n = obs_trace::sample_last_run(&mut shard_us);
        trace.set_shards(&shard_us[..n]);
    }
    let mut fields = vec![
        (
            "classes",
            Json::Arr(classes.iter().map(|&c| json::num(c as f64)).collect()),
        ),
        (
            "labels",
            Json::Arr(
                classes
                    .iter()
                    .map(|&c| json::s(version.label_of(c)))
                    .collect(),
            ),
        ),
        ("model", json::s(version.id.to_string())),
    ];
    if let Some(votes) = &routed.votes {
        let k = version.schema.n_classes();
        fields.push((
            "votes",
            Json::Arr(
                votes
                    .chunks_exact(k)
                    .map(|c| Json::Arr(c.iter().map(|&v| json::num(v as f64)).collect()))
                    .collect(),
            ),
        ));
        fields.push((
            "probs",
            Json::Arr(
                votes
                    .chunks_exact(k)
                    .map(|c| {
                        Json::Arr(
                            crate::add::terminal::probabilities(c)
                                .into_iter()
                                .map(json::num)
                                .collect(),
                        )
                    })
                    .collect(),
            ),
        ));
    }
    if let Some(values) = &routed.values {
        fields.push((
            "values",
            Json::Arr(values.iter().map(|&v| json::num(v)).collect()),
        ));
    }
    if let Some(kind) = routed.rerouted {
        fields.push(("served_by", json::s(kind.name())));
    }
    if want_steps {
        fields.push((
            "steps",
            match steps {
                Some(s) => Json::Arr(s.iter().map(|&n| json::num(n as f64)).collect()),
                None => Json::Null,
            },
        ));
    }
    if trace.inline {
        fields.push(("trace", trace.breakdown_json()));
    }
    Ok(json::obj(fields))
}

/// Persistent keep-alive HTTP/1.1 client: one connection, many
/// requests. Used by the `loadgen` CLI command, the benches, and the
/// bit-identity integration tests (JSON and binary bodies alike).
pub struct HttpClient {
    reader: BufReader<TcpStream>,
}

impl HttpClient {
    /// Open a keep-alive connection.
    pub fn connect(addr: &str) -> Result<HttpClient> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        Ok(HttpClient {
            reader: BufReader::new(stream),
        })
    }

    /// One request/response round trip over the persistent connection.
    /// Returns `(status, headers, body)`.
    pub fn request_raw(
        &mut self,
        method: &str,
        path: &str,
        content_type: &str,
        body: &[u8],
    ) -> Result<(u16, Vec<(String, String)>, Vec<u8>)> {
        self.request_raw_with_headers(method, path, content_type, &[], body)
    }

    /// Like [`HttpClient::request_raw`] with extra request headers
    /// (e.g. `X-Request-Id` for trace-propagation tests).
    pub fn request_raw_with_headers(
        &mut self,
        method: &str,
        path: &str,
        content_type: &str,
        extra_headers: &[(&str, &str)],
        body: &[u8],
    ) -> Result<(u16, Vec<(String, String)>, Vec<u8>)> {
        let mut head = format!(
            "{method} {path} HTTP/1.1\r\nHost: client\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n",
            body.len()
        );
        for (k, v) in extra_headers {
            head.push_str(&format!("{k}: {v}\r\n"));
        }
        head.push_str("\r\n");
        let stream = self.reader.get_mut();
        stream.write_all(head.as_bytes())?;
        stream.write_all(body)?;
        stream.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| Error::Serve(format!("malformed status line {line:?}")))?;
        let mut headers = Vec::new();
        let mut content_length = 0usize;
        loop {
            let mut h = String::new();
            self.reader.read_line(&mut h)?;
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                let (k, v) = (k.trim().to_string(), v.trim().to_string());
                if k.eq_ignore_ascii_case("content-length") {
                    content_length = v
                        .parse()
                        .map_err(|_| Error::Serve("bad content-length".into()))?;
                }
                headers.push((k, v));
            }
        }
        let mut body = vec![0u8; content_length];
        self.reader.read_exact(&mut body)?;
        Ok((status, headers, body))
    }

    /// A JSON request/response round trip.
    pub fn request_json(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&Json>,
    ) -> Result<(u16, Json)> {
        let text = body.map(|b| b.to_string_compact()).unwrap_or_default();
        let (status, _, body) =
            self.request_raw(method, path, "application/json", text.as_bytes())?;
        let text = String::from_utf8_lossy(&body);
        let json = if text.trim().is_empty() {
            Json::Null
        } else {
            Json::parse(text.trim())?
        };
        Ok((status, json))
    }

    /// A body-less GET.
    pub fn get(&mut self, path: &str) -> Result<(u16, Json)> {
        self.request_json("GET", path, None)
    }
}

/// Tiny blocking one-shot HTTP client (`Connection: close`) for tests,
/// examples and the bench harness.
pub fn http_request(addr: &str, method: &str, path: &str, body: Option<&Json>) -> Result<(u16, Json)> {
    let mut stream = TcpStream::connect(addr)?;
    let body_text = body.map(|b| b.to_string_compact()).unwrap_or_default();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body_text}",
        body_text.len()
    );
    stream.write_all(req.as_bytes())?;
    let mut buf = Vec::new();
    BufReader::new(stream).read_to_end(&mut buf)?;
    let text = String::from_utf8_lossy(&buf);
    let mut lines = text.split("\r\n");
    let status: u16 = lines
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| Error::Serve("malformed response".into()))?;
    let payload = text
        .split_once("\r\n\r\n")
        .map(|(_, b)| b)
        .unwrap_or("");
    let json = if payload.trim().is_empty() {
        Json::Null
    } else {
        Json::parse(payload.trim())?
    };
    Ok((status, json))
}
