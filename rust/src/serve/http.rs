//! Minimal HTTP/1.1 front-end over `std::net` (no async runtime is
//! available offline; a thread-pool accept loop serves the same purpose
//! for this request shape).
//!
//! Endpoints:
//! - `GET  /healthz`          → `{"ok": true}`
//! - `GET  /metrics`          → server metrics snapshot
//! - `GET  /model`            → model/bundle description
//! - `POST /classify`         → `{"features": [...], "backend": "dd"?}`
//! - `POST /classify_batch`   → `{"rows": [[...], ...], "backend": ...?}`

use crate::error::{Error, Result};
use crate::serve::router::Router;
use crate::serve::{BackendKind, ClassifyRequest};
use crate::util::json::{self, Json};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

/// Maximum accepted request body (1 MiB — batches of a few thousand rows).
const MAX_BODY: usize = 1 << 20;

/// Parsed request.
struct Request {
    method: String,
    path: String,
    body: Vec<u8>,
}

fn read_request(stream: &mut TcpStream) -> Result<Request> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| Error::Serve("empty request line".into()))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| Error::Serve("request line missing path".into()))?
        .to_string();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v
                    .trim()
                    .parse()
                    .map_err(|_| Error::Serve("bad content-length".into()))?;
            }
        }
    }
    if content_length > MAX_BODY {
        return Err(Error::Serve(format!("body too large ({content_length} bytes)")));
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(Request { method, path, body })
}

fn write_response(stream: &mut TcpStream, status: u16, body: &Json) -> Result<()> {
    let body = body.to_string_compact();
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        _ => "Internal Server Error",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// Handle one connection: parse, route, respond. Errors become JSON
/// error bodies; connection-level failures are logged and dropped.
pub fn handle_connection(mut stream: TcpStream, router: &Arc<Router>) {
    let _ = stream.set_nodelay(true);
    let response = match read_request(&mut stream) {
        Ok(req) => route(&req, router),
        Err(e) => (400, json::obj(vec![("error", json::s(e.to_string()))])),
    };
    if let Err(e) = write_response(&mut stream, response.0, &response.1) {
        crate::log_debug!("http: failed to write response: {e}");
    }
}

fn route(req: &Request, router: &Arc<Router>) -> (u16, Json) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => (200, json::obj(vec![("ok", Json::Bool(true))])),
        ("GET", "/metrics") => (200, router.metrics().to_json()),
        ("GET", "/model") => (200, model_info(router)),
        ("POST", "/classify") => match classify(req, router) {
            Ok(j) => (200, j),
            Err(e) => (400, json::obj(vec![("error", json::s(e.to_string()))])),
        },
        ("POST", "/classify_batch") => match classify_batch(req, router) {
            Ok(j) => (200, j),
            Err(e) => (400, json::obj(vec![("error", json::s(e.to_string()))])),
        },
        ("GET", _) | ("POST", _) => (
            404,
            json::obj(vec![("error", json::s(format!("no such path {}", req.path)))]),
        ),
        _ => (
            405,
            json::obj(vec![("error", json::s("method not allowed"))]),
        ),
    }
}

fn model_info(router: &Arc<Router>) -> Json {
    let b = router.bundle();
    let size = b.dd.size();
    json::obj(vec![
        ("dataset", json::s(b.forest.schema.classes.join("/"))),
        ("trees", json::num(b.forest.n_trees() as f64)),
        ("forest_nodes", json::num(b.forest.n_nodes() as f64)),
        ("dd_nodes", json::num(size.total() as f64)),
        ("dd_label", json::s(b.dd.label())),
        (
            "classes",
            Json::Arr(
                b.forest
                    .schema
                    .classes
                    .iter()
                    .map(|c| json::s(c.clone()))
                    .collect(),
            ),
        ),
        ("default_backend", json::s(router.default_backend().name())),
        ("xla_loaded", Json::Bool(router.has_xla())),
    ])
}

fn parse_body(body: &[u8]) -> Result<Json> {
    let text = std::str::from_utf8(body).map_err(|_| Error::Serve("body is not UTF-8".into()))?;
    Json::parse(text)
}

fn parse_backend(v: &Json) -> Result<Option<BackendKind>> {
    match v.get_str("backend") {
        Some(s) => Ok(Some(BackendKind::parse(s)?)),
        None => Ok(None),
    }
}

fn parse_row(v: &Json) -> Result<Vec<f32>> {
    v.as_arr()
        .ok_or_else(|| Error::Serve("features must be an array".into()))?
        .iter()
        .map(|x| {
            x.as_f64()
                .map(|f| f as f32)
                .ok_or_else(|| Error::Serve("features must be numbers".into()))
        })
        .collect()
}

fn classify(req: &Request, router: &Arc<Router>) -> Result<Json> {
    let v = parse_body(&req.body)?;
    let features = parse_row(
        v.get("features")
            .ok_or_else(|| Error::Serve("missing 'features'".into()))?,
    )?;
    let backend = parse_backend(&v)?;
    let resp = router.classify(&ClassifyRequest { features, backend })?;
    Ok(json::obj(vec![
        ("class", json::num(resp.class as f64)),
        ("label", json::s(resp.label)),
        ("backend", json::s(resp.backend.name())),
        (
            "steps",
            resp.steps.map(|s| json::num(s as f64)).unwrap_or(Json::Null),
        ),
        ("latency_us", json::num(resp.latency_us as f64)),
    ]))
}

fn classify_batch(req: &Request, router: &Arc<Router>) -> Result<Json> {
    let v = parse_body(&req.body)?;
    let rows: Vec<Vec<f32>> = v
        .get("rows")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::Serve("missing 'rows' array".into()))?
        .iter()
        .map(parse_row)
        .collect::<Result<_>>()?;
    if rows.is_empty() {
        return Err(Error::Serve("empty batch".into()));
    }
    let backend = parse_backend(&v)?;
    let classes = router.classify_batch(&rows, backend)?;
    let bundle = router.bundle();
    Ok(json::obj(vec![
        (
            "classes",
            Json::Arr(classes.iter().map(|&c| json::num(c as f64)).collect()),
        ),
        (
            "labels",
            Json::Arr(classes.iter().map(|&c| json::s(bundle.label(c))).collect()),
        ),
    ]))
}

/// Tiny blocking HTTP client for tests, examples and the bench harness.
pub fn http_request(addr: &str, method: &str, path: &str, body: Option<&Json>) -> Result<(u16, Json)> {
    let mut stream = TcpStream::connect(addr)?;
    let body_text = body.map(|b| b.to_string_compact()).unwrap_or_default();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body_text}",
        body_text.len()
    );
    stream.write_all(req.as_bytes())?;
    let mut buf = Vec::new();
    BufReader::new(stream).read_to_end(&mut buf)?;
    let text = String::from_utf8_lossy(&buf);
    let mut lines = text.split("\r\n");
    let status: u16 = lines
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| Error::Serve("malformed response".into()))?;
    let payload = text
        .split_once("\r\n\r\n")
        .map(|(_, b)| b)
        .unwrap_or("");
    let json = if payload.trim().is_empty() {
        Json::Null
    } else {
        Json::parse(payload.trim())?
    };
    Ok((status, json))
}
