//! Server metrics: lock-free counters and log₂ latency histograms,
//! exported as a JSON snapshot (`GET /metrics`) and in Prometheus text
//! format (`GET /metrics?format=prometheus`).

use crate::obs::prom::PromWriter;
use crate::serve::BackendKind;
use crate::util::json::{self, Json};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Number of log₂ microsecond buckets (`2^0 .. 2^N` µs, last = overflow).
pub const BUCKETS: usize = 24;

/// A latency histogram with power-of-two microsecond buckets.
#[derive(Debug, Default)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Histogram {
    /// Record one duration observation.
    pub fn observe(&self, d: Duration) {
        self.observe_value(d.as_micros().min(u128::from(u64::MAX)) as u64);
    }

    /// Record one raw-valued observation (same log₂ buckets; used for
    /// unit-less series like dispatched batch sizes).
    pub fn observe_value(&self, v: u64) {
        let idx = (64 - v.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(v, Ordering::Relaxed);
        self.max_us.fetch_max(v, Ordering::Relaxed);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in µs.
    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Approximate quantile from bucket boundaries: the upper bound of
    /// the bucket containing the q-th observation, clamped to the
    /// largest observed value so a quantile can never exceed anything
    /// actually recorded (one 5000 µs sample reports 5000, not 8192).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * q).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return (1u64 << (i + 1)).min(self.max_us.load(Ordering::Relaxed));
            }
        }
        self.max_us.load(Ordering::Relaxed)
    }

    /// Raw bucket counts: bucket `i` holds values in `[2^i, 2^(i+1)-1]`
    /// (0 and 1 both land in bucket 0); the last bucket is the overflow
    /// tail.
    pub fn bucket_counts(&self) -> [u64; BUCKETS] {
        let mut out = [0u64; BUCKETS];
        for (dst, src) in out.iter_mut().zip(&self.buckets) {
            *dst = src.load(Ordering::Relaxed);
        }
        out
    }

    /// Sum of all observed values (µs for duration series).
    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Largest observed value (µs for duration series).
    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// JSON snapshot with microsecond-suffixed keys (duration series).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("count", json::num(self.count() as f64)),
            ("mean_us", json::num(self.mean_us())),
            ("p50_us", json::num(self.quantile_us(0.5) as f64)),
            ("p95_us", json::num(self.quantile_us(0.95) as f64)),
            ("p99_us", json::num(self.quantile_us(0.99) as f64)),
            (
                "max_us",
                json::num(self.max_us.load(Ordering::Relaxed) as f64),
            ),
        ])
    }

    /// JSON snapshot with unit-neutral keys, for raw-valued series
    /// recorded via [`observe_value`](Self::observe_value) (e.g. batch
    /// sizes) — a `_us` suffix on row counts would misread as latency.
    pub fn to_json_values(&self) -> Json {
        json::obj(vec![
            ("count", json::num(self.count() as f64)),
            ("mean", json::num(self.mean_us())),
            ("p50", json::num(self.quantile_us(0.5) as f64)),
            ("p95", json::num(self.quantile_us(0.95) as f64)),
            ("p99", json::num(self.quantile_us(0.99) as f64)),
            (
                "max",
                json::num(self.max_us.load(Ordering::Relaxed) as f64),
            ),
        ])
    }
}

/// Aggregated server metrics.
#[derive(Debug)]
pub struct ServerMetrics {
    started: Instant,
    /// Total requests accepted.
    pub requests: AtomicU64,
    /// Requests that failed.
    pub errors: AtomicU64,
    /// Per-backend latency histograms (indexed by `BackendKind`).
    forest: Histogram,
    dd: Histogram,
    frozen: Histogram,
    xla: Histogram,
    /// Dynamic batcher: batches dispatched and total batched items.
    pub batches: AtomicU64,
    /// Total items across all dispatched batches.
    pub batched_items: AtomicU64,
    /// Distribution of dispatched batch sizes (rows per batch; both the
    /// dynamic batcher and the explicit batch endpoint record here).
    pub batch_size: Histogram,
    /// Per-batch evaluation time.
    pub batch_eval_us: Histogram,
    /// Configured evaluation parallelism (workers + caller; set by the
    /// server at startup from `ServeConfig::eval_threads`).
    pub eval_threads: AtomicU64,
    /// End-to-end request latency (request parsed → response flushed),
    /// across every endpoint and both front-ends.
    pub request_us: Histogram,
    /// Currently open connections (gauge).
    pub connections_open: AtomicU64,
    /// Connections accepted since startup.
    pub connections_total: AtomicU64,
    /// Requests shed with `429` by admission control (full dispatch or
    /// batcher queue).
    pub rejected: AtomicU64,
    /// Requests shed with `429` by the per-connection in-flight cap
    /// (`ServeConfig::conn_max_inflight`; also included in `rejected`).
    pub conn_rejected: AtomicU64,
    /// Eval panics caught and quarantined (the request got `500` or was
    /// rerouted; the process kept serving).
    pub eval_panics: AtomicU64,
    /// Requests answered `504` because their deadline expired (at
    /// admission, in the batch queue, or during eval).
    pub deadline_dropped: AtomicU64,
    /// Requests transparently served by a fallback backend because a
    /// circuit breaker was open.
    pub degraded_requests: AtomicU64,
    /// Circuit breakers currently open or half-open (gauge, mirrored
    /// from the router's breaker board).
    pub breakers_open: AtomicU64,
    /// Total closed → open breaker transitions (mirrored counter).
    pub breaker_trips: AtomicU64,
    /// Total bytes read from client sockets (both front-ends).
    pub bytes_read_total: AtomicU64,
    /// Total bytes written to client sockets (both front-ends).
    pub bytes_written_total: AtomicU64,
    /// Requests currently queued for the evented dispatch pool (gauge).
    pub dispatch_queue_depth: AtomicU64,
    /// Jobs currently queued for the dynamic batcher (gauge).
    pub batch_queue_depth: AtomicU64,
    /// Requests that asked for the per-class vote distribution
    /// (`"probs": true` on `/classify` or `/classify_batch`).
    pub prob_requests: AtomicU64,
    /// Decisions re-ranked by `ServeConfig::class_weights`
    /// (per row on the batch path).
    pub weighted_decisions: AtomicU64,
    /// Regression predictions served (vote-weighted bin means; per row
    /// on the batch path).
    pub regression_predictions: AtomicU64,
    /// Front-end marker: 1 = evented, 0 = sync (set once at startup).
    io_evented: AtomicU64,
    /// Active frozen-sweep SIMD kernel, stored as its
    /// [`Kernel::code`](crate::runtime::simd::Kernel::code) (0 = scalar;
    /// set once at startup after `ServeConfig::simd` and
    /// `FOREST_ADD_NO_SIMD` are resolved).
    simd_kernel: AtomicU64,
}

impl Default for ServerMetrics {
    fn default() -> Self {
        ServerMetrics {
            started: Instant::now(),
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            forest: Histogram::default(),
            dd: Histogram::default(),
            frozen: Histogram::default(),
            xla: Histogram::default(),
            batches: AtomicU64::new(0),
            batched_items: AtomicU64::new(0),
            batch_size: Histogram::default(),
            batch_eval_us: Histogram::default(),
            eval_threads: AtomicU64::new(0),
            request_us: Histogram::default(),
            connections_open: AtomicU64::new(0),
            connections_total: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            conn_rejected: AtomicU64::new(0),
            eval_panics: AtomicU64::new(0),
            deadline_dropped: AtomicU64::new(0),
            degraded_requests: AtomicU64::new(0),
            breakers_open: AtomicU64::new(0),
            breaker_trips: AtomicU64::new(0),
            bytes_read_total: AtomicU64::new(0),
            bytes_written_total: AtomicU64::new(0),
            dispatch_queue_depth: AtomicU64::new(0),
            batch_queue_depth: AtomicU64::new(0),
            prob_requests: AtomicU64::new(0),
            weighted_decisions: AtomicU64::new(0),
            regression_predictions: AtomicU64::new(0),
            io_evented: AtomicU64::new(0),
            simd_kernel: AtomicU64::new(0),
        }
    }
}

impl ServerMetrics {
    /// The histogram for a backend.
    pub fn backend(&self, kind: BackendKind) -> &Histogram {
        match kind {
            BackendKind::Forest => &self.forest,
            BackendKind::Dd => &self.dd,
            BackendKind::Frozen => &self.frozen,
            BackendKind::Xla => &self.xla,
        }
    }

    /// Record a served request.
    pub fn observe(&self, kind: BackendKind, latency: Duration) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.backend(kind).observe(latency);
    }

    /// Record a failed request.
    pub fn observe_error(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a dispatched batch of `n` items.
    pub fn observe_batch(&self, n: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_items.fetch_add(n as u64, Ordering::Relaxed);
        self.batch_size.observe_value(n as u64);
    }

    /// Record the evaluation time of one dispatched batch.
    pub fn observe_batch_eval(&self, d: Duration) {
        self.batch_eval_us.observe(d);
    }

    /// Record the end-to-end latency of one served request.
    pub fn observe_request(&self, latency: Duration) {
        self.request_us.observe(latency);
    }

    /// Record a request shed with `429`.
    pub fn observe_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a request shed with `429` by the per-connection cap
    /// (counts in both `rejected` and `conn_rejected`).
    pub fn observe_conn_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
        self.conn_rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a quarantined eval panic.
    pub fn observe_eval_panic(&self) {
        self.eval_panics.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a request dropped because its deadline expired (`504`).
    pub fn observe_deadline_dropped(&self) {
        self.deadline_dropped.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a request served by a fallback backend (breaker open).
    pub fn observe_degraded(&self) {
        self.degraded_requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a request that asked for the vote distribution.
    pub fn observe_prob_request(&self) {
        self.prob_requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Record `n` decisions re-ranked by configured class weights.
    pub fn observe_weighted_decisions(&self, n: u64) {
        self.weighted_decisions.fetch_add(n, Ordering::Relaxed);
    }

    /// Record `n` regression predictions served.
    pub fn observe_regression_predictions(&self, n: u64) {
        self.regression_predictions.fetch_add(n, Ordering::Relaxed);
    }

    /// Mirror the breaker board's gauges into the snapshot (called by
    /// the router after every recorded eval outcome).
    pub fn sync_breakers(&self, open: u64, trips: u64) {
        self.breakers_open.store(open, Ordering::Relaxed);
        self.breaker_trips.store(trips, Ordering::Relaxed);
    }

    /// A connection was accepted (front-end connection gauges).
    pub fn connection_opened(&self) {
        self.connections_open.fetch_add(1, Ordering::Relaxed);
        self.connections_total.fetch_add(1, Ordering::Relaxed);
    }

    /// A connection was closed.
    pub fn connection_closed(&self) {
        // saturating: a miscounted close must not wrap the gauge
        let _ = self.connections_open.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |n| n.checked_sub(1),
        );
    }

    /// Record which front-end serves this process (shown in `/metrics`).
    pub fn set_io_mode(&self, evented: bool) {
        self.io_evented.store(u64::from(evented), Ordering::Relaxed);
    }

    /// Record the frozen-sweep SIMD kernel this process resolved at
    /// startup (shown in `/metrics` as `simd_kernel`).
    pub fn set_simd_kernel(&self, kernel: crate::runtime::simd::Kernel) {
        self.simd_kernel
            .store(u64::from(kernel.code()), Ordering::Relaxed);
    }

    fn simd_kernel(&self) -> crate::runtime::simd::Kernel {
        crate::runtime::simd::Kernel::from_code(self.simd_kernel.load(Ordering::Relaxed) as u8)
    }

    /// Account bytes read from a client socket.
    pub fn add_bytes_read(&self, n: u64) {
        self.bytes_read_total.fetch_add(n, Ordering::Relaxed);
    }

    /// Account bytes written to a client socket.
    pub fn add_bytes_written(&self, n: u64) {
        self.bytes_written_total.fetch_add(n, Ordering::Relaxed);
    }

    /// A job entered the dynamic batcher queue.
    pub fn batch_enqueued(&self) {
        self.batch_queue_depth.fetch_add(1, Ordering::Relaxed);
    }

    /// `n` jobs left the dynamic batcher queue (saturating: a miscount
    /// must not wrap the gauge).
    pub fn batch_dequeued(&self, n: u64) {
        let _ = self.batch_queue_depth.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |v| Some(v.saturating_sub(n)),
        );
    }

    /// Mean items per dispatched batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_items.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Full JSON snapshot (the `/metrics` endpoint body).
    pub fn to_json(&self) -> Json {
        let uptime = self.started.elapsed().as_secs_f64();
        let requests = self.requests.load(Ordering::Relaxed);
        json::obj(vec![
            ("uptime_s", json::num(uptime)),
            (
                "io_mode",
                json::s(if self.io_evented.load(Ordering::Relaxed) == 1 {
                    "evented"
                } else {
                    "sync"
                }),
            ),
            ("simd_kernel", json::s(self.simd_kernel().name())),
            ("requests", json::num(requests as f64)),
            (
                "errors",
                json::num(self.errors.load(Ordering::Relaxed) as f64),
            ),
            (
                "rejected_429",
                json::num(self.rejected.load(Ordering::Relaxed) as f64),
            ),
            (
                "conn_rejected_429",
                json::num(self.conn_rejected.load(Ordering::Relaxed) as f64),
            ),
            (
                "degraded",
                Json::Bool(self.breakers_open.load(Ordering::Relaxed) > 0),
            ),
            (
                "breakers",
                json::obj(vec![
                    (
                        "open",
                        json::num(self.breakers_open.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "trips",
                        json::num(self.breaker_trips.load(Ordering::Relaxed) as f64),
                    ),
                ]),
            ),
            (
                "fault",
                json::obj(vec![
                    (
                        "eval_panics",
                        json::num(self.eval_panics.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "deadline_dropped",
                        json::num(self.deadline_dropped.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "degraded_requests",
                        json::num(self.degraded_requests.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "injected",
                        json::num(crate::runtime::fault::fired_total() as f64),
                    ),
                ]),
            ),
            (
                "votes",
                json::obj(vec![
                    (
                        "prob_requests",
                        json::num(self.prob_requests.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "weighted_decisions",
                        json::num(self.weighted_decisions.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "regression_predictions",
                        json::num(self.regression_predictions.load(Ordering::Relaxed) as f64),
                    ),
                ]),
            ),
            ("request_us", self.request_us.to_json()),
            (
                "connections",
                json::obj(vec![
                    (
                        "open",
                        json::num(self.connections_open.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "total",
                        json::num(self.connections_total.load(Ordering::Relaxed) as f64),
                    ),
                ]),
            ),
            (
                "throughput_rps",
                json::num(if uptime > 0.0 {
                    requests as f64 / uptime
                } else {
                    0.0
                }),
            ),
            (
                "bytes",
                json::obj(vec![
                    (
                        "read",
                        json::num(self.bytes_read_total.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "written",
                        json::num(self.bytes_written_total.load(Ordering::Relaxed) as f64),
                    ),
                ]),
            ),
            (
                "queue_depth",
                json::obj(vec![
                    (
                        "dispatch",
                        json::num(self.dispatch_queue_depth.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "batch",
                        json::num(self.batch_queue_depth.load(Ordering::Relaxed) as f64),
                    ),
                ]),
            ),
            ("mean_batch_size", json::num(self.mean_batch_size())),
            ("batch_size", self.batch_size.to_json_values()),
            ("batch_eval_us", self.batch_eval_us.to_json()),
            ("eval_shards", eval_shards_json()),
            (
                "eval_threads",
                json::num(self.eval_threads.load(Ordering::Relaxed) as f64),
            ),
            (
                "backends",
                json::obj(vec![
                    ("forest", self.forest.to_json()),
                    ("dd", self.dd.to_json()),
                    ("frozen", self.frozen.to_json()),
                    ("xla", self.xla.to_json()),
                ]),
            ),
        ])
    }

    /// Prometheus text-format snapshot
    /// (`GET /metrics?format=prometheus`). Histograms render as
    /// cumulative `le` buckets + `_sum`/`_count`; the per-shard eval
    /// timing table comes from the process-wide pool instrumentation.
    pub fn to_prometheus(&self) -> String {
        let mut w = PromWriter::new();
        w.gauge(
            "forest_uptime_seconds",
            "seconds since server start",
            self.started.elapsed().as_secs_f64(),
        );
        w.gauge(
            "forest_io_evented",
            "1 when the evented front-end serves this process",
            self.io_evented.load(Ordering::Relaxed) as f64,
        );
        w.header(
            "forest_simd_kernel",
            "gauge",
            "active frozen-sweep SIMD kernel (1 on the kernel label)",
        );
        w.sample(
            "forest_simd_kernel",
            &[("kernel", self.simd_kernel().name())],
            1.0,
        );
        w.counter(
            "forest_requests_total",
            "requests accepted",
            self.requests.load(Ordering::Relaxed),
        );
        w.counter(
            "forest_errors_total",
            "requests that failed",
            self.errors.load(Ordering::Relaxed),
        );
        w.counter(
            "forest_rejected_total",
            "requests shed with 429 by admission control",
            self.rejected.load(Ordering::Relaxed),
        );
        w.counter(
            "forest_conn_rejected_total",
            "requests shed with 429 by the per-connection in-flight cap",
            self.conn_rejected.load(Ordering::Relaxed),
        );
        w.counter(
            "forest_eval_panics_total",
            "eval panics caught and quarantined",
            self.eval_panics.load(Ordering::Relaxed),
        );
        w.counter(
            "forest_deadline_dropped_total",
            "requests answered 504 after their deadline expired",
            self.deadline_dropped.load(Ordering::Relaxed),
        );
        w.counter(
            "forest_degraded_requests_total",
            "requests served by a fallback backend while a breaker was open",
            self.degraded_requests.load(Ordering::Relaxed),
        );
        w.gauge(
            "forest_breakers_open",
            "circuit breakers currently open or half-open",
            self.breakers_open.load(Ordering::Relaxed) as f64,
        );
        w.counter(
            "forest_breaker_trips_total",
            "circuit breaker closed-to-open transitions",
            self.breaker_trips.load(Ordering::Relaxed),
        );
        w.counter(
            "forest_prob_requests_total",
            "requests that asked for the vote distribution",
            self.prob_requests.load(Ordering::Relaxed),
        );
        w.counter(
            "forest_weighted_decisions_total",
            "decisions re-ranked by configured class weights",
            self.weighted_decisions.load(Ordering::Relaxed),
        );
        w.counter(
            "forest_regression_predictions_total",
            "regression predictions served (vote-weighted bin means)",
            self.regression_predictions.load(Ordering::Relaxed),
        );
        w.counter(
            "forest_faults_injected_total",
            "faults fired by the deterministic injection harness",
            crate::runtime::fault::fired_total(),
        );
        w.gauge(
            "forest_connections_open",
            "currently open connections",
            self.connections_open.load(Ordering::Relaxed) as f64,
        );
        w.counter(
            "forest_connections_total",
            "connections accepted since start",
            self.connections_total.load(Ordering::Relaxed),
        );
        w.counter(
            "forest_bytes_read_total",
            "bytes read from client sockets",
            self.bytes_read_total.load(Ordering::Relaxed),
        );
        w.counter(
            "forest_bytes_written_total",
            "bytes written to client sockets",
            self.bytes_written_total.load(Ordering::Relaxed),
        );
        w.gauge(
            "forest_dispatch_queue_depth",
            "requests queued for the evented dispatch pool",
            self.dispatch_queue_depth.load(Ordering::Relaxed) as f64,
        );
        w.gauge(
            "forest_batch_queue_depth",
            "jobs queued for the dynamic batcher",
            self.batch_queue_depth.load(Ordering::Relaxed) as f64,
        );
        w.gauge(
            "forest_eval_threads",
            "configured evaluation parallelism",
            self.eval_threads.load(Ordering::Relaxed) as f64,
        );
        w.counter(
            "forest_batches_total",
            "batches dispatched",
            self.batches.load(Ordering::Relaxed),
        );
        w.counter(
            "forest_batched_items_total",
            "total rows across dispatched batches",
            self.batched_items.load(Ordering::Relaxed),
        );
        prom_histogram(
            &mut w,
            "forest_request_us",
            "end-to-end request latency in microseconds",
            &self.request_us,
        );
        prom_histogram(
            &mut w,
            "forest_batch_size",
            "rows per dispatched batch",
            &self.batch_size,
        );
        prom_histogram(
            &mut w,
            "forest_batch_eval_us",
            "per-batch evaluation time in microseconds",
            &self.batch_eval_us,
        );
        w.header(
            "forest_backend_us",
            "histogram",
            "per-backend evaluation latency in microseconds",
        );
        for kind in [
            BackendKind::Forest,
            BackendKind::Dd,
            BackendKind::Frozen,
            BackendKind::Xla,
        ] {
            let h = self.backend(kind);
            w.log2_histogram(
                "forest_backend_us",
                &[("backend", kind.name())],
                &h.bucket_counts(),
                h.count(),
                h.sum_us(),
            );
        }
        let shards = crate::obs::trace::shard_stats();
        w.header(
            "forest_eval_shard_us",
            "summary",
            "per-shard evaluation time across sharded batches, microseconds",
        );
        for s in &shards {
            let label = format!("{}", s.shard);
            w.sample(
                "forest_eval_shard_us_sum",
                &[("shard", &label)],
                s.sum_us as f64,
            );
            w.sample(
                "forest_eval_shard_us_count",
                &[("shard", &label)],
                s.count as f64,
            );
        }
        w.header(
            "forest_eval_shard_max_us",
            "gauge",
            "slowest single evaluation per shard, microseconds",
        );
        for s in &shards {
            let label = format!("{}", s.shard);
            w.sample(
                "forest_eval_shard_max_us",
                &[("shard", &label)],
                s.max_us as f64,
            );
        }
        w.finish()
    }
}

/// Header + series for one log₂ histogram family.
fn prom_histogram(w: &mut PromWriter, name: &str, help: &str, h: &Histogram) {
    w.header(name, "histogram", help);
    w.log2_histogram(name, &[], &h.bucket_counts(), h.count(), h.sum_us());
}

/// Per-shard eval timing as JSON (shard index, count, mean, max).
fn eval_shards_json() -> Json {
    Json::Arr(
        crate::obs::trace::shard_stats()
            .iter()
            .map(|s| {
                json::obj(vec![
                    ("shard", json::num(s.shard as f64)),
                    ("count", json::num(s.count as f64)),
                    ("mean_us", json::num(s.sum_us as f64 / s.count as f64)),
                    ("max_us", json::num(s.max_us as f64)),
                ])
            })
            .collect(),
    )
}

/// The event loop reports lifecycle through this trait, keeping the net
/// layer independent of the serving layer.
impl crate::net::LoopObserver for ServerMetrics {
    fn conn_opened(&self) {
        self.connection_opened();
    }
    fn conn_closed(&self) {
        self.connection_closed();
    }
    fn request_served(&self, latency: Duration) {
        self.observe_request(latency);
    }
    fn request_rejected(&self) {
        self.observe_rejected();
    }
    fn request_rejected_conn(&self) {
        self.observe_conn_rejected();
    }
    fn dispatch_enqueued(&self) {
        self.dispatch_queue_depth.fetch_add(1, Ordering::Relaxed);
    }
    fn dispatch_dequeued(&self) {
        // saturating: a miscounted dequeue must not wrap the gauge
        let _ = self.dispatch_queue_depth.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |n| n.checked_sub(1),
        );
    }
    fn bytes_read(&self, n: u64) {
        self.add_bytes_read(n);
    }
    fn bytes_written(&self, n: u64) {
        self.add_bytes_written(n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_records_and_quantiles_monotone() {
        let h = Histogram::default();
        for us in [1u64, 10, 100, 1000, 10_000] {
            h.observe(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!((h.mean_us() - 2222.2).abs() < 1.0);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
        assert!(h.quantile_us(0.99) >= 8192);
    }

    #[test]
    fn quantiles_clamp_to_the_largest_observation() {
        // regression: one 5000 µs sample used to report p50 = 8192 (the
        // raw bucket upper bound, above anything ever observed)
        let h = Histogram::default();
        h.observe(Duration::from_micros(5000));
        assert_eq!(h.quantile_us(0.5), 5000);
        assert_eq!(h.quantile_us(0.99), 5000);
        assert_eq!(h.max_us(), 5000);
        // clamping never lifts a quantile: smaller samples keep their
        // own bucket bounds
        h.observe(Duration::from_micros(3));
        assert!(h.quantile_us(0.5) <= 5000);
        assert!(h.quantile_us(0.5) >= 3);
    }

    #[test]
    fn bucket_counts_sum_to_count() {
        let h = Histogram::default();
        for us in [1u64, 2, 4, 8, 5000] {
            h.observe(Duration::from_micros(us));
        }
        let buckets = h.bucket_counts();
        assert_eq!(buckets.iter().sum::<u64>(), h.count());
        assert_eq!(h.sum_us(), 5015);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.quantile_us(0.99), 0);
    }

    #[test]
    fn metrics_snapshot_shape() {
        let m = ServerMetrics::default();
        m.observe(BackendKind::Dd, Duration::from_micros(50));
        m.observe(BackendKind::Xla, Duration::from_micros(500));
        m.observe_error();
        m.observe_batch(16);
        m.observe_batch(8);
        m.observe_batch_eval(Duration::from_micros(120));
        m.eval_threads.store(4, Ordering::Relaxed);
        let j = m.to_json();
        assert_eq!(j.get_i64("requests"), Some(3));
        assert_eq!(j.get_i64("errors"), Some(1));
        assert_eq!(
            j.get("backends").unwrap().get("dd").unwrap().get_i64("count"),
            Some(1)
        );
        assert_eq!(j.get("mean_batch_size").unwrap().as_f64(), Some(12.0));
        let sizes = j.get("batch_size").unwrap();
        assert_eq!(sizes.get_i64("count"), Some(2));
        assert_eq!(sizes.get("mean").unwrap().as_f64(), Some(12.0));
        assert!(sizes.get("mean_us").is_none(), "sizes are not latencies");
        assert_eq!(j.get("batch_eval_us").unwrap().get_i64("count"), Some(1));
        assert_eq!(j.get_i64("eval_threads"), Some(4));
        assert_eq!(j.get_str("io_mode"), Some("sync"), "sync until set");
        assert_eq!(j.get_i64("rejected_429"), Some(0));
        assert_eq!(j.get_i64("conn_rejected_429"), Some(0));
        assert_eq!(j.get("degraded").and_then(Json::as_bool), Some(false));
        let breakers = j.get("breakers").unwrap();
        assert_eq!(breakers.get_i64("open"), Some(0));
        assert_eq!(breakers.get_i64("trips"), Some(0));
        let votes = j.get("votes").unwrap();
        assert_eq!(votes.get_i64("prob_requests"), Some(0));
        assert_eq!(votes.get_i64("weighted_decisions"), Some(0));
        assert_eq!(votes.get_i64("regression_predictions"), Some(0));
        let fault = j.get("fault").unwrap();
        assert_eq!(fault.get_i64("eval_panics"), Some(0));
        assert_eq!(fault.get_i64("deadline_dropped"), Some(0));
        assert_eq!(fault.get_i64("degraded_requests"), Some(0));
        // the injected-fault counter is process-global (other tests may
        // arm the harness); only its presence is assertable here
        assert!(fault.get_i64("injected").is_some());
        assert_eq!(j.get("request_us").unwrap().get_i64("count"), Some(0));
        let conns = j.get("connections").unwrap();
        assert_eq!(conns.get_i64("open"), Some(0));
        assert_eq!(conns.get_i64("total"), Some(0));
        let bytes = j.get("bytes").unwrap();
        assert_eq!(bytes.get_i64("read"), Some(0));
        assert_eq!(bytes.get_i64("written"), Some(0));
        let depth = j.get("queue_depth").unwrap();
        assert_eq!(depth.get_i64("dispatch"), Some(0));
        assert_eq!(depth.get_i64("batch"), Some(0));
        // shard timing is process-global; only the key's presence is
        // assertable alongside concurrent pool tests
        assert!(j.get("eval_shards").unwrap().as_arr().is_some());
    }

    #[test]
    fn prometheus_exposition_renders_every_series() {
        let m = ServerMetrics::default();
        m.observe(BackendKind::Frozen, Duration::from_micros(90));
        m.observe_request(Duration::from_micros(120));
        m.add_bytes_read(10);
        m.add_bytes_written(20);
        m.observe_eval_panic();
        m.observe_deadline_dropped();
        m.observe_conn_rejected();
        m.observe_degraded();
        m.observe_prob_request();
        m.observe_weighted_decisions(3);
        m.observe_regression_predictions(2);
        m.sync_breakers(1, 2);
        let body = m.to_prometheus();
        assert!(body.contains("# TYPE forest_request_us histogram\n"));
        // 120 µs lands in bucket [64, 127]
        assert!(body.contains("forest_request_us_bucket{le=\"127\"} 1\n"));
        assert!(body.contains("forest_request_us_bucket{le=\"63\"} 0\n"));
        assert!(body.contains("forest_request_us_bucket{le=\"+Inf\"} 1\n"));
        assert!(body.contains("forest_request_us_sum 120\n"));
        assert!(body.contains("forest_request_us_count 1\n"));
        assert!(body.contains("forest_backend_us_bucket{backend=\"frozen\",le=\"127\"} 1\n"));
        assert!(body.contains("forest_backend_us_count{backend=\"frozen\"} 1\n"));
        assert!(body.contains("forest_requests_total 1\n"));
        assert!(body.contains("forest_bytes_read_total 10\n"));
        assert!(body.contains("forest_bytes_written_total 20\n"));
        assert!(body.contains("forest_dispatch_queue_depth 0\n"));
        assert!(body.contains("forest_batch_queue_depth 0\n"));
        assert!(body.contains("forest_eval_panics_total 1\n"));
        assert!(body.contains("forest_deadline_dropped_total 1\n"));
        assert!(body.contains("forest_conn_rejected_total 1\n"));
        assert!(body.contains("forest_rejected_total 1\n"));
        assert!(body.contains("forest_degraded_requests_total 1\n"));
        assert!(body.contains("forest_breakers_open 1\n"));
        assert!(body.contains("forest_breaker_trips_total 2\n"));
        assert!(body.contains("forest_prob_requests_total 1\n"));
        assert!(body.contains("forest_weighted_decisions_total 3\n"));
        assert!(body.contains("forest_regression_predictions_total 2\n"));
        assert!(body.contains("forest_faults_injected_total "));
        // shard family headers render even before any sharded batch ran
        assert!(body.contains("# TYPE forest_eval_shard_us summary\n"));
        assert!(body.contains("# TYPE forest_eval_shard_max_us gauge\n"));
    }

    #[test]
    fn queue_depth_gauges_saturate_at_zero() {
        use crate::net::LoopObserver as _;
        let m = ServerMetrics::default();
        m.dispatch_enqueued();
        m.dispatch_enqueued();
        m.dispatch_dequeued();
        assert_eq!(m.dispatch_queue_depth.load(Ordering::Relaxed), 1);
        m.dispatch_dequeued();
        m.dispatch_dequeued(); // extra dequeue saturates instead of wrapping
        assert_eq!(m.dispatch_queue_depth.load(Ordering::Relaxed), 0);
        m.batch_enqueued();
        m.batch_dequeued(5);
        assert_eq!(m.batch_queue_depth.load(Ordering::Relaxed), 0);
        m.bytes_read(7);
        m.bytes_written(9);
        assert_eq!(m.bytes_read_total.load(Ordering::Relaxed), 7);
        assert_eq!(m.bytes_written_total.load(Ordering::Relaxed), 9);
    }

    #[test]
    fn histogram_reports_p95() {
        let h = Histogram::default();
        for us in [1u64, 2, 4, 8, 5000] {
            h.observe(Duration::from_micros(us));
        }
        let j = h.to_json();
        assert!(j.get_i64("p95_us").is_some());
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.95));
        assert!(h.quantile_us(0.95) <= h.quantile_us(0.99));
        let v = h.to_json_values();
        assert!(v.get_i64("p95").is_some());
        assert!(v.get("p95_us").is_none());
    }

    #[test]
    fn simd_kernel_is_exposed_in_both_formats() {
        let m = ServerMetrics::default();
        assert_eq!(
            m.to_json().get_str("simd_kernel"),
            Some("scalar"),
            "scalar until set"
        );
        let k = crate::runtime::simd::detected();
        m.set_simd_kernel(k);
        assert_eq!(m.to_json().get_str("simd_kernel"), Some(k.name()));
        let prom = m.to_prometheus();
        assert!(prom.contains("# TYPE forest_simd_kernel gauge"), "{prom}");
        assert!(
            prom.contains(&format!("forest_simd_kernel{{kernel=\"{}\"}}", k.name())),
            "{prom}"
        );
    }

    #[test]
    fn front_end_counters_flow_through_the_observer_trait() {
        use crate::net::LoopObserver as _;
        let m = ServerMetrics::default();
        m.set_io_mode(true);
        m.conn_opened();
        m.conn_opened();
        m.conn_closed();
        m.request_served(Duration::from_micros(40));
        m.request_rejected();
        let j = m.to_json();
        assert_eq!(j.get_str("io_mode"), Some("evented"));
        let conns = j.get("connections").unwrap();
        assert_eq!(conns.get_i64("open"), Some(1));
        assert_eq!(conns.get_i64("total"), Some(2));
        assert_eq!(j.get("request_us").unwrap().get_i64("count"), Some(1));
        assert!(j.get("request_us").unwrap().get_i64("p95_us").unwrap() > 0);
        assert_eq!(j.get_i64("rejected_429"), Some(1));
        // the gauge saturates at zero instead of wrapping
        m.conn_closed();
        m.conn_closed();
        assert_eq!(m.connections_open.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn histogram_records_raw_values() {
        let h = Histogram::default();
        for n in [1u64, 8, 64, 1024] {
            h.observe_value(n);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean_us() - 274.25).abs() < 1e-9);
        assert!(h.quantile_us(0.99) >= 1024);
    }

    #[test]
    fn concurrent_observation_is_consistent() {
        let m = std::sync::Arc::new(ServerMetrics::default());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    m.observe(BackendKind::Dd, Duration::from_micros(7));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.requests.load(Ordering::Relaxed), 8000);
        assert_eq!(m.backend(BackendKind::Dd).count(), 8000);
    }
}
