//! Server metrics: lock-free counters and log₂ latency histograms.

use crate::serve::BackendKind;
use crate::util::json::{self, Json};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Number of log₂ microsecond buckets (`2^0 .. 2^N` µs, last = overflow).
const BUCKETS: usize = 24;

/// A latency histogram with power-of-two microsecond buckets.
#[derive(Debug, Default)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Histogram {
    /// Record one duration observation.
    pub fn observe(&self, d: Duration) {
        self.observe_value(d.as_micros().min(u128::from(u64::MAX)) as u64);
    }

    /// Record one raw-valued observation (same log₂ buckets; used for
    /// unit-less series like dispatched batch sizes).
    pub fn observe_value(&self, v: u64) {
        let idx = (64 - v.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(v, Ordering::Relaxed);
        self.max_us.fetch_max(v, Ordering::Relaxed);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in µs.
    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Approximate quantile from bucket boundaries (upper bound of the
    /// bucket containing the q-th observation).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * q).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_us.load(Ordering::Relaxed)
    }

    /// JSON snapshot with microsecond-suffixed keys (duration series).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("count", json::num(self.count() as f64)),
            ("mean_us", json::num(self.mean_us())),
            ("p50_us", json::num(self.quantile_us(0.5) as f64)),
            ("p95_us", json::num(self.quantile_us(0.95) as f64)),
            ("p99_us", json::num(self.quantile_us(0.99) as f64)),
            (
                "max_us",
                json::num(self.max_us.load(Ordering::Relaxed) as f64),
            ),
        ])
    }

    /// JSON snapshot with unit-neutral keys, for raw-valued series
    /// recorded via [`observe_value`](Self::observe_value) (e.g. batch
    /// sizes) — a `_us` suffix on row counts would misread as latency.
    pub fn to_json_values(&self) -> Json {
        json::obj(vec![
            ("count", json::num(self.count() as f64)),
            ("mean", json::num(self.mean_us())),
            ("p50", json::num(self.quantile_us(0.5) as f64)),
            ("p95", json::num(self.quantile_us(0.95) as f64)),
            ("p99", json::num(self.quantile_us(0.99) as f64)),
            (
                "max",
                json::num(self.max_us.load(Ordering::Relaxed) as f64),
            ),
        ])
    }
}

/// Aggregated server metrics.
#[derive(Debug)]
pub struct ServerMetrics {
    started: Instant,
    /// Total requests accepted.
    pub requests: AtomicU64,
    /// Requests that failed.
    pub errors: AtomicU64,
    /// Per-backend latency histograms (indexed by `BackendKind`).
    forest: Histogram,
    dd: Histogram,
    frozen: Histogram,
    xla: Histogram,
    /// Dynamic batcher: batches dispatched and total batched items.
    pub batches: AtomicU64,
    /// Total items across all dispatched batches.
    pub batched_items: AtomicU64,
    /// Distribution of dispatched batch sizes (rows per batch; both the
    /// dynamic batcher and the explicit batch endpoint record here).
    pub batch_size: Histogram,
    /// Per-batch evaluation time.
    pub batch_eval_us: Histogram,
    /// Configured evaluation parallelism (workers + caller; set by the
    /// server at startup from `ServeConfig::eval_threads`).
    pub eval_threads: AtomicU64,
    /// End-to-end request latency (request parsed → response flushed),
    /// across every endpoint and both front-ends.
    pub request_us: Histogram,
    /// Currently open connections (gauge).
    pub connections_open: AtomicU64,
    /// Connections accepted since startup.
    pub connections_total: AtomicU64,
    /// Requests shed with `429` by admission control (full dispatch or
    /// batcher queue).
    pub rejected: AtomicU64,
    /// Front-end marker: 1 = evented, 0 = sync (set once at startup).
    io_evented: AtomicU64,
}

impl Default for ServerMetrics {
    fn default() -> Self {
        ServerMetrics {
            started: Instant::now(),
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            forest: Histogram::default(),
            dd: Histogram::default(),
            frozen: Histogram::default(),
            xla: Histogram::default(),
            batches: AtomicU64::new(0),
            batched_items: AtomicU64::new(0),
            batch_size: Histogram::default(),
            batch_eval_us: Histogram::default(),
            eval_threads: AtomicU64::new(0),
            request_us: Histogram::default(),
            connections_open: AtomicU64::new(0),
            connections_total: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            io_evented: AtomicU64::new(0),
        }
    }
}

impl ServerMetrics {
    /// The histogram for a backend.
    pub fn backend(&self, kind: BackendKind) -> &Histogram {
        match kind {
            BackendKind::Forest => &self.forest,
            BackendKind::Dd => &self.dd,
            BackendKind::Frozen => &self.frozen,
            BackendKind::Xla => &self.xla,
        }
    }

    /// Record a served request.
    pub fn observe(&self, kind: BackendKind, latency: Duration) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.backend(kind).observe(latency);
    }

    /// Record a failed request.
    pub fn observe_error(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a dispatched batch of `n` items.
    pub fn observe_batch(&self, n: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_items.fetch_add(n as u64, Ordering::Relaxed);
        self.batch_size.observe_value(n as u64);
    }

    /// Record the evaluation time of one dispatched batch.
    pub fn observe_batch_eval(&self, d: Duration) {
        self.batch_eval_us.observe(d);
    }

    /// Record the end-to-end latency of one served request.
    pub fn observe_request(&self, latency: Duration) {
        self.request_us.observe(latency);
    }

    /// Record a request shed with `429`.
    pub fn observe_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// A connection was accepted (front-end connection gauges).
    pub fn connection_opened(&self) {
        self.connections_open.fetch_add(1, Ordering::Relaxed);
        self.connections_total.fetch_add(1, Ordering::Relaxed);
    }

    /// A connection was closed.
    pub fn connection_closed(&self) {
        // saturating: a miscounted close must not wrap the gauge
        let _ = self.connections_open.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |n| n.checked_sub(1),
        );
    }

    /// Record which front-end serves this process (shown in `/metrics`).
    pub fn set_io_mode(&self, evented: bool) {
        self.io_evented.store(u64::from(evented), Ordering::Relaxed);
    }

    /// Mean items per dispatched batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_items.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Full JSON snapshot (the `/metrics` endpoint body).
    pub fn to_json(&self) -> Json {
        let uptime = self.started.elapsed().as_secs_f64();
        let requests = self.requests.load(Ordering::Relaxed);
        json::obj(vec![
            ("uptime_s", json::num(uptime)),
            (
                "io_mode",
                json::s(if self.io_evented.load(Ordering::Relaxed) == 1 {
                    "evented"
                } else {
                    "sync"
                }),
            ),
            ("requests", json::num(requests as f64)),
            (
                "errors",
                json::num(self.errors.load(Ordering::Relaxed) as f64),
            ),
            (
                "rejected_429",
                json::num(self.rejected.load(Ordering::Relaxed) as f64),
            ),
            ("request_us", self.request_us.to_json()),
            (
                "connections",
                json::obj(vec![
                    (
                        "open",
                        json::num(self.connections_open.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "total",
                        json::num(self.connections_total.load(Ordering::Relaxed) as f64),
                    ),
                ]),
            ),
            (
                "throughput_rps",
                json::num(if uptime > 0.0 {
                    requests as f64 / uptime
                } else {
                    0.0
                }),
            ),
            ("mean_batch_size", json::num(self.mean_batch_size())),
            ("batch_size", self.batch_size.to_json_values()),
            ("batch_eval_us", self.batch_eval_us.to_json()),
            (
                "eval_threads",
                json::num(self.eval_threads.load(Ordering::Relaxed) as f64),
            ),
            (
                "backends",
                json::obj(vec![
                    ("forest", self.forest.to_json()),
                    ("dd", self.dd.to_json()),
                    ("frozen", self.frozen.to_json()),
                    ("xla", self.xla.to_json()),
                ]),
            ),
        ])
    }
}

/// The event loop reports lifecycle through this trait, keeping the net
/// layer independent of the serving layer.
impl crate::net::LoopObserver for ServerMetrics {
    fn conn_opened(&self) {
        self.connection_opened();
    }
    fn conn_closed(&self) {
        self.connection_closed();
    }
    fn request_served(&self, latency: Duration) {
        self.observe_request(latency);
    }
    fn request_rejected(&self) {
        self.observe_rejected();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_records_and_quantiles_monotone() {
        let h = Histogram::default();
        for us in [1u64, 10, 100, 1000, 10_000] {
            h.observe(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!((h.mean_us() - 2222.2).abs() < 1.0);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
        assert!(h.quantile_us(0.99) >= 8192);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.quantile_us(0.99), 0);
    }

    #[test]
    fn metrics_snapshot_shape() {
        let m = ServerMetrics::default();
        m.observe(BackendKind::Dd, Duration::from_micros(50));
        m.observe(BackendKind::Xla, Duration::from_micros(500));
        m.observe_error();
        m.observe_batch(16);
        m.observe_batch(8);
        m.observe_batch_eval(Duration::from_micros(120));
        m.eval_threads.store(4, Ordering::Relaxed);
        let j = m.to_json();
        assert_eq!(j.get_i64("requests"), Some(3));
        assert_eq!(j.get_i64("errors"), Some(1));
        assert_eq!(
            j.get("backends").unwrap().get("dd").unwrap().get_i64("count"),
            Some(1)
        );
        assert_eq!(j.get("mean_batch_size").unwrap().as_f64(), Some(12.0));
        let sizes = j.get("batch_size").unwrap();
        assert_eq!(sizes.get_i64("count"), Some(2));
        assert_eq!(sizes.get("mean").unwrap().as_f64(), Some(12.0));
        assert!(sizes.get("mean_us").is_none(), "sizes are not latencies");
        assert_eq!(j.get("batch_eval_us").unwrap().get_i64("count"), Some(1));
        assert_eq!(j.get_i64("eval_threads"), Some(4));
        assert_eq!(j.get_str("io_mode"), Some("sync"), "sync until set");
        assert_eq!(j.get_i64("rejected_429"), Some(0));
        assert_eq!(j.get("request_us").unwrap().get_i64("count"), Some(0));
        let conns = j.get("connections").unwrap();
        assert_eq!(conns.get_i64("open"), Some(0));
        assert_eq!(conns.get_i64("total"), Some(0));
    }

    #[test]
    fn histogram_reports_p95() {
        let h = Histogram::default();
        for us in [1u64, 2, 4, 8, 5000] {
            h.observe(Duration::from_micros(us));
        }
        let j = h.to_json();
        assert!(j.get_i64("p95_us").is_some());
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.95));
        assert!(h.quantile_us(0.95) <= h.quantile_us(0.99));
        let v = h.to_json_values();
        assert!(v.get_i64("p95").is_some());
        assert!(v.get("p95_us").is_none());
    }

    #[test]
    fn front_end_counters_flow_through_the_observer_trait() {
        use crate::net::LoopObserver as _;
        let m = ServerMetrics::default();
        m.set_io_mode(true);
        m.conn_opened();
        m.conn_opened();
        m.conn_closed();
        m.request_served(Duration::from_micros(40));
        m.request_rejected();
        let j = m.to_json();
        assert_eq!(j.get_str("io_mode"), Some("evented"));
        let conns = j.get("connections").unwrap();
        assert_eq!(conns.get_i64("open"), Some(1));
        assert_eq!(conns.get_i64("total"), Some(2));
        assert_eq!(j.get("request_us").unwrap().get_i64("count"), Some(1));
        assert!(j.get("request_us").unwrap().get_i64("p95_us").unwrap() > 0);
        assert_eq!(j.get_i64("rejected_429"), Some(1));
        // the gauge saturates at zero instead of wrapping
        m.conn_closed();
        m.conn_closed();
        assert_eq!(m.connections_open.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn histogram_records_raw_values() {
        let h = Histogram::default();
        for n in [1u64, 8, 64, 1024] {
            h.observe_value(n);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean_us() - 274.25).abs() < 1e-9);
        assert!(h.quantile_us(0.99) >= 1024);
    }

    #[test]
    fn concurrent_observation_is_consistent() {
        let m = std::sync::Arc::new(ServerMetrics::default());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    m.observe(BackendKind::Dd, Duration::from_micros(7));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.requests.load(Ordering::Relaxed), 8000);
        assert_eq!(m.backend(BackendKind::Dd).count(), 8000);
    }
}
