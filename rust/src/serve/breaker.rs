//! Per-`(model, backend)` circuit breakers for the serving router.
//!
//! Every eval outcome feeds a small state machine keyed by the exact
//! model version and backend that produced it:
//!
//! - **Closed** — traffic flows; failures are remembered in a sliding
//!   window ([`FAILURE_WINDOW`]). Reaching the configured threshold
//!   inside the window trips the breaker.
//! - **Open** — the router routes around this backend (the degradation
//!   chain `frozen → dd → forest` is bit-identical, so rerouting is
//!   correctness-preserving). After the cooldown the next [`allow`]
//!   call admits exactly one probe request.
//! - **Half-open** — one probe is in flight; its success closes the
//!   breaker, its failure re-opens it for another cooldown.
//!
//! The warm path is cheap by construction: [`allow`](BreakerBoard::allow)
//! and [`record_success`](BreakerBoard::record_success) first check one
//! relaxed atomic (`tracked`) and return immediately while no breaker
//! has ever recorded a failure — a healthy server never takes the lock.
//! Keys use the full version id (`name@vN`), so a hot-swap naturally
//! starts the new version with fresh breakers.

use crate::serve::BackendKind;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Sliding window over which failures are counted towards the trip
/// threshold. Older failures age out and no longer count.
pub const FAILURE_WINDOW: Duration = Duration::from_secs(10);

/// One backend slot's breaker state.
#[derive(Debug)]
enum State {
    /// Serving normally; recent failure instants ride along.
    Closed { failures: Vec<Instant> },
    /// Tripped at `since`; routed around until the cooldown elapses.
    Open { since: Instant },
    /// One probe in flight; everyone else is still routed around.
    HalfOpen,
}

/// Breaker board shared by all router paths (single and batch).
#[derive(Debug)]
pub struct BreakerBoard {
    /// Failures within [`FAILURE_WINDOW`] that trip a breaker
    /// (`0` disables the board entirely: never trip, always allow).
    threshold: usize,
    /// How long an open breaker waits before admitting a probe.
    cooldown: Duration,
    slots: Mutex<HashMap<String, [Option<State>; 4]>>,
    /// Entries holding any state at all — the warm-path gate: while
    /// zero, `allow`/`record_success` return without locking.
    tracked: AtomicU64,
    /// Breakers currently open or half-open (gauge).
    open: AtomicU64,
    /// Times any breaker transitioned closed → open (counter).
    trips: AtomicU64,
}

fn idx(kind: BackendKind) -> usize {
    match kind {
        BackendKind::Forest => 0,
        BackendKind::Dd => 1,
        BackendKind::Frozen => 2,
        BackendKind::Xla => 3,
    }
}

const KINDS: [BackendKind; 4] = [
    BackendKind::Forest,
    BackendKind::Dd,
    BackendKind::Frozen,
    BackendKind::Xla,
];

impl BreakerBoard {
    /// A board that trips after `threshold` failures inside
    /// [`FAILURE_WINDOW`] and probes after `cooldown`.
    pub fn new(threshold: usize, cooldown: Duration) -> BreakerBoard {
        BreakerBoard {
            threshold,
            cooldown,
            slots: Mutex::new(HashMap::new()),
            tracked: AtomicU64::new(0),
            open: AtomicU64::new(0),
            trips: AtomicU64::new(0),
        }
    }

    /// May a request be routed to `(model, kind)` right now? An open
    /// breaker past its cooldown flips to half-open here and admits the
    /// calling request as its probe.
    pub fn allow(&self, model: &str, kind: BackendKind) -> bool {
        if self.threshold == 0 || self.tracked.load(Ordering::Relaxed) == 0 {
            return true;
        }
        let mut slots = self.slots.lock().unwrap();
        let Some(entry) = slots.get_mut(model) else {
            return true;
        };
        match &entry[idx(kind)] {
            None | Some(State::Closed { .. }) => true,
            Some(State::Open { since }) => {
                if since.elapsed() >= self.cooldown {
                    entry[idx(kind)] = Some(State::HalfOpen);
                    true // this caller is the probe
                } else {
                    false
                }
            }
            Some(State::HalfOpen) => false, // a probe is already in flight
        }
    }

    /// Record a successful eval: closes a half-open breaker, clears any
    /// remembered failures.
    pub fn record_success(&self, model: &str, kind: BackendKind) {
        if self.threshold == 0 || self.tracked.load(Ordering::Relaxed) == 0 {
            return;
        }
        let mut slots = self.slots.lock().unwrap();
        let Some(entry) = slots.get_mut(model) else {
            return;
        };
        let slot = &mut entry[idx(kind)];
        match slot {
            None => {}
            Some(State::Closed { failures }) if failures.is_empty() => {}
            Some(State::Closed { .. }) => {
                *slot = Some(State::Closed { failures: Vec::new() });
            }
            Some(State::Open { .. }) | Some(State::HalfOpen) => {
                *slot = Some(State::Closed { failures: Vec::new() });
                // saturating: a spurious success must not wrap the gauge
                let _ = self.open.fetch_update(
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                    |n| n.checked_sub(1),
                );
            }
        }
    }

    /// Record a failed eval (error or quarantined panic). Enough of
    /// these inside [`FAILURE_WINDOW`] trip the breaker; a failure while
    /// half-open re-opens it immediately.
    pub fn record_failure(&self, model: &str, kind: BackendKind) {
        if self.threshold == 0 {
            return;
        }
        let now = Instant::now();
        let mut slots = self.slots.lock().unwrap();
        let entry = slots.entry(model.to_string()).or_insert_with(|| {
            self.tracked.fetch_add(1, Ordering::Relaxed);
            [None, None, None, None]
        });
        let slot = &mut entry[idx(kind)];
        match slot {
            Some(State::Open { .. }) => {} // already routed around
            Some(State::HalfOpen) => {
                // the probe failed: straight back to open
                *slot = Some(State::Open { since: now });
            }
            None | Some(State::Closed { .. }) => {
                let mut failures = match slot.take() {
                    Some(State::Closed { failures }) => failures,
                    _ => Vec::new(),
                };
                failures.retain(|t| now.duration_since(*t) < FAILURE_WINDOW);
                failures.push(now);
                if failures.len() >= self.threshold {
                    *slot = Some(State::Open { since: now });
                    self.open.fetch_add(1, Ordering::Relaxed);
                    self.trips.fetch_add(1, Ordering::Relaxed);
                } else {
                    *slot = Some(State::Closed { failures });
                }
            }
        }
    }

    /// Breakers currently open or half-open (the `/metrics` `degraded`
    /// flag is `open_count() > 0`).
    pub fn open_count(&self) -> u64 {
        self.open.load(Ordering::Relaxed)
    }

    /// Total closed → open transitions since startup.
    pub fn trips_total(&self) -> u64 {
        self.trips.load(Ordering::Relaxed)
    }

    /// Every `(model, backend)` pair whose breaker is open or half-open,
    /// for `/readyz` and diagnostics. Sorted for stable output.
    pub fn open_breakers(&self) -> Vec<(String, BackendKind)> {
        let slots = self.slots.lock().unwrap();
        let mut out = Vec::new();
        for (model, entry) in slots.iter() {
            for kind in KINDS {
                if matches!(
                    entry[idx(kind)],
                    Some(State::Open { .. }) | Some(State::HalfOpen)
                ) {
                    out.push((model.clone(), kind));
                }
            }
        }
        out.sort_by(|a, b| (&a.0, idx(a.1)).cmp(&(&b.0, idx(b.1))));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn board() -> BreakerBoard {
        BreakerBoard::new(3, Duration::from_millis(40))
    }

    #[test]
    fn trips_after_threshold_failures_and_reroutes() {
        let b = board();
        assert!(b.allow("m@v1", BackendKind::Frozen));
        b.record_failure("m@v1", BackendKind::Frozen);
        b.record_failure("m@v1", BackendKind::Frozen);
        assert!(b.allow("m@v1", BackendKind::Frozen), "below threshold");
        assert_eq!(b.open_count(), 0);
        b.record_failure("m@v1", BackendKind::Frozen);
        assert!(!b.allow("m@v1", BackendKind::Frozen), "tripped");
        assert_eq!(b.open_count(), 1);
        assert_eq!(b.trips_total(), 1);
        // the sibling backend and other models are untouched
        assert!(b.allow("m@v1", BackendKind::Dd));
        assert!(b.allow("other@v1", BackendKind::Frozen));
        assert_eq!(
            b.open_breakers(),
            vec![("m@v1".to_string(), BackendKind::Frozen)]
        );
    }

    #[test]
    fn half_open_probe_closes_on_success_and_reopens_on_failure() {
        let b = board();
        for _ in 0..3 {
            b.record_failure("m@v1", BackendKind::Dd);
        }
        assert!(!b.allow("m@v1", BackendKind::Dd));
        std::thread::sleep(Duration::from_millis(60));
        // past cooldown: exactly one probe gets through
        assert!(b.allow("m@v1", BackendKind::Dd), "probe admitted");
        assert!(!b.allow("m@v1", BackendKind::Dd), "second probe held back");
        assert_eq!(b.open_count(), 1, "half-open still counts as degraded");
        // probe failure re-opens for another full cooldown
        b.record_failure("m@v1", BackendKind::Dd);
        assert!(!b.allow("m@v1", BackendKind::Dd));
        std::thread::sleep(Duration::from_millis(60));
        assert!(b.allow("m@v1", BackendKind::Dd));
        // probe success closes and clears history: three fresh failures
        // are needed to trip again
        b.record_success("m@v1", BackendKind::Dd);
        assert_eq!(b.open_count(), 0);
        assert!(b.open_breakers().is_empty());
        b.record_failure("m@v1", BackendKind::Dd);
        b.record_failure("m@v1", BackendKind::Dd);
        assert!(b.allow("m@v1", BackendKind::Dd));
        assert_eq!(b.trips_total(), 1, "trips count only closed → open");
    }

    #[test]
    fn success_clears_the_failure_window() {
        let b = board();
        b.record_failure("m@v1", BackendKind::Forest);
        b.record_failure("m@v1", BackendKind::Forest);
        b.record_success("m@v1", BackendKind::Forest);
        b.record_failure("m@v1", BackendKind::Forest);
        b.record_failure("m@v1", BackendKind::Forest);
        assert!(b.allow("m@v1", BackendKind::Forest), "window was cleared");
        assert_eq!(b.open_count(), 0);
    }

    #[test]
    fn zero_threshold_disables_the_board() {
        let b = BreakerBoard::new(0, Duration::from_millis(1));
        for _ in 0..100 {
            b.record_failure("m@v1", BackendKind::Frozen);
        }
        assert!(b.allow("m@v1", BackendKind::Frozen));
        assert_eq!(b.open_count(), 0);
        assert_eq!(b.trips_total(), 0);
        assert!(b.open_breakers().is_empty());
    }
}
