//! XLA backend: a dedicated engine thread owning the PJRT executable.
//!
//! PJRT client handles are not `Send`, so the engine lives on one thread;
//! batches arrive over a channel and replies return through per-batch
//! channels. One engine per artifact variant (`one compiled executable per
//! model variant`, DESIGN.md §2).
//!
//! The backend speaks [`Classifier`] like every other evaluator; its
//! [`CostModel::preferred_batch`] advertises the artifact batch size, so
//! the router's dynamic batcher coalesces single-request traffic into
//! full executions.

use crate::batch::{RowMatrix, RowMatrixBuf};
use crate::classifier::{BackendKind, Classifier, ClassifierInfo, CostModel};
use crate::error::{Error, Result};
use crate::forest::RandomForest;
use crate::runtime::{PackedForest, VariantMeta, XlaEngine};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender};
use std::thread::JoinHandle;

type BatchReply = Result<Vec<u32>>;

enum Msg {
    /// One artifact-sized chunk, shipped to the engine thread as an owned
    /// flat matrix (a single buffer copy, never per-row `Vec`s).
    Batch(RowMatrixBuf, Sender<BatchReply>),
    Shutdown,
}

/// Handle to the engine thread.
pub struct XlaBackend {
    tx: SyncSender<Msg>,
    handle: Option<JoinHandle<()>>,
    /// Shape contract of the loaded artifact.
    pub meta: VariantMeta,
    /// Feature arity of the packed forest (≤ the artifact's padded width).
    n_features: usize,
    /// Class count of the packed forest (≤ the artifact's padded count).
    n_classes: usize,
    /// Node count of the source forest (the Fig. 7 size measure — not
    /// the artifact's padded capacity).
    forest_nodes: usize,
}

impl XlaBackend {
    /// Pack `forest` and start the engine thread for `variant`.
    ///
    /// Loading errors (missing artifacts, incompatible forest) surface
    /// immediately — the thread reports its startup result before this
    /// constructor returns.
    pub fn start(artifacts_dir: &str, variant: &str, forest: &RandomForest) -> Result<XlaBackend> {
        let meta = VariantMeta::load(artifacts_dir, variant)?;
        let packed = PackedForest::pack(forest, &meta)?;
        let n_features = forest.schema.n_features();
        let n_classes = forest.n_classes();
        let forest_nodes = forest.n_nodes();
        let (tx, rx): (SyncSender<Msg>, Receiver<Msg>) = mpsc::sync_channel(64);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let dir = artifacts_dir.to_string();
        let var = variant.to_string();
        let handle = std::thread::Builder::new()
            .name(format!("xla-engine-{variant}"))
            .spawn(move || {
                let engine = match XlaEngine::load(&dir, &var) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Shutdown => return,
                        Msg::Batch(rows, reply) => {
                            let out = run_batch(&engine, &packed, n_features, &rows);
                            let _ = reply.send(out);
                        }
                    }
                }
            })
            .expect("failed to spawn xla engine thread");
        ready_rx
            .recv()
            .map_err(|_| Error::Runtime("xla engine thread died during startup".into()))??;
        Ok(XlaBackend {
            tx,
            handle: Some(handle),
            meta,
            n_features,
            n_classes,
            forest_nodes,
        })
    }

    /// Blocking RPC of one artifact-sized chunk to the engine thread.
    fn submit_chunk(&self, rows: RowMatrixBuf) -> Result<Vec<u32>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Msg::Batch(rows, reply_tx))
            .map_err(|_| Error::Serve("xla engine has shut down".into()))?;
        reply_rx
            .recv()
            .map_err(|_| Error::Serve("xla engine dropped a batch".into()))?
    }

    /// Stop the engine thread.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// The tensorised backend: batch-native, step counts unavailable.
impl Classifier for XlaBackend {
    fn info(&self) -> ClassifierInfo {
        ClassifierInfo {
            backend: BackendKind::Xla,
            label: format!(
                "XLA/PJRT tensorised forest ('{}' artifact, batch {})",
                self.meta.name, self.meta.batch
            ),
            n_features: self.n_features,
            n_classes: self.n_classes,
            size_nodes: self.forest_nodes,
            cost: CostModel {
                max_steps: None,
                aggregation_reads: 0,
                preferred_batch: self.meta.batch,
            },
        }
    }

    fn classify_with_steps(&self, x: &[f32]) -> Result<(u32, Option<usize>)> {
        let mut one = RowMatrixBuf::with_capacity(x.len(), 1);
        one.push_row(x)?;
        let out = self.submit_chunk(one)?;
        out.first()
            .map(|&c| (c, None))
            .ok_or_else(|| Error::Serve("xla engine returned an empty batch".into()))
    }

    /// Native batch path: oversized batches are split into artifact-sized
    /// chunks, each one PJRT execution (the chunk copy is one contiguous
    /// `memcpy` into the owned buffer that crosses the engine thread).
    fn classify_batch(&self, rows: RowMatrix<'_>) -> Result<Vec<u32>> {
        if rows.is_empty() {
            return Ok(Vec::new());
        }
        let mut out = Vec::with_capacity(rows.n_rows());
        let mut start = 0usize;
        while start < rows.n_rows() {
            let len = (rows.n_rows() - start).min(self.meta.batch);
            let chunk = RowMatrixBuf::from_matrix(rows.slice(start, len));
            out.extend(self.submit_chunk(chunk)?);
            start += len;
        }
        Ok(out)
    }
}

impl Drop for XlaBackend {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn run_batch(
    engine: &XlaEngine,
    packed: &PackedForest,
    n_features: usize,
    rows: &RowMatrixBuf,
) -> Result<Vec<u32>> {
    let m = rows.as_matrix();
    if m.n_features() != n_features {
        return Err(Error::SchemaMismatch(format!(
            "rows have {} features, model expects {n_features}",
            m.n_features()
        )));
    }
    engine.classify_rows(m, packed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;
    use crate::forest::ForestLearner;

    /// These tests need `make artifacts` to have run; they are exercised
    /// again end-to-end in `rust/tests/integration_runtime.rs`.
    fn artifacts_dir() -> Option<String> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if std::path::Path::new(dir).join("forest_small.meta.json").exists() {
            Some(dir.to_string())
        } else {
            None
        }
    }

    #[test]
    fn startup_error_is_immediate() {
        let ds = datasets::iris();
        let forest = ForestLearner::default().trees(8).max_depth(4).seed(0).fit(&ds);
        assert!(XlaBackend::start("/no/such/dir", "small", &forest).is_err());
    }

    #[test]
    fn batch_classification_matches_forest() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let ds = datasets::iris();
        // small variant: T=32, D=6, F=8, C=4 — train a compatible forest
        let forest = ForestLearner::default()
            .trees(32)
            .max_depth(6)
            .seed(11)
            .fit(&ds);
        let backend = XlaBackend::start(&dir, "small", &forest).unwrap();
        let info = backend.info();
        assert_eq!(info.backend, BackendKind::Xla);
        assert_eq!(info.n_features, 4);
        assert_eq!(info.n_classes, 3);
        assert!(info.cost.preferred_batch > 1);
        let mut buf = crate::batch::RowMatrixBuf::with_capacity(ds.n_features(), 40);
        for i in 0..40 {
            buf.push_row(ds.row(i * 3)).unwrap();
        }
        let rows = buf.as_matrix();
        let got = backend.classify_batch(rows).unwrap();
        for (row, cls) in rows.iter().zip(&got) {
            assert_eq!(*cls, forest.predict(row));
        }
        // single-row path goes through a batch of one
        assert_eq!(
            backend.classify(ds.row(5)).unwrap(),
            forest.predict(ds.row(5))
        );
        backend.shutdown();
    }
}
