//! Serving coordinator: the production layer that makes the paper's
//! compile-time optimisation deployable.
//!
//! Requests flow: HTTP front-end ([`http`]) → [`router::Router`] →
//! backend. Three backends expose the same classification semantics at
//! different cost profiles:
//!
//! - **forest** — the baseline: walk all `n` trees (linear in forest size);
//! - **dd** — the paper's contribution: one root-to-terminal walk through
//!   the compiled ADD (`Most frequent class DD*`);
//! - **xla** — the L2/L1 tensorised evaluator via PJRT, fed by the dynamic
//!   batcher ([`batcher`]) for throughput-oriented batched traffic.
//!
//! All state is owned by Rust; Python exists only in the artifact build
//! path. Metrics ([`metrics`]) track per-backend latency histograms.

pub mod batcher;
pub mod config;
pub mod http;
pub mod metrics;
pub mod router;
pub mod server;
pub mod xla_backend;

use crate::compile::{CompileOptions, CompiledDD, ForestCompiler};
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::forest::{ForestLearner, RandomForest};

/// Which execution backend serves a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// Naive forest walk (baseline).
    Forest,
    /// Compiled decision diagram (the paper's system).
    Dd,
    /// Batched XLA/PJRT tensorised evaluator.
    Xla,
}

impl BackendKind {
    /// Parse from a request/config string.
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s.to_ascii_lowercase().as_str() {
            "forest" | "rf" => Ok(BackendKind::Forest),
            "dd" | "add" | "diagram" => Ok(BackendKind::Dd),
            "xla" | "pjrt" => Ok(BackendKind::Xla),
            other => Err(Error::invalid(format!(
                "unknown backend '{other}' (forest|dd|xla)"
            ))),
        }
    }

    /// Stable name for metrics/JSON.
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Forest => "forest",
            BackendKind::Dd => "dd",
            BackendKind::Xla => "xla",
        }
    }
}

/// One classification request.
#[derive(Debug, Clone)]
pub struct ClassifyRequest {
    /// Feature row (must match the model schema arity).
    pub features: Vec<f32>,
    /// Backend override (router default otherwise).
    pub backend: Option<BackendKind>,
}

/// One classification response.
#[derive(Debug, Clone)]
pub struct ClassifyResponse {
    /// Predicted class index.
    pub class: u32,
    /// Human-readable class label.
    pub label: String,
    /// Backend that served the request.
    pub backend: BackendKind,
    /// §6 step count (native backends; `None` for XLA).
    pub steps: Option<usize>,
    /// Service latency in microseconds.
    pub latency_us: u64,
}

/// A trained model pair: the baseline forest and its compiled diagram.
#[derive(Debug)]
pub struct ModelBundle {
    /// Baseline Random Forest.
    pub forest: RandomForest,
    /// Compiled `DD*` for the same forest.
    pub dd: CompiledDD,
}

impl ModelBundle {
    /// Train a forest on `data` and compile it.
    pub fn train(
        data: &Dataset,
        trees: usize,
        max_depth: usize,
        seed: u64,
        compile_opts: CompileOptions,
    ) -> Result<ModelBundle> {
        let forest = ForestLearner::default()
            .trees(trees)
            .max_depth(max_depth)
            .seed(seed)
            .fit(data);
        let dd = ForestCompiler::new(compile_opts).compile(&forest)?;
        Ok(ModelBundle { forest, dd })
    }

    /// Validate a request row against the model schema.
    pub fn check_row(&self, features: &[f32]) -> Result<()> {
        let want = self.forest.schema.n_features();
        if features.len() != want {
            return Err(Error::Serve(format!(
                "request has {} features, model expects {want}",
                features.len()
            )));
        }
        if features.iter().any(|v| !v.is_finite()) {
            return Err(Error::Serve("request contains non-finite features".into()));
        }
        Ok(())
    }

    /// Class label for an index.
    pub fn label(&self, class: u32) -> String {
        self.forest
            .schema
            .classes
            .get(class as usize)
            .cloned()
            .unwrap_or_else(|| format!("class-{class}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;

    #[test]
    fn backend_parse_and_names() {
        assert_eq!(BackendKind::parse("dd").unwrap(), BackendKind::Dd);
        assert_eq!(BackendKind::parse("RF").unwrap(), BackendKind::Forest);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Xla);
        assert!(BackendKind::parse("gpu").is_err());
        assert_eq!(BackendKind::Xla.name(), "xla");
    }

    #[test]
    fn bundle_trains_and_validates_rows() {
        let ds = datasets::iris();
        let b = ModelBundle::train(&ds, 10, 0, 1, CompileOptions::default()).unwrap();
        assert!(b.check_row(ds.row(0)).is_ok());
        assert!(b.check_row(&[1.0, 2.0]).is_err());
        assert!(b.check_row(&[f32::NAN, 0.0, 0.0, 0.0]).is_err());
        assert_eq!(b.label(0), "setosa");
        assert_eq!(b.label(99), "class-99");
        // dd and forest agree everywhere
        assert_eq!(b.dd.agreement(&b.forest, &ds), 1.0);
    }
}
