//! Serving coordinator: the production layer that makes the paper's
//! compile-time optimisation deployable.
//!
//! Requests flow: HTTP front-end ([`http`]) → [`router::Router`] →
//! [`Classifier`](crate::classifier::Classifier) trait object resolved
//! from the shared [`ModelRegistry`](crate::engine::ModelRegistry).
//! Four backends expose the same classification semantics at different
//! cost profiles:
//!
//! - **forest** — the baseline: walk all `n` trees (linear in forest size);
//! - **dd** — the paper's contribution: one root-to-terminal walk through
//!   the compiled ADD (`Most frequent class DD*`);
//! - **frozen** — the same diagram in its flat, snapshot-loadable serving
//!   form ([`crate::frozen::FrozenDD`]): identical predictions,
//!   cache-friendly arrays, millisecond replica startup via
//!   `serve --snapshot`;
//! - **xla** — the L2/L1 tensorised evaluator via PJRT, fed by the dynamic
//!   batcher ([`batcher`]) for throughput-oriented batched traffic.
//!
//! The router never names a concrete evaluator type: backends whose
//! [`CostModel`](crate::classifier::CostModel) prefers batching are
//! coalesced through the batcher, everything else is served inline.
//! Batches travel as one borrowed flat
//! [`RowMatrix`](crate::batch::RowMatrix) end to end — the HTTP layer
//! parses request rows straight into a
//! [`RowMatrixBuf`](crate::batch::RowMatrixBuf), and the forest/frozen
//! backends shard large batches across the process-wide evaluation pool
//! (`ServeConfig::eval_threads`, surfaced in `/metrics`).
//! Models are named and versioned; registering under an existing name
//! hot-swaps atomically, and requests may select `model` and `backend`
//! per call. All state is owned by Rust; Python exists only in the
//! artifact build path.
//!
//! Two socket front-ends drive the same endpoint layer
//! (`ServeConfig::io_mode` / `serve --io`): the evented loop
//! ([`crate::net::event_loop`] — epoll/kqueue readiness, keep-alive,
//! pipelining, bounded dispatch) where a poller exists, and the sync
//! thread-per-connection pool (keep-alive with per-connection read
//! timeouts) everywhere. Both parse with [`crate::net::proto`] and reply
//! through [`http::respond`], so responses are bit-identical across
//! modes. Overload is shed, never queued unboundedly: a full batcher or
//! dispatch queue yields `429` + `Retry-After`. Metrics ([`metrics`])
//! track per-backend and end-to-end latency histograms (p50/p95/p99),
//! connection gauges, and the `429` shed count.
//!
//! Faults are contained, not fatal: a panicking eval shard is
//! quarantined by the pool and surfaced as a per-request error, circuit
//! breakers ([`breaker`]) route repeated failures around a sick backend
//! along the bit-identical chain `frozen → dd → forest` (the reroute is
//! announced via `X-Served-By`), and every request carries a deadline
//! (`reply_timeout_ms`, capped lower by a client `X-Deadline-Ms`
//! header) that is enforced from admission through the batcher into the
//! tiled frozen sweep (`504` on expiry). `GET /readyz` reports `503`
//! while any breaker is open.

pub mod batcher;
pub mod breaker;
pub mod config;
pub mod http;
pub mod metrics;
pub mod router;
pub mod server;
pub mod xla_backend;

pub use crate::classifier::BackendKind;

/// One classification request.
#[derive(Debug, Clone, Default)]
pub struct ClassifyRequest {
    /// Feature row (must match the model schema arity).
    pub features: Vec<f32>,
    /// Backend override (router default otherwise).
    pub backend: Option<BackendKind>,
    /// Model-name override (the registry's default model otherwise).
    pub model: Option<String>,
    /// Request the per-class vote distribution alongside the decision
    /// (`"probs": true` over HTTP). Requires a vote-preserving backend.
    pub probs: bool,
}

impl ClassifyRequest {
    /// A request for the default model/backend.
    pub fn new(features: Vec<f32>) -> ClassifyRequest {
        ClassifyRequest {
            features,
            backend: None,
            model: None,
            probs: false,
        }
    }

    /// Select a backend.
    pub fn on_backend(mut self, backend: BackendKind) -> ClassifyRequest {
        self.backend = Some(backend);
        self
    }

    /// Select a named model.
    pub fn on_model(mut self, model: impl Into<String>) -> ClassifyRequest {
        self.model = Some(model.into());
        self
    }

    /// Ask for the vote distribution (`votes` + `probs` in the response).
    pub fn with_probs(mut self) -> ClassifyRequest {
        self.probs = true;
        self
    }
}

/// One classification response.
#[derive(Debug, Clone)]
pub struct ClassifyResponse {
    /// Predicted class index.
    pub class: u32,
    /// Human-readable class label.
    pub label: String,
    /// Backend that served the request.
    pub backend: BackendKind,
    /// Model version that served the request (`name@vN`).
    pub model: String,
    /// §6 step count (native backends; `None` for XLA).
    pub steps: Option<usize>,
    /// Service latency in microseconds.
    pub latency_us: u64,
    /// Set when a circuit breaker rerouted the request around its picked
    /// backend: the backend that actually served it (same value as
    /// `backend`, kept separate so transports can emit `X-Served-By`
    /// only on degraded responses). `None` on the normal path.
    pub served_by: Option<BackendKind>,
    /// Per-class vote counts (only when the request asked for `probs`).
    pub votes: Option<Vec<u32>>,
    /// Per-class vote fractions derived from `votes` (same gating). When
    /// `class_weights` are configured these stay the *raw* fractions —
    /// weights re-rank the decision, not the reported distribution.
    pub probs: Option<Vec<f64>>,
    /// Regression prediction (vote-weighted mean of the model's bin value
    /// table). Always present for regression models, `None` otherwise.
    pub value: Option<f64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_builders_compose() {
        let req = ClassifyRequest::new(vec![1.0, 2.0])
            .on_backend(BackendKind::Forest)
            .on_model("canary")
            .with_probs();
        assert_eq!(req.features, vec![1.0, 2.0]);
        assert_eq!(req.backend, Some(BackendKind::Forest));
        assert_eq!(req.model.as_deref(), Some("canary"));
        assert!(req.probs);
        let plain = ClassifyRequest::new(vec![0.0]);
        assert!(plain.backend.is_none() && plain.model.is_none() && !plain.probs);
    }
}
