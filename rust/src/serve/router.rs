//! Request router: resolves `(model, backend)` to a [`Classifier`] trait
//! object in the shared [`ModelRegistry`] and dispatches.
//!
//! Backends that advertise a batch-oriented cost model
//! (`preferred_batch > 1`, i.e. the XLA engine) have single requests
//! coalesced through the dynamic batcher, which groups concurrent
//! traffic per classifier instance and executes one fused
//! `classify_batch` per group; single-row walkers (`forest`/`dd`) are
//! served inline. Explicit batch requests bypass the batcher and go
//! straight to the backend's batch path.
//!
//! The router holds no model state of its own: a hot-swap in the
//! registry is visible to the very next request, while requests already
//! dispatched finish against the version they resolved (RCU via `Arc`).
//!
//! Fault handling lives here too: every eval attempt runs behind a
//! panic guard (a shard panic quarantined by the pool, or an unwind out
//! of a serial walk, becomes [`Error::EvalPanic`] for that request
//! only), outcomes feed per-`(model, backend)` circuit breakers
//! ([`BreakerBoard`]), and an open breaker reroutes along the
//! bit-identical chain `frozen → dd → forest`. The per-request deadline
//! (published thread-locally by the HTTP layer) is checked before and
//! after eval and rides each coalesced job into the batcher, which
//! answers expired jobs with `504` instead of evaluating them.

use crate::batch::{RowMatrix, RowMatrixBuf};
use crate::classifier::Classifier;
use crate::engine::{ModelRegistry, ModelVersion};
use crate::error::{Error, Result};
use crate::serve::batcher::{Batcher, BatcherConfig};
use crate::serve::breaker::BreakerBoard;
use crate::serve::metrics::ServerMetrics;
use crate::serve::{BackendKind, ClassifyRequest, ClassifyResponse};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::Sender;
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

/// A coalesced single-request job: the resolved classifier, the feature
/// row (moved, never copied, on the hot path), the request deadline,
/// and the reply channel.
type BatchJob = (
    Arc<dyn Classifier>,
    Vec<f32>,
    Option<Instant>,
    Sender<Result<u32>>,
);

/// The serving router (shared across HTTP workers).
pub struct Router {
    registry: Arc<ModelRegistry>,
    metrics: Arc<ServerMetrics>,
    default_backend: BackendKind,
    /// Started lazily on the first batch-first dispatch: a build without
    /// any batch-native backend (e.g. against the offline `xla` stub)
    /// never pays the batcher thread or its queue.
    batcher: OnceLock<Batcher<BatchJob>>,
    batch_cfg: BatcherConfig,
    reply_timeout: Duration,
    breakers: BreakerBoard,
}

/// The outcome of one routed single-row dispatch, before response
/// shaping.
struct Routed {
    backend: BackendKind,
    model: String,
    class: u32,
    steps: Option<usize>,
    label: String,
    /// `Some(backend)` when a circuit breaker rerouted the request off
    /// its picked backend.
    rerouted: Option<BackendKind>,
}

/// The outcome of a routed explicit-batch dispatch.
pub struct BatchRouted {
    /// Per-row predicted classes.
    pub classes: Vec<u32>,
    /// Per-row §6 step counts (when requested and the backend meters).
    pub steps: Option<Vec<u32>>,
    /// The model version that served the batch — callers render labels
    /// against the exact version that classified, not a later hot-swap.
    pub version: Arc<ModelVersion>,
    /// `Some(backend)` when a circuit breaker rerouted the batch.
    pub rerouted: Option<BackendKind>,
}

/// Clone an eval error for fan-out to every reply of a failed batch,
/// preserving the variants the HTTP layer maps to dedicated statuses
/// (`504` for expired deadlines, `500` for quarantined panics).
fn clone_eval_err(e: &Error) -> Error {
    match e {
        Error::DeadlineExceeded(msg) => Error::DeadlineExceeded(msg.clone()),
        Error::EvalPanic { shard, msg } => Error::EvalPanic {
            shard: *shard,
            msg: msg.clone(),
        },
        other => Error::Serve(other.to_string()),
    }
}

/// Batcher worker: groups a window's jobs per classifier instance
/// (several models/versions may interleave), packs each group's rows
/// into one flat matrix, and runs one fused `classify_batch` per group.
fn start_batcher(metrics: Arc<ServerMetrics>, cfg: BatcherConfig) -> Batcher<BatchJob> {
    Batcher::start("router", cfg, move |jobs: Vec<BatchJob>| {
        metrics.batch_dequeued(jobs.len() as u64);
        // Deadline-expired jobs are answered (the HTTP layer maps this
        // to 504) and dropped before grouping: a reply nobody is
        // waiting for any more must not cost an eval slot.
        let now = Instant::now();
        let (live, dead): (Vec<BatchJob>, Vec<BatchJob>) = jobs
            .into_iter()
            .partition(|(_, _, deadline, _)| !deadline.is_some_and(|d| now >= d));
        for (_, _, _, reply) in dead {
            let _ = reply.send(Err(Error::DeadlineExceeded(
                "request expired in the batch queue".into(),
            )));
        }
        if live.is_empty() {
            return;
        }
        metrics.observe_batch(live.len());
        let eval_start = Instant::now();
        let mut jobs = live;
        while !jobs.is_empty() {
            let clf = jobs[0].0.clone();
            let (group, rest): (Vec<BatchJob>, Vec<BatchJob>) = jobs
                .into_iter()
                .partition(|(c, _, _, _)| Arc::ptr_eq(c, &clf));
            jobs = rest;
            // Rows of one group share the model's arity (enforced by
            // `check_row` before submission), so they pack into one flat
            // matrix — a contiguous copy each, no per-row Vec downstream.
            let mut rows = RowMatrixBuf::with_capacity(group[0].1.len(), group.len());
            let mut replies = Vec::with_capacity(group.len());
            let mut pack_err = None;
            for (_, row, _, reply) in group {
                if pack_err.is_none() {
                    if let Err(e) = rows.push_row(&row) {
                        pack_err = Some(e.to_string());
                    }
                }
                replies.push(reply);
            }
            let result = match pack_err {
                Some(msg) => Err(Error::Serve(msg)),
                None => {
                    // a backend panic must not take down the batcher
                    // thread (and with it every future coalesced job)
                    let matrix = rows.as_matrix();
                    match catch_unwind(AssertUnwindSafe(|| clf.classify_batch(matrix))) {
                        Ok(r) => r,
                        Err(p) => Err(Error::EvalPanic {
                            shard: 0,
                            msg: crate::runtime::pool::payload_msg(&*p),
                        }),
                    }
                }
            };
            match result {
                Ok(classes) => {
                    for (reply, class) in replies.into_iter().zip(classes) {
                        let _ = reply.send(Ok(class));
                    }
                }
                Err(e) => {
                    for reply in replies {
                        let _ = reply.send(Err(clone_eval_err(&e)));
                    }
                }
            }
        }
        metrics.observe_batch_eval(eval_start.elapsed());
    })
}

impl Router {
    /// Build a router over a model registry. `reply_timeout` bounds how
    /// long a coalesced request waits for its batch to execute
    /// (configurable via `serve::config::ServeConfig::reply_timeout_ms`).
    pub fn new(
        registry: Arc<ModelRegistry>,
        metrics: Arc<ServerMetrics>,
        default_backend: BackendKind,
        batch_cfg: BatcherConfig,
        reply_timeout: Duration,
        breakers: BreakerBoard,
    ) -> Router {
        Router {
            registry,
            metrics,
            default_backend,
            batcher: OnceLock::new(),
            batch_cfg,
            reply_timeout,
            breakers,
        }
    }

    fn batcher(&self) -> &Batcher<BatchJob> {
        self.batcher
            .get_or_init(|| start_batcher(self.metrics.clone(), self.batch_cfg.clone()))
    }

    /// The model registry served by this router.
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// The metrics registry.
    pub fn metrics(&self) -> &Arc<ServerMetrics> {
        &self.metrics
    }

    /// The circuit-breaker board (`/readyz` reads open breakers here).
    pub fn breakers(&self) -> &BreakerBoard {
        &self.breakers
    }

    /// The per-request time budget: how long a coalesced request waits
    /// for its batch, and the default (and cap) for request deadlines.
    pub fn reply_timeout(&self) -> Duration {
        self.reply_timeout
    }

    /// Default backend for requests without an override.
    pub fn default_backend(&self) -> BackendKind {
        self.default_backend
    }

    /// True when the default model has the XLA backend loaded.
    pub fn has_xla(&self) -> bool {
        self.registry
            .get(None)
            .map(|v| v.has(BackendKind::Xla))
            .unwrap_or(false)
    }

    /// Pick the backend for a request. An explicit backend override wins
    /// (and errors if the model lacks it). Otherwise the router-wide
    /// default applies when the resolved model has it, falling back to
    /// the model's own default backend when it doesn't — uniformly for
    /// tagged and untagged traffic, so a forest-only model serves either
    /// way. Deploy-time misconfiguration (e.g. `--backend xla` with
    /// broken artifacts) is surfaced by the startup warning and the
    /// `/model` endpoint's `xla_loaded`/`default_backend` fields, not by
    /// per-request failures.
    fn pick_backend(
        &self,
        version: &crate::engine::ModelVersion,
        requested: Option<BackendKind>,
    ) -> BackendKind {
        match requested {
            Some(kind) => kind,
            None if version.has(self.default_backend) => self.default_backend,
            None => version.default_backend,
        }
    }

    /// Serve one classification request.
    pub fn classify(&self, req: &ClassifyRequest) -> Result<ClassifyResponse> {
        let start = Instant::now();
        match self.dispatch(req.model.as_deref(), req.backend, &req.features) {
            Ok(routed) => {
                let latency = start.elapsed();
                self.metrics.observe(routed.backend, latency);
                Ok(ClassifyResponse {
                    class: routed.class,
                    label: routed.label,
                    backend: routed.backend,
                    model: routed.model,
                    steps: routed.steps,
                    latency_us: latency.as_micros() as u64,
                    served_by: routed.rerouted,
                })
            }
            Err(e) => {
                self.metrics.observe_error();
                Err(e)
            }
        }
    }

    /// Backend attempt order for one request: the picked backend first,
    /// then the bit-identical degradation chain `frozen → dd → forest`
    /// restricted to backends the model actually has — all filtered by
    /// breaker state. When every breaker in the chain is open (probes
    /// already in flight), the picked backend is attempted anyway: the
    /// backends are interchangeable, so failing open keeps serving and
    /// the outcome feeds the breaker either way.
    fn candidates(
        &self,
        version: &ModelVersion,
        primary: BackendKind,
        model_key: &str,
    ) -> Vec<BackendKind> {
        let mut chain = vec![primary];
        for kind in [BackendKind::Frozen, BackendKind::Dd, BackendKind::Forest] {
            if kind != primary && version.has(kind) {
                chain.push(kind);
            }
        }
        let allowed: Vec<BackendKind> = chain
            .iter()
            .copied()
            .filter(|&kind| self.breakers.allow(model_key, kind))
            .collect();
        if allowed.is_empty() {
            vec![primary]
        } else {
            allowed
        }
    }

    /// Feed one eval outcome to the breaker board and mirror its gauges
    /// into the metrics snapshot.
    fn note_outcome(&self, model_key: &str, kind: BackendKind, ok: bool) {
        if ok {
            self.breakers.record_success(model_key, kind);
        } else {
            self.breakers.record_failure(model_key, kind);
        }
        self.metrics
            .sync_breakers(self.breakers.open_count(), self.breakers.trips_total());
    }

    /// One eval attempt against one backend: batch-first backends go
    /// through the dynamic batcher, single-row walkers run inline behind
    /// a panic guard. A result computed after the deadline is discarded
    /// — the frozen sweep may have bailed out mid-batch, so a late
    /// answer is not guaranteed complete.
    fn eval_single(
        &self,
        version: &ModelVersion,
        kind: BackendKind,
        features: &[f32],
        deadline: Option<Instant>,
    ) -> Result<(u32, Option<usize>)> {
        let slot = version.slot(kind)?.clone();
        let out = if slot.batch_first {
            let (tx, rx) = std::sync::mpsc::channel();
            // depth gauge brackets the submit: a rejected job never counts
            self.metrics.batch_enqueued();
            if let Err(e) = self
                .batcher()
                .submit((slot.classifier.clone(), features.to_vec(), deadline, tx))
            {
                self.metrics.batch_dequeued(1);
                return Err(e);
            }
            let class = rx
                .recv_timeout(self.reply_timeout)
                .map_err(|_| Error::Serve("batched backend reply timed out".into()))??;
            (class, None)
        } else {
            match catch_unwind(AssertUnwindSafe(|| {
                slot.classifier.classify_with_steps(features)
            })) {
                Ok(r) => r?,
                Err(p) => {
                    return Err(Error::EvalPanic {
                        shard: 0,
                        msg: crate::runtime::pool::payload_msg(&*p),
                    })
                }
            }
        };
        if deadline.is_some_and(|d| Instant::now() >= d) {
            return Err(Error::DeadlineExceeded(
                "deadline expired during evaluation".into(),
            ));
        }
        Ok(out)
    }

    fn dispatch(
        &self,
        model: Option<&str>,
        requested: Option<BackendKind>,
        features: &[f32],
    ) -> Result<Routed> {
        let deadline = crate::obs::trace::eval_deadline();
        let version = self.registry.get(model)?;
        let primary = self.pick_backend(&version, requested);
        // an explicitly requested backend the model lacks is a client
        // error, surfaced before any fallback logic runs
        version.slot(primary)?;
        version.check_row(features)?;
        if deadline.is_some_and(|d| Instant::now() >= d) {
            return Err(Error::DeadlineExceeded(
                "request expired before evaluation".into(),
            ));
        }
        let model_key = version.id.to_string();
        let mut last_err = None;
        for kind in self.candidates(&version, primary, &model_key) {
            match self.eval_single(&version, kind, features, deadline) {
                Ok((class, steps)) => {
                    self.note_outcome(&model_key, kind, true);
                    let rerouted = (kind != primary).then_some(kind);
                    if rerouted.is_some() {
                        self.metrics.observe_degraded();
                    }
                    return Ok(Routed {
                        backend: kind,
                        model: model_key,
                        class,
                        steps,
                        label: version.label_of(class),
                        rerouted,
                    });
                }
                // no fallback can beat an expired clock, and overload is
                // shed (429), never rerouted around admission control
                Err(e @ (Error::DeadlineExceeded(_) | Error::Overloaded(_))) => return Err(e),
                Err(e) => {
                    if matches!(e, Error::EvalPanic { .. }) {
                        self.metrics.observe_eval_panic();
                    }
                    self.note_outcome(&model_key, kind, false);
                    last_err = Some(e);
                }
            }
        }
        Err(last_err.unwrap_or_else(|| Error::Serve("no backend available".into())))
    }

    /// One batch eval attempt against one backend, behind the same panic
    /// guard and post-eval deadline check as [`eval_single`](Self::eval_single)
    /// (the frozen sweep may bail out mid-batch on expiry, so a late
    /// result is discarded rather than returned incomplete).
    fn eval_batch(
        &self,
        version: &ModelVersion,
        kind: BackendKind,
        rows: RowMatrix<'_>,
        want_steps: bool,
        deadline: Option<Instant>,
    ) -> Result<(Vec<u32>, Option<Vec<u32>>)> {
        let slot = version.slot(kind)?.clone();
        let out = match catch_unwind(AssertUnwindSafe(|| {
            if want_steps {
                slot.classifier.classify_batch_with_steps(rows)
            } else {
                slot.classifier.classify_batch(rows).map(|c| (c, None))
            }
        })) {
            Ok(r) => r?,
            Err(p) => {
                return Err(Error::EvalPanic {
                    shard: 0,
                    msg: crate::runtime::pool::payload_msg(&*p),
                })
            }
        };
        if deadline.is_some_and(|d| Instant::now() >= d) {
            return Err(Error::DeadlineExceeded(
                "deadline expired during evaluation".into(),
            ));
        }
        Ok(out)
    }

    /// Serve an explicit flat batch (bypasses the single-request batcher
    /// and uses the backend's native batch path directly). With
    /// `want_steps`, metered backends also return the §6 step count per
    /// row (`None` for backends that cannot meter, e.g. XLA) — the batch
    /// counterpart of the single-request `steps` field. Breakers and the
    /// degradation chain apply exactly as on the single-request path.
    pub fn classify_batch(
        &self,
        rows: RowMatrix<'_>,
        backend: Option<BackendKind>,
        model: Option<&str>,
        want_steps: bool,
    ) -> Result<BatchRouted> {
        let start = Instant::now();
        let deadline = crate::obs::trace::eval_deadline();
        let result = (|| {
            let version = self.registry.get(model)?;
            let primary = self.pick_backend(&version, backend);
            version.slot(primary)?;
            version.check_matrix(rows)?;
            if deadline.is_some_and(|d| Instant::now() >= d) {
                return Err(Error::DeadlineExceeded(
                    "request expired before evaluation".into(),
                ));
            }
            let model_key = version.id.to_string();
            let mut last_err = None;
            for kind in self.candidates(&version, primary, &model_key) {
                match self.eval_batch(&version, kind, rows, want_steps, deadline) {
                    Ok((classes, steps)) => {
                        self.note_outcome(&model_key, kind, true);
                        let rerouted = (kind != primary).then_some(kind);
                        if rerouted.is_some() {
                            self.metrics.observe_degraded();
                        }
                        return Ok((kind, classes, steps, version, rerouted));
                    }
                    Err(e @ Error::DeadlineExceeded(_)) => return Err(e),
                    Err(e) => {
                        if matches!(e, Error::EvalPanic { .. }) {
                            self.metrics.observe_eval_panic();
                        }
                        self.note_outcome(&model_key, kind, false);
                        last_err = Some(e);
                    }
                }
            }
            Err(last_err.unwrap_or_else(|| Error::Serve("no backend available".into())))
        })();
        match result {
            Ok((backend, classes, steps, version, rerouted)) => {
                let elapsed = start.elapsed();
                self.metrics.observe(backend, elapsed);
                self.metrics.observe_batch(rows.n_rows());
                self.metrics.observe_batch_eval(elapsed);
                Ok(BatchRouted {
                    classes,
                    steps,
                    version,
                    rerouted,
                })
            }
            Err(e) => {
                self.metrics.observe_error();
                Err(e)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Engine;

    fn router() -> (crate::data::Dataset, Router) {
        let ds = crate::data::datasets::iris();
        let engine = Engine::builder()
            .dataset(ds.clone())
            .trees(12)
            .seed(2)
            .build()
            .unwrap();
        let r = Router::new(
            engine.registry().clone(),
            Arc::new(ServerMetrics::default()),
            BackendKind::Dd,
            BatcherConfig::default(),
            Duration::from_secs(5),
            BreakerBoard::new(3, Duration::from_millis(100)),
        );
        (ds, r)
    }

    #[test]
    fn native_backends_agree() {
        let (ds, r) = router();
        for i in (0..ds.n_rows()).step_by(11) {
            let via_dd = r
                .classify(&ClassifyRequest::new(ds.row(i).to_vec()).on_backend(BackendKind::Dd))
                .unwrap();
            let via_rf = r
                .classify(
                    &ClassifyRequest::new(ds.row(i).to_vec()).on_backend(BackendKind::Forest),
                )
                .unwrap();
            assert_eq!(via_dd.class, via_rf.class, "row {i}");
            assert!(via_dd.steps.unwrap() < via_rf.steps.unwrap());
            assert_eq!(via_dd.model, "default@v1");
        }
    }

    #[test]
    fn default_backend_applies() {
        let (ds, r) = router();
        let resp = r.classify(&ClassifyRequest::new(ds.row(0).to_vec())).unwrap();
        assert_eq!(resp.backend, BackendKind::Dd);
        assert!(!resp.label.is_empty());
    }

    #[test]
    fn bad_rows_rejected_and_counted() {
        let (_, r) = router();
        let err = r.classify(&ClassifyRequest::new(vec![1.0])).unwrap_err();
        assert!(err.to_string().contains("features"));
        assert_eq!(
            r.metrics().errors.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn xla_without_engine_fails_cleanly() {
        let (ds, r) = router();
        let err = r
            .classify(&ClassifyRequest::new(ds.row(0).to_vec()).on_backend(BackendKind::Xla))
            .unwrap_err();
        assert!(err.to_string().contains("not available"));
    }

    #[test]
    fn unknown_model_fails_cleanly() {
        let (ds, r) = router();
        let err = r
            .classify(&ClassifyRequest::new(ds.row(0).to_vec()).on_model("nope"))
            .unwrap_err();
        assert!(err.to_string().contains("unknown model"));
    }

    #[test]
    fn batch_endpoint_native() {
        let (ds, r) = router();
        let mut buf = RowMatrixBuf::with_capacity(ds.n_features(), 30);
        for i in 0..30 {
            buf.push_row(ds.row(i * 5)).unwrap();
        }
        let rows = buf.as_matrix();
        let dd = r
            .classify_batch(rows, Some(BackendKind::Dd), None, false)
            .unwrap();
        assert!(dd.steps.is_none(), "steps only on request");
        assert!(dd.rerouted.is_none(), "healthy path never reroutes");
        let rf = r
            .classify_batch(rows, Some(BackendKind::Forest), None, false)
            .unwrap();
        let frozen = r
            .classify_batch(rows, Some(BackendKind::Frozen), None, true)
            .unwrap();
        assert_eq!(dd.classes, rf.classes);
        assert_eq!(dd.classes, frozen.classes);
        assert_eq!(dd.classes.len(), 30);
        assert_eq!(dd.version.id.to_string(), "default@v1");
        // §6 metering survives the explicit-batch path, row for row
        let frozen_steps = frozen.steps.expect("frozen walks are metered");
        for (i, row) in rows.iter().enumerate() {
            let single = r
                .classify(
                    &ClassifyRequest::new(row.to_vec()).on_backend(BackendKind::Frozen),
                )
                .unwrap();
            assert_eq!(frozen_steps[i] as usize, single.steps.unwrap(), "row {i}");
        }
        // batch sizes and eval time land in the histograms
        assert!(r.metrics().batch_size.count() >= 3);
        assert!(r.metrics().batch_eval_us.count() >= 3);
    }

    #[test]
    fn untagged_requests_fall_back_to_the_model_default_backend() {
        let (ds, r) = router();
        // a forest-only model lacks the router-wide default backend (dd)
        crate::engine::register_forest(
            r.registry(),
            "baseline",
            crate::forest::ForestLearner::default().trees(4).seed(1).fit(&ds),
        )
        .unwrap();
        let resp = r
            .classify(&ClassifyRequest::new(ds.row(0).to_vec()).on_model("baseline"))
            .unwrap();
        assert_eq!(resp.backend, BackendKind::Forest);
        // an explicit override still errors cleanly
        let err = r
            .classify(
                &ClassifyRequest::new(ds.row(0).to_vec())
                    .on_model("baseline")
                    .on_backend(BackendKind::Dd),
            )
            .unwrap_err();
        assert!(err.to_string().contains("not available"));
    }

    #[test]
    fn per_request_model_selection_and_hot_swap() {
        let (ds, r) = router();
        // register a second, smaller model under another name
        let engine = Engine::with_registry(r.registry().clone());
        engine
            .train_and_register(
                "canary",
                &ds,
                4,
                0,
                9,
                crate::compile::CompileOptions::default(),
            )
            .unwrap();
        let resp = r
            .classify(&ClassifyRequest::new(ds.row(0).to_vec()).on_model("canary"))
            .unwrap();
        assert_eq!(resp.model, "canary@v1");
        // hot-swap the canary; the next request sees v2 without rebuilding
        // the router
        engine
            .train_and_register(
                "canary",
                &ds,
                6,
                0,
                10,
                crate::compile::CompileOptions::default(),
            )
            .unwrap();
        let resp = r
            .classify(&ClassifyRequest::new(ds.row(0).to_vec()).on_model("canary"))
            .unwrap();
        assert_eq!(resp.model, "canary@v2");
    }

    #[test]
    fn metrics_observe_served_requests() {
        let (ds, r) = router();
        for i in 0..5 {
            r.classify(&ClassifyRequest::new(ds.row(i).to_vec()).on_backend(BackendKind::Dd))
                .unwrap();
        }
        assert_eq!(r.metrics().backend(BackendKind::Dd).count(), 5);
    }

    #[test]
    fn breaker_reroutes_along_the_bit_identical_chain() {
        let (ds, r) = router();
        let row = ds.row(0).to_vec();
        let healthy = r
            .classify(&ClassifyRequest::new(row.clone()).on_backend(BackendKind::Dd))
            .unwrap();
        assert!(healthy.served_by.is_none());
        // trip dd's breaker (threshold 3 on the test board)
        for _ in 0..3 {
            r.breakers().record_failure("default@v1", BackendKind::Dd);
        }
        assert_eq!(r.breakers().open_count(), 1);
        let degraded = r.classify(&ClassifyRequest::new(row.clone())).unwrap();
        assert_eq!(degraded.backend, BackendKind::Frozen, "next in the chain");
        assert_eq!(degraded.served_by, Some(BackendKind::Frozen));
        assert_eq!(degraded.class, healthy.class, "the reroute is bit-identical");
        assert_eq!(
            r.metrics()
                .degraded_requests
                .load(std::sync::atomic::Ordering::Relaxed),
            1
        );
        // dd stays open until its cooldown admits a probe…
        assert_eq!(r.breakers().open_count(), 1);
        std::thread::sleep(Duration::from_millis(150));
        // …whose success re-closes the breaker and restores the primary
        let recovered = r.classify(&ClassifyRequest::new(row)).unwrap();
        assert_eq!(recovered.backend, BackendKind::Dd);
        assert!(recovered.served_by.is_none());
        assert_eq!(r.breakers().open_count(), 0);
    }

    #[test]
    fn expired_deadlines_fail_fast_with_a_deadline_error() {
        let (ds, r) = router();
        crate::obs::trace::set_eval_deadline(Some(Instant::now() - Duration::from_millis(5)));
        let err = r
            .classify(&ClassifyRequest::new(ds.row(0).to_vec()))
            .unwrap_err();
        assert!(matches!(err, Error::DeadlineExceeded(_)), "{err}");
        // the explicit batch path enforces the same budget
        let mut buf = RowMatrixBuf::with_capacity(ds.n_features(), 1);
        buf.push_row(ds.row(0)).unwrap();
        let err = r
            .classify_batch(buf.as_matrix(), None, None, false)
            .unwrap_err();
        assert!(matches!(err, Error::DeadlineExceeded(_)), "{err}");
        // clearing the deadline restores service on this thread
        crate::obs::trace::set_eval_deadline(None);
        assert!(r
            .classify(&ClassifyRequest::new(ds.row(0).to_vec()))
            .is_ok());
    }
}
