//! Request router: dispatches classification requests across backends.
//!
//! Single requests on the `xla` backend pass through the dynamic batcher,
//! which coalesces concurrent traffic into PJRT executions; `forest`/`dd`
//! requests are served inline (they are single-row walks with no batching
//! benefit). Explicit batch requests bypass the batcher and chunk straight
//! into the engine.

use crate::error::{Error, Result};
use crate::serve::batcher::{Batcher, BatcherConfig};
use crate::serve::metrics::ServerMetrics;
use crate::serve::xla_backend::XlaBackend;
use crate::serve::{BackendKind, ClassifyRequest, ClassifyResponse, ModelBundle};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::{Duration, Instant};

type XlaJob = (Vec<f32>, Sender<Result<u32>>);

/// The serving router (shared across HTTP workers).
pub struct Router {
    bundle: Arc<ModelBundle>,
    metrics: Arc<ServerMetrics>,
    default_backend: BackendKind,
    xla: Option<Arc<XlaBackend>>,
    xla_batcher: Option<Batcher<XlaJob>>,
    reply_timeout: Duration,
}

impl Router {
    /// Build a router. `xla` is optional — without it, `xla`-backend
    /// requests fail cleanly and the serving path is fully native.
    pub fn new(
        bundle: Arc<ModelBundle>,
        metrics: Arc<ServerMetrics>,
        default_backend: BackendKind,
        xla: Option<Arc<XlaBackend>>,
        batch_cfg: BatcherConfig,
    ) -> Router {
        let xla_batcher = xla.as_ref().map(|backend| {
            let backend = backend.clone();
            let m = metrics.clone();
            Batcher::start("xla", batch_cfg, move |jobs: Vec<XlaJob>| {
                m.observe_batch(jobs.len());
                let rows: Vec<Vec<f32>> = jobs.iter().map(|(r, _)| r.clone()).collect();
                match backend.classify_batch(rows) {
                    Ok(classes) => {
                        for ((_, reply), class) in jobs.into_iter().zip(classes) {
                            let _ = reply.send(Ok(class));
                        }
                    }
                    Err(e) => {
                        let msg = e.to_string();
                        for (_, reply) in jobs {
                            let _ = reply.send(Err(Error::Serve(msg.clone())));
                        }
                    }
                }
            })
        });
        Router {
            bundle,
            metrics,
            default_backend,
            xla,
            xla_batcher,
            reply_timeout: Duration::from_secs(5),
        }
    }

    /// The model bundle served by this router.
    pub fn bundle(&self) -> &Arc<ModelBundle> {
        &self.bundle
    }

    /// The metrics registry.
    pub fn metrics(&self) -> &Arc<ServerMetrics> {
        &self.metrics
    }

    /// Default backend for requests without an override.
    pub fn default_backend(&self) -> BackendKind {
        self.default_backend
    }

    /// True when the XLA path is loaded.
    pub fn has_xla(&self) -> bool {
        self.xla.is_some()
    }

    /// Serve one classification request.
    pub fn classify(&self, req: &ClassifyRequest) -> Result<ClassifyResponse> {
        let start = Instant::now();
        let backend = req.backend.unwrap_or(self.default_backend);
        let result = self.dispatch(backend, &req.features);
        match result {
            Ok((class, steps)) => {
                let latency = start.elapsed();
                self.metrics.observe(backend, latency);
                Ok(ClassifyResponse {
                    class,
                    label: self.bundle.label(class),
                    backend,
                    steps,
                    latency_us: latency.as_micros() as u64,
                })
            }
            Err(e) => {
                self.metrics.observe_error();
                Err(e)
            }
        }
    }

    fn dispatch(&self, backend: BackendKind, features: &[f32]) -> Result<(u32, Option<usize>)> {
        self.bundle.check_row(features)?;
        match backend {
            BackendKind::Forest => {
                let (c, steps) = self.bundle.forest.predict_with_steps(features);
                Ok((c, Some(steps)))
            }
            BackendKind::Dd => {
                let (c, steps) = self.bundle.dd.classify_with_steps(features);
                Ok((c, Some(steps)))
            }
            BackendKind::Xla => {
                let batcher = self
                    .xla_batcher
                    .as_ref()
                    .ok_or_else(|| Error::Serve("xla backend not loaded".into()))?;
                let (tx, rx) = std::sync::mpsc::channel();
                batcher.submit((features.to_vec(), tx))?;
                let class = rx
                    .recv_timeout(self.reply_timeout)
                    .map_err(|_| Error::Serve("xla reply timed out".into()))??;
                Ok((class, None))
            }
        }
    }

    /// Serve an explicit batch (bypasses the single-request batcher).
    pub fn classify_batch(
        &self,
        rows: &[Vec<f32>],
        backend: Option<BackendKind>,
    ) -> Result<Vec<u32>> {
        let backend = backend.unwrap_or(self.default_backend);
        let start = Instant::now();
        for r in rows {
            self.bundle.check_row(r)?;
        }
        let out = match backend {
            BackendKind::Forest => rows
                .iter()
                .map(|r| self.bundle.forest.predict(r))
                .collect::<Vec<_>>(),
            BackendKind::Dd => rows
                .iter()
                .map(|r| self.bundle.dd.classify(r))
                .collect::<Vec<_>>(),
            BackendKind::Xla => {
                let xla = self
                    .xla
                    .as_ref()
                    .ok_or_else(|| Error::Serve("xla backend not loaded".into()))?;
                self.metrics.observe_batch(rows.len());
                xla.classify_batch(rows.to_vec())?
            }
        };
        self.metrics.observe(backend, start.elapsed());
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compile::CompileOptions;
    use crate::data::datasets;

    fn router() -> (crate::data::Dataset, Router) {
        let ds = datasets::iris();
        let bundle =
            Arc::new(ModelBundle::train(&ds, 12, 0, 2, CompileOptions::default()).unwrap());
        let r = Router::new(
            bundle,
            Arc::new(ServerMetrics::default()),
            BackendKind::Dd,
            None,
            BatcherConfig::default(),
        );
        (ds, r)
    }

    #[test]
    fn native_backends_agree() {
        let (ds, r) = router();
        for i in (0..ds.n_rows()).step_by(11) {
            let via_dd = r
                .classify(&ClassifyRequest {
                    features: ds.row(i).to_vec(),
                    backend: Some(BackendKind::Dd),
                })
                .unwrap();
            let via_rf = r
                .classify(&ClassifyRequest {
                    features: ds.row(i).to_vec(),
                    backend: Some(BackendKind::Forest),
                })
                .unwrap();
            assert_eq!(via_dd.class, via_rf.class, "row {i}");
            assert!(via_dd.steps.unwrap() < via_rf.steps.unwrap());
        }
    }

    #[test]
    fn default_backend_applies() {
        let (ds, r) = router();
        let resp = r
            .classify(&ClassifyRequest {
                features: ds.row(0).to_vec(),
                backend: None,
            })
            .unwrap();
        assert_eq!(resp.backend, BackendKind::Dd);
        assert!(!resp.label.is_empty());
    }

    #[test]
    fn bad_rows_rejected_and_counted() {
        let (_, r) = router();
        let err = r
            .classify(&ClassifyRequest {
                features: vec![1.0],
                backend: None,
            })
            .unwrap_err();
        assert!(err.to_string().contains("features"));
        assert_eq!(
            r.metrics().errors.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn xla_without_engine_fails_cleanly() {
        let (ds, r) = router();
        let err = r
            .classify(&ClassifyRequest {
                features: ds.row(0).to_vec(),
                backend: Some(BackendKind::Xla),
            })
            .unwrap_err();
        assert!(err.to_string().contains("not loaded"));
    }

    #[test]
    fn batch_endpoint_native() {
        let (ds, r) = router();
        let rows: Vec<Vec<f32>> = (0..30).map(|i| ds.row(i * 5).to_vec()).collect();
        let dd = r.classify_batch(&rows, Some(BackendKind::Dd)).unwrap();
        let rf = r.classify_batch(&rows, Some(BackendKind::Forest)).unwrap();
        assert_eq!(dd, rf);
        assert_eq!(dd.len(), 30);
    }

    #[test]
    fn metrics_observe_served_requests() {
        let (ds, r) = router();
        for i in 0..5 {
            r.classify(&ClassifyRequest {
                features: ds.row(i).to_vec(),
                backend: Some(BackendKind::Dd),
            })
            .unwrap();
        }
        assert_eq!(r.metrics().backend(BackendKind::Dd).count(), 5);
    }
}
