//! Request router: resolves `(model, backend)` to a [`Classifier`] trait
//! object in the shared [`ModelRegistry`] and dispatches.
//!
//! Backends that advertise a batch-oriented cost model
//! (`preferred_batch > 1`, i.e. the XLA engine) have single requests
//! coalesced through the dynamic batcher, which groups concurrent
//! traffic per classifier instance and executes one fused
//! `classify_batch` per group; single-row walkers (`forest`/`dd`) are
//! served inline. Explicit batch requests bypass the batcher and go
//! straight to the backend's batch path.
//!
//! The router holds no model state of its own: a hot-swap in the
//! registry is visible to the very next request, while requests already
//! dispatched finish against the version they resolved (RCU via `Arc`).
//!
//! Fault handling lives here too: every eval attempt runs behind a
//! panic guard (a shard panic quarantined by the pool, or an unwind out
//! of a serial walk, becomes [`Error::EvalPanic`] for that request
//! only), outcomes feed per-`(model, backend)` circuit breakers
//! ([`BreakerBoard`]), and an open breaker reroutes along the
//! bit-identical chain `frozen → dd → forest`. The per-request deadline
//! (published thread-locally by the HTTP layer) is checked before and
//! after eval and rides each coalesced job into the batcher, which
//! answers expired jobs with `504` instead of evaluating them.

use crate::add::terminal::{argmax, expected_value, probabilities, weighted_argmax};
use crate::batch::{RowMatrix, RowMatrixBuf};
use crate::classifier::Classifier;
use crate::engine::{ModelRegistry, ModelVersion};
use crate::error::{Error, Result};
use crate::serve::batcher::{Batcher, BatcherConfig};
use crate::serve::breaker::BreakerBoard;
use crate::serve::metrics::ServerMetrics;
use crate::serve::{BackendKind, ClassifyRequest, ClassifyResponse};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::Sender;
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

/// A coalesced single-request job: the resolved classifier, the feature
/// row (moved, never copied, on the hot path), the request deadline,
/// and the reply channel.
type BatchJob = (
    Arc<dyn Classifier>,
    Vec<f32>,
    Option<Instant>,
    Sender<Result<u32>>,
);

/// The serving router (shared across HTTP workers).
pub struct Router {
    registry: Arc<ModelRegistry>,
    metrics: Arc<ServerMetrics>,
    default_backend: BackendKind,
    /// Started lazily on the first batch-first dispatch: a build without
    /// any batch-native backend (e.g. against the offline `xla` stub)
    /// never pays the batcher thread or its queue.
    batcher: OnceLock<Batcher<BatchJob>>,
    batch_cfg: BatcherConfig,
    reply_timeout: Duration,
    breakers: BreakerBoard,
    /// Per-class decision weights (`ServeConfig::class_weights`): when
    /// non-empty, every decision becomes
    /// [`weighted_argmax`](crate::add::terminal::weighted_argmax) over
    /// the model's vote vector. Empty = plain majority.
    class_weights: Vec<f32>,
}

/// The outcome of one routed single-row dispatch, before response
/// shaping.
struct Routed {
    backend: BackendKind,
    model: String,
    class: u32,
    steps: Option<usize>,
    label: String,
    /// `Some(backend)` when a circuit breaker rerouted the request off
    /// its picked backend.
    rerouted: Option<BackendKind>,
    /// Per-class vote counts, when the request (or the router's decision
    /// rule) needed them.
    votes: Option<Vec<u32>>,
    /// Regression prediction (vote-weighted bin mean), when the model is
    /// a regression forest.
    value: Option<f64>,
}

/// The outcome of a routed explicit-batch dispatch.
pub struct BatchRouted {
    /// Per-row predicted classes.
    pub classes: Vec<u32>,
    /// Per-row §6 step counts (when requested and the backend meters).
    pub steps: Option<Vec<u32>>,
    /// The model version that served the batch — callers render labels
    /// against the exact version that classified, not a later hot-swap.
    pub version: Arc<ModelVersion>,
    /// `Some(backend)` when a circuit breaker rerouted the batch.
    pub rerouted: Option<BackendKind>,
    /// Flat per-row vote counts (stride = the model's class count), when
    /// the batch asked for probabilities.
    pub votes: Option<Vec<u32>>,
    /// Per-row regression predictions, when the model is a regression
    /// forest.
    pub values: Option<Vec<f64>>,
}

/// Clone an eval error for fan-out to every reply of a failed batch,
/// preserving the variants the HTTP layer maps to dedicated statuses
/// (`504` for expired deadlines, `500` for quarantined panics).
fn clone_eval_err(e: &Error) -> Error {
    match e {
        Error::DeadlineExceeded(msg) => Error::DeadlineExceeded(msg.clone()),
        Error::EvalPanic { shard, msg } => Error::EvalPanic {
            shard: *shard,
            msg: msg.clone(),
        },
        other => Error::Serve(other.to_string()),
    }
}

/// Batcher worker: groups a window's jobs per classifier instance
/// (several models/versions may interleave), packs each group's rows
/// into one flat matrix, and runs one fused `classify_batch` per group.
fn start_batcher(metrics: Arc<ServerMetrics>, cfg: BatcherConfig) -> Batcher<BatchJob> {
    Batcher::start("router", cfg, move |jobs: Vec<BatchJob>| {
        metrics.batch_dequeued(jobs.len() as u64);
        // Deadline-expired jobs are answered (the HTTP layer maps this
        // to 504) and dropped before grouping: a reply nobody is
        // waiting for any more must not cost an eval slot.
        let now = Instant::now();
        let (live, dead): (Vec<BatchJob>, Vec<BatchJob>) = jobs
            .into_iter()
            .partition(|(_, _, deadline, _)| !deadline.is_some_and(|d| now >= d));
        for (_, _, _, reply) in dead {
            let _ = reply.send(Err(Error::DeadlineExceeded(
                "request expired in the batch queue".into(),
            )));
        }
        if live.is_empty() {
            return;
        }
        metrics.observe_batch(live.len());
        let eval_start = Instant::now();
        let mut jobs = live;
        while !jobs.is_empty() {
            let clf = jobs[0].0.clone();
            let (group, rest): (Vec<BatchJob>, Vec<BatchJob>) = jobs
                .into_iter()
                .partition(|(c, _, _, _)| Arc::ptr_eq(c, &clf));
            jobs = rest;
            // Rows of one group share the model's arity (enforced by
            // `check_row` before submission), so they pack into one flat
            // matrix — a contiguous copy each, no per-row Vec downstream.
            let mut rows = RowMatrixBuf::with_capacity(group[0].1.len(), group.len());
            let mut replies = Vec::with_capacity(group.len());
            let mut pack_err = None;
            for (_, row, _, reply) in group {
                if pack_err.is_none() {
                    if let Err(e) = rows.push_row(&row) {
                        pack_err = Some(e.to_string());
                    }
                }
                replies.push(reply);
            }
            let result = match pack_err {
                Some(msg) => Err(Error::Serve(msg)),
                None => {
                    // a backend panic must not take down the batcher
                    // thread (and with it every future coalesced job)
                    let matrix = rows.as_matrix();
                    match catch_unwind(AssertUnwindSafe(|| clf.classify_batch(matrix))) {
                        Ok(r) => r,
                        Err(p) => Err(Error::EvalPanic {
                            shard: 0,
                            msg: crate::runtime::pool::payload_msg(&*p),
                        }),
                    }
                }
            };
            match result {
                Ok(classes) => {
                    for (reply, class) in replies.into_iter().zip(classes) {
                        let _ = reply.send(Ok(class));
                    }
                }
                Err(e) => {
                    for reply in replies {
                        let _ = reply.send(Err(clone_eval_err(&e)));
                    }
                }
            }
        }
        metrics.observe_batch_eval(eval_start.elapsed());
    })
}

impl Router {
    /// Build a router over a model registry. `reply_timeout` bounds how
    /// long a coalesced request waits for its batch to execute
    /// (configurable via `serve::config::ServeConfig::reply_timeout_ms`).
    pub fn new(
        registry: Arc<ModelRegistry>,
        metrics: Arc<ServerMetrics>,
        default_backend: BackendKind,
        batch_cfg: BatcherConfig,
        reply_timeout: Duration,
        breakers: BreakerBoard,
    ) -> Router {
        Router {
            registry,
            metrics,
            default_backend,
            batcher: OnceLock::new(),
            batch_cfg,
            reply_timeout,
            breakers,
            class_weights: Vec::new(),
        }
    }

    /// Install per-class decision weights (`ServeConfig::class_weights`).
    /// Arity is validated per request against the resolved model's class
    /// count, since models hot-swap underneath the router.
    pub fn with_class_weights(mut self, weights: Vec<f32>) -> Router {
        self.class_weights = weights;
        self
    }

    /// The configured decision weights for one resolved model version:
    /// `None` when unweighted, an error when the configured arity does
    /// not match the model's class count.
    fn decision_weights(&self, version: &ModelVersion) -> Result<Option<&[f32]>> {
        if self.class_weights.is_empty() {
            return Ok(None);
        }
        let k = version.schema.n_classes();
        if self.class_weights.len() != k {
            return Err(Error::invalid(format!(
                "class_weights has {} entries but model '{}' has {k} classes",
                self.class_weights.len(),
                version.id
            )));
        }
        Ok(Some(&self.class_weights))
    }

    fn batcher(&self) -> &Batcher<BatchJob> {
        self.batcher
            .get_or_init(|| start_batcher(self.metrics.clone(), self.batch_cfg.clone()))
    }

    /// The model registry served by this router.
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// The metrics registry.
    pub fn metrics(&self) -> &Arc<ServerMetrics> {
        &self.metrics
    }

    /// The circuit-breaker board (`/readyz` reads open breakers here).
    pub fn breakers(&self) -> &BreakerBoard {
        &self.breakers
    }

    /// The per-request time budget: how long a coalesced request waits
    /// for its batch, and the default (and cap) for request deadlines.
    pub fn reply_timeout(&self) -> Duration {
        self.reply_timeout
    }

    /// Default backend for requests without an override.
    pub fn default_backend(&self) -> BackendKind {
        self.default_backend
    }

    /// True when the default model has the XLA backend loaded.
    pub fn has_xla(&self) -> bool {
        self.registry
            .get(None)
            .map(|v| v.has(BackendKind::Xla))
            .unwrap_or(false)
    }

    /// Pick the backend for a request. An explicit backend override wins
    /// (and errors if the model lacks it). Otherwise the router-wide
    /// default applies when the resolved model has it, falling back to
    /// the model's own default backend when it doesn't — uniformly for
    /// tagged and untagged traffic, so a forest-only model serves either
    /// way. Deploy-time misconfiguration (e.g. `--backend xla` with
    /// broken artifacts) is surfaced by the startup warning and the
    /// `/model` endpoint's `xla_loaded`/`default_backend` fields, not by
    /// per-request failures.
    fn pick_backend(
        &self,
        version: &crate::engine::ModelVersion,
        requested: Option<BackendKind>,
    ) -> BackendKind {
        match requested {
            Some(kind) => kind,
            None if version.has(self.default_backend) => self.default_backend,
            None => version.default_backend,
        }
    }

    /// Serve one classification request.
    pub fn classify(&self, req: &ClassifyRequest) -> Result<ClassifyResponse> {
        let start = Instant::now();
        if req.probs {
            self.metrics.observe_prob_request();
        }
        match self.dispatch(req.model.as_deref(), req.backend, &req.features, req.probs) {
            Ok(routed) => {
                let latency = start.elapsed();
                self.metrics.observe(routed.backend, latency);
                // Votes may have been fetched only to drive a weighted or
                // regression decision — they reach the client solely on
                // explicit request.
                let probs = routed
                    .votes
                    .as_deref()
                    .filter(|_| req.probs)
                    .map(probabilities);
                let votes = if req.probs { routed.votes } else { None };
                Ok(ClassifyResponse {
                    class: routed.class,
                    label: routed.label,
                    backend: routed.backend,
                    model: routed.model,
                    steps: routed.steps,
                    latency_us: latency.as_micros() as u64,
                    served_by: routed.rerouted,
                    votes,
                    probs,
                    value: routed.value,
                })
            }
            Err(e) => {
                self.metrics.observe_error();
                Err(e)
            }
        }
    }

    /// Backend attempt order for one request: the picked backend first,
    /// then the bit-identical degradation chain `frozen → dd → forest`
    /// restricted to backends the model actually has — all filtered by
    /// breaker state. When every breaker in the chain is open (probes
    /// already in flight), the picked backend is attempted anyway: the
    /// backends are interchangeable, so failing open keeps serving and
    /// the outcome feeds the breaker either way.
    fn candidates(
        &self,
        version: &ModelVersion,
        primary: BackendKind,
        model_key: &str,
    ) -> Vec<BackendKind> {
        let mut chain = vec![primary];
        for kind in [BackendKind::Frozen, BackendKind::Dd, BackendKind::Forest] {
            if kind != primary && version.has(kind) {
                chain.push(kind);
            }
        }
        let allowed: Vec<BackendKind> = chain
            .iter()
            .copied()
            .filter(|&kind| self.breakers.allow(model_key, kind))
            .collect();
        if allowed.is_empty() {
            vec![primary]
        } else {
            allowed
        }
    }

    /// Feed one eval outcome to the breaker board and mirror its gauges
    /// into the metrics snapshot.
    fn note_outcome(&self, model_key: &str, kind: BackendKind, ok: bool) {
        if ok {
            self.breakers.record_success(model_key, kind);
        } else {
            self.breakers.record_failure(model_key, kind);
        }
        self.metrics
            .sync_breakers(self.breakers.open_count(), self.breakers.trips_total());
    }

    /// One eval attempt against one backend: batch-first backends go
    /// through the dynamic batcher, single-row walkers run inline behind
    /// a panic guard. A result computed after the deadline is discarded
    /// — the frozen sweep may have bailed out mid-batch, so a late
    /// answer is not guaranteed complete.
    ///
    /// With `want_votes` the attempt runs inline even on batch-first
    /// backends: the coalesced batch path only carries classes, and a
    /// backend that cannot expose votes must fail this request alone
    /// with [`Error::InvalidArgument`] rather than poison a fused batch.
    fn eval_single(
        &self,
        version: &ModelVersion,
        kind: BackendKind,
        features: &[f32],
        deadline: Option<Instant>,
        want_votes: bool,
    ) -> Result<(u32, Option<usize>, Option<Vec<u32>>)> {
        let slot = version.slot(kind)?.clone();
        let out = if want_votes {
            match catch_unwind(AssertUnwindSafe(|| {
                let votes = slot.classifier.votes(features)?;
                let (class, steps) = slot.classifier.classify_with_steps(features)?;
                Ok::<_, Error>((class, steps, Some(votes)))
            })) {
                Ok(r) => r?,
                Err(p) => {
                    return Err(Error::EvalPanic {
                        shard: 0,
                        msg: crate::runtime::pool::payload_msg(&*p),
                    })
                }
            }
        } else if slot.batch_first {
            let (tx, rx) = std::sync::mpsc::channel();
            // depth gauge brackets the submit: a rejected job never counts
            self.metrics.batch_enqueued();
            if let Err(e) = self
                .batcher()
                .submit((slot.classifier.clone(), features.to_vec(), deadline, tx))
            {
                self.metrics.batch_dequeued(1);
                return Err(e);
            }
            let class = rx
                .recv_timeout(self.reply_timeout)
                .map_err(|_| Error::Serve("batched backend reply timed out".into()))??;
            (class, None, None)
        } else {
            match catch_unwind(AssertUnwindSafe(|| {
                slot.classifier.classify_with_steps(features)
            })) {
                Ok(r) => {
                    let (class, steps) = r?;
                    (class, steps, None)
                }
                Err(p) => {
                    return Err(Error::EvalPanic {
                        shard: 0,
                        msg: crate::runtime::pool::payload_msg(&*p),
                    })
                }
            }
        };
        if deadline.is_some_and(|d| Instant::now() >= d) {
            return Err(Error::DeadlineExceeded(
                "deadline expired during evaluation".into(),
            ));
        }
        Ok(out)
    }

    fn dispatch(
        &self,
        model: Option<&str>,
        requested: Option<BackendKind>,
        features: &[f32],
        want_probs: bool,
    ) -> Result<Routed> {
        let deadline = crate::obs::trace::eval_deadline();
        let version = self.registry.get(model)?;
        let primary = self.pick_backend(&version, requested);
        // an explicitly requested backend the model lacks is a client
        // error, surfaced before any fallback logic runs
        version.slot(primary)?;
        version.check_row(features)?;
        let weights = self.decision_weights(&version)?;
        let values = version.schema.values();
        // Votes are fetched when the client asked for probabilities, when
        // weighted decisions are configured, or when the model is a
        // regression forest — all three rules are pure post-maps over the
        // same per-class vote vector.
        let want_votes = want_probs || weights.is_some() || values.is_some();
        if deadline.is_some_and(|d| Instant::now() >= d) {
            return Err(Error::DeadlineExceeded(
                "request expired before evaluation".into(),
            ));
        }
        let model_key = version.id.to_string();
        let mut last_err = None;
        for kind in self.candidates(&version, primary, &model_key) {
            match self.eval_single(&version, kind, features, deadline, want_votes) {
                Ok((mut class, steps, votes)) => {
                    self.note_outcome(&model_key, kind, true);
                    let rerouted = (kind != primary).then_some(kind);
                    if rerouted.is_some() {
                        self.metrics.observe_degraded();
                    }
                    let mut value = None;
                    if let Some(v) = votes.as_deref() {
                        if let Some(w) = weights {
                            class = weighted_argmax(v, w) as u32;
                            self.metrics.observe_weighted_decisions(1);
                        }
                        if let Some(vals) = values {
                            value = Some(expected_value(v, vals));
                            self.metrics.observe_regression_predictions(1);
                        }
                    }
                    return Ok(Routed {
                        backend: kind,
                        model: model_key,
                        class,
                        steps,
                        label: version.label_of(class),
                        rerouted,
                        votes,
                        value,
                    });
                }
                // no fallback can beat an expired clock, overload is shed
                // (429) rather than rerouted around admission control, and
                // a votes-capability gap (majority-abstracted model, XLA)
                // is the client's answer — it must not trip breakers or
                // degrade onto a backend with the same gap
                Err(
                    e @ (Error::DeadlineExceeded(_)
                    | Error::Overloaded(_)
                    | Error::InvalidArgument(_)),
                ) => return Err(e),
                Err(e) => {
                    if matches!(e, Error::EvalPanic { .. }) {
                        self.metrics.observe_eval_panic();
                    }
                    self.note_outcome(&model_key, kind, false);
                    last_err = Some(e);
                }
            }
        }
        Err(last_err.unwrap_or_else(|| Error::Serve("no backend available".into())))
    }

    /// One batch eval attempt against one backend, behind the same panic
    /// guard and post-eval deadline check as [`eval_single`](Self::eval_single)
    /// (the frozen sweep may bail out mid-batch on expiry, so a late
    /// result is discarded rather than returned incomplete).
    fn eval_batch(
        &self,
        version: &ModelVersion,
        kind: BackendKind,
        rows: RowMatrix<'_>,
        want_steps: bool,
        want_votes: bool,
        deadline: Option<Instant>,
    ) -> Result<(Vec<u32>, Option<Vec<u32>>, Option<Vec<u32>>)> {
        let slot = version.slot(kind)?.clone();
        let n_classes = version.schema.n_classes();
        let out = match catch_unwind(AssertUnwindSafe(|| {
            if want_votes {
                // classes fall out of the vote sweep (same strict-argmax
                // tie-break as the classify kernels, pinned by the
                // conformance suite); steps need the metered walk too
                let votes = slot.classifier.votes_batch(rows)?;
                let steps = if want_steps {
                    slot.classifier.classify_batch_with_steps(rows)?.1
                } else {
                    None
                };
                let classes = votes
                    .chunks_exact(n_classes)
                    .map(|c| argmax(c) as u32)
                    .collect();
                Ok((classes, steps, Some(votes)))
            } else if want_steps {
                slot.classifier
                    .classify_batch_with_steps(rows)
                    .map(|(c, s)| (c, s, None))
            } else {
                slot.classifier
                    .classify_batch(rows)
                    .map(|c| (c, None, None))
            }
        })) {
            Ok(r) => r?,
            Err(p) => {
                return Err(Error::EvalPanic {
                    shard: 0,
                    msg: crate::runtime::pool::payload_msg(&*p),
                })
            }
        };
        if deadline.is_some_and(|d| Instant::now() >= d) {
            return Err(Error::DeadlineExceeded(
                "deadline expired during evaluation".into(),
            ));
        }
        Ok(out)
    }

    /// Serve an explicit flat batch (bypasses the single-request batcher
    /// and uses the backend's native batch path directly). With
    /// `want_steps`, metered backends also return the §6 step count per
    /// row (`None` for backends that cannot meter, e.g. XLA) — the batch
    /// counterpart of the single-request `steps` field. Breakers and the
    /// degradation chain apply exactly as on the single-request path.
    pub fn classify_batch(
        &self,
        rows: RowMatrix<'_>,
        backend: Option<BackendKind>,
        model: Option<&str>,
        want_steps: bool,
        want_probs: bool,
    ) -> Result<BatchRouted> {
        let start = Instant::now();
        let deadline = crate::obs::trace::eval_deadline();
        if want_probs {
            self.metrics.observe_prob_request();
        }
        let result = (|| {
            let version = self.registry.get(model)?;
            let primary = self.pick_backend(&version, backend);
            version.slot(primary)?;
            version.check_matrix(rows)?;
            let weights = self.decision_weights(&version)?;
            let values = version.schema.values();
            let want_votes = want_probs || weights.is_some() || values.is_some();
            if deadline.is_some_and(|d| Instant::now() >= d) {
                return Err(Error::DeadlineExceeded(
                    "request expired before evaluation".into(),
                ));
            }
            let model_key = version.id.to_string();
            let mut last_err = None;
            for kind in self.candidates(&version, primary, &model_key) {
                match self.eval_batch(&version, kind, rows, want_steps, want_votes, deadline) {
                    Ok((mut classes, steps, votes)) => {
                        self.note_outcome(&model_key, kind, true);
                        let rerouted = (kind != primary).then_some(kind);
                        if rerouted.is_some() {
                            self.metrics.observe_degraded();
                        }
                        let mut row_values = None;
                        if let Some(v) = votes.as_deref() {
                            let k = version.schema.n_classes();
                            if let Some(w) = weights {
                                classes = v
                                    .chunks_exact(k)
                                    .map(|c| weighted_argmax(c, w) as u32)
                                    .collect();
                                self.metrics.observe_weighted_decisions(classes.len() as u64);
                            }
                            if let Some(vals) = values {
                                row_values = Some(
                                    v.chunks_exact(k)
                                        .map(|c| expected_value(c, vals))
                                        .collect::<Vec<f64>>(),
                                );
                                self.metrics
                                    .observe_regression_predictions(rows.n_rows() as u64);
                            }
                        }
                        let votes = if want_probs { votes } else { None };
                        return Ok((kind, classes, steps, votes, row_values, version, rerouted));
                    }
                    Err(
                        e @ (Error::DeadlineExceeded(_) | Error::InvalidArgument(_)),
                    ) => return Err(e),
                    Err(e) => {
                        if matches!(e, Error::EvalPanic { .. }) {
                            self.metrics.observe_eval_panic();
                        }
                        self.note_outcome(&model_key, kind, false);
                        last_err = Some(e);
                    }
                }
            }
            Err(last_err.unwrap_or_else(|| Error::Serve("no backend available".into())))
        })();
        match result {
            Ok((backend, classes, steps, votes, values, version, rerouted)) => {
                let elapsed = start.elapsed();
                self.metrics.observe(backend, elapsed);
                self.metrics.observe_batch(rows.n_rows());
                self.metrics.observe_batch_eval(elapsed);
                Ok(BatchRouted {
                    classes,
                    steps,
                    version,
                    rerouted,
                    votes,
                    values,
                })
            }
            Err(e) => {
                self.metrics.observe_error();
                Err(e)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Engine;

    fn router() -> (crate::data::Dataset, Router) {
        let ds = crate::data::datasets::iris();
        let engine = Engine::builder()
            .dataset(ds.clone())
            .trees(12)
            .seed(2)
            .build()
            .unwrap();
        let r = Router::new(
            engine.registry().clone(),
            Arc::new(ServerMetrics::default()),
            BackendKind::Dd,
            BatcherConfig::default(),
            Duration::from_secs(5),
            BreakerBoard::new(3, Duration::from_millis(100)),
        );
        (ds, r)
    }

    #[test]
    fn native_backends_agree() {
        let (ds, r) = router();
        for i in (0..ds.n_rows()).step_by(11) {
            let via_dd = r
                .classify(&ClassifyRequest::new(ds.row(i).to_vec()).on_backend(BackendKind::Dd))
                .unwrap();
            let via_rf = r
                .classify(
                    &ClassifyRequest::new(ds.row(i).to_vec()).on_backend(BackendKind::Forest),
                )
                .unwrap();
            assert_eq!(via_dd.class, via_rf.class, "row {i}");
            assert!(via_dd.steps.unwrap() < via_rf.steps.unwrap());
            assert_eq!(via_dd.model, "default@v1");
        }
    }

    #[test]
    fn default_backend_applies() {
        let (ds, r) = router();
        let resp = r.classify(&ClassifyRequest::new(ds.row(0).to_vec())).unwrap();
        assert_eq!(resp.backend, BackendKind::Dd);
        assert!(!resp.label.is_empty());
    }

    #[test]
    fn bad_rows_rejected_and_counted() {
        let (_, r) = router();
        let err = r.classify(&ClassifyRequest::new(vec![1.0])).unwrap_err();
        assert!(err.to_string().contains("features"));
        assert_eq!(
            r.metrics().errors.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn xla_without_engine_fails_cleanly() {
        let (ds, r) = router();
        let err = r
            .classify(&ClassifyRequest::new(ds.row(0).to_vec()).on_backend(BackendKind::Xla))
            .unwrap_err();
        assert!(err.to_string().contains("not available"));
    }

    #[test]
    fn unknown_model_fails_cleanly() {
        let (ds, r) = router();
        let err = r
            .classify(&ClassifyRequest::new(ds.row(0).to_vec()).on_model("nope"))
            .unwrap_err();
        assert!(err.to_string().contains("unknown model"));
    }

    #[test]
    fn batch_endpoint_native() {
        let (ds, r) = router();
        let mut buf = RowMatrixBuf::with_capacity(ds.n_features(), 30);
        for i in 0..30 {
            buf.push_row(ds.row(i * 5)).unwrap();
        }
        let rows = buf.as_matrix();
        let dd = r
            .classify_batch(rows, Some(BackendKind::Dd), None, false, false)
            .unwrap();
        assert!(dd.steps.is_none(), "steps only on request");
        assert!(dd.rerouted.is_none(), "healthy path never reroutes");
        assert!(dd.votes.is_none(), "votes only on request");
        let rf = r
            .classify_batch(rows, Some(BackendKind::Forest), None, false, false)
            .unwrap();
        let frozen = r
            .classify_batch(rows, Some(BackendKind::Frozen), None, true, false)
            .unwrap();
        assert_eq!(dd.classes, rf.classes);
        assert_eq!(dd.classes, frozen.classes);
        assert_eq!(dd.classes.len(), 30);
        assert_eq!(dd.version.id.to_string(), "default@v1");
        // §6 metering survives the explicit-batch path, row for row
        let frozen_steps = frozen.steps.expect("frozen walks are metered");
        for (i, row) in rows.iter().enumerate() {
            let single = r
                .classify(
                    &ClassifyRequest::new(row.to_vec()).on_backend(BackendKind::Frozen),
                )
                .unwrap();
            assert_eq!(frozen_steps[i] as usize, single.steps.unwrap(), "row {i}");
        }
        // batch sizes and eval time land in the histograms
        assert!(r.metrics().batch_size.count() >= 3);
        assert!(r.metrics().batch_eval_us.count() >= 3);
    }

    #[test]
    fn untagged_requests_fall_back_to_the_model_default_backend() {
        let (ds, r) = router();
        // a forest-only model lacks the router-wide default backend (dd)
        crate::engine::register_forest(
            r.registry(),
            "baseline",
            crate::forest::ForestLearner::default().trees(4).seed(1).fit(&ds),
        )
        .unwrap();
        let resp = r
            .classify(&ClassifyRequest::new(ds.row(0).to_vec()).on_model("baseline"))
            .unwrap();
        assert_eq!(resp.backend, BackendKind::Forest);
        // an explicit override still errors cleanly
        let err = r
            .classify(
                &ClassifyRequest::new(ds.row(0).to_vec())
                    .on_model("baseline")
                    .on_backend(BackendKind::Dd),
            )
            .unwrap_err();
        assert!(err.to_string().contains("not available"));
    }

    #[test]
    fn per_request_model_selection_and_hot_swap() {
        let (ds, r) = router();
        // register a second, smaller model under another name
        let engine = Engine::with_registry(r.registry().clone());
        engine
            .train_and_register(
                "canary",
                &ds,
                4,
                0,
                9,
                crate::compile::CompileOptions::default(),
            )
            .unwrap();
        let resp = r
            .classify(&ClassifyRequest::new(ds.row(0).to_vec()).on_model("canary"))
            .unwrap();
        assert_eq!(resp.model, "canary@v1");
        // hot-swap the canary; the next request sees v2 without rebuilding
        // the router
        engine
            .train_and_register(
                "canary",
                &ds,
                6,
                0,
                10,
                crate::compile::CompileOptions::default(),
            )
            .unwrap();
        let resp = r
            .classify(&ClassifyRequest::new(ds.row(0).to_vec()).on_model("canary"))
            .unwrap();
        assert_eq!(resp.model, "canary@v2");
    }

    #[test]
    fn metrics_observe_served_requests() {
        let (ds, r) = router();
        for i in 0..5 {
            r.classify(&ClassifyRequest::new(ds.row(i).to_vec()).on_backend(BackendKind::Dd))
                .unwrap();
        }
        assert_eq!(r.metrics().backend(BackendKind::Dd).count(), 5);
    }

    #[test]
    fn breaker_reroutes_along_the_bit_identical_chain() {
        let (ds, r) = router();
        let row = ds.row(0).to_vec();
        let healthy = r
            .classify(&ClassifyRequest::new(row.clone()).on_backend(BackendKind::Dd))
            .unwrap();
        assert!(healthy.served_by.is_none());
        // trip dd's breaker (threshold 3 on the test board)
        for _ in 0..3 {
            r.breakers().record_failure("default@v1", BackendKind::Dd);
        }
        assert_eq!(r.breakers().open_count(), 1);
        let degraded = r.classify(&ClassifyRequest::new(row.clone())).unwrap();
        assert_eq!(degraded.backend, BackendKind::Frozen, "next in the chain");
        assert_eq!(degraded.served_by, Some(BackendKind::Frozen));
        assert_eq!(degraded.class, healthy.class, "the reroute is bit-identical");
        assert_eq!(
            r.metrics()
                .degraded_requests
                .load(std::sync::atomic::Ordering::Relaxed),
            1
        );
        // dd stays open until its cooldown admits a probe…
        assert_eq!(r.breakers().open_count(), 1);
        std::thread::sleep(Duration::from_millis(150));
        // …whose success re-closes the breaker and restores the primary
        let recovered = r.classify(&ClassifyRequest::new(row)).unwrap();
        assert_eq!(recovered.backend, BackendKind::Dd);
        assert!(recovered.served_by.is_none());
        assert_eq!(r.breakers().open_count(), 0);
    }

    #[test]
    fn probs_ride_vote_preserving_backends() {
        let (ds, r) = router();
        let resp = r
            .classify(
                &ClassifyRequest::new(ds.row(0).to_vec())
                    .on_backend(BackendKind::Forest)
                    .with_probs(),
            )
            .unwrap();
        let votes = resp.votes.as_ref().unwrap();
        assert_eq!(votes.iter().sum::<u32>(), 12, "one vote per tree");
        let probs = resp.probs.as_ref().unwrap();
        assert!((probs.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert_eq!(argmax(votes) as u32, resp.class);
        assert!(resp.value.is_none(), "classification models have no value");
        assert_eq!(
            r.metrics()
                .prob_requests
                .load(std::sync::atomic::Ordering::Relaxed),
            1
        );
        // without the flag, the wire stays lean
        let plain = r
            .classify(&ClassifyRequest::new(ds.row(0).to_vec()).on_backend(BackendKind::Forest))
            .unwrap();
        assert!(plain.votes.is_none() && plain.probs.is_none());
    }

    #[test]
    fn majority_backends_reject_probs_without_tripping_breakers() {
        // the default compile abstraction (majority) folds votes away at
        // compile time — asking it for a distribution is a client error,
        // not a backend fault
        let (ds, r) = router();
        let err = r
            .classify(
                &ClassifyRequest::new(ds.row(0).to_vec())
                    .on_backend(BackendKind::Dd)
                    .with_probs(),
            )
            .unwrap_err();
        assert!(matches!(err, Error::InvalidArgument(_)), "{err}");
        assert!(err.to_string().contains("vote"), "{err}");
        assert_eq!(r.breakers().open_count(), 0, "capability gaps never trip");
        // plain classification on the same backend is untouched
        assert!(r
            .classify(&ClassifyRequest::new(ds.row(0).to_vec()).on_backend(BackendKind::Dd))
            .is_ok());
    }

    #[test]
    fn class_weights_rerank_decisions() {
        let (ds, r) = router();
        // find a row that splits the forest, so a weight can flip it
        let mut split = None;
        for i in 0..ds.n_rows() {
            let resp = r
                .classify(
                    &ClassifyRequest::new(ds.row(i).to_vec())
                        .on_backend(BackendKind::Forest)
                        .with_probs(),
                )
                .unwrap();
            let votes = resp.votes.clone().unwrap();
            if votes.iter().filter(|&&v| v > 0).count() >= 2 {
                split = Some((i, resp.class as usize, votes));
                break;
            }
        }
        let (i, base, votes) = split.expect("some iris row splits a 12-tree forest");
        let runner = (0..votes.len())
            .filter(|&c| c != base)
            .max_by_key(|&c| votes[c])
            .unwrap();
        // weight the runner-up heavily enough that its (non-zero) votes
        // outscore the raw winner's
        let mut weights = vec![1.0f32; votes.len()];
        weights[runner] = votes[base] as f32 + 1.0;
        let weighted = Router::new(
            r.registry().clone(),
            Arc::new(ServerMetrics::default()),
            BackendKind::Forest,
            BatcherConfig::default(),
            Duration::from_secs(5),
            BreakerBoard::new(3, Duration::from_millis(100)),
        )
        .with_class_weights(weights);
        let resp = weighted
            .classify(&ClassifyRequest::new(ds.row(i).to_vec()))
            .unwrap();
        assert_eq!(resp.class as usize, runner, "the weight flips the decision");
        assert!(resp.votes.is_none(), "votes still only ship on request");
        assert_eq!(
            weighted
                .metrics()
                .weighted_decisions
                .load(std::sync::atomic::Ordering::Relaxed),
            1
        );
        // reported probabilities stay the raw vote fractions
        let with_probs = weighted
            .classify(&ClassifyRequest::new(ds.row(i).to_vec()).with_probs())
            .unwrap();
        let probs = with_probs.probs.unwrap();
        assert!(probs[base] > probs[runner], "weights re-rank, not re-weight");
        // a weight vector of the wrong arity is a client error
        let bad = Router::new(
            r.registry().clone(),
            Arc::new(ServerMetrics::default()),
            BackendKind::Forest,
            BatcherConfig::default(),
            Duration::from_secs(5),
            BreakerBoard::new(3, Duration::from_millis(100)),
        )
        .with_class_weights(vec![1.0, 2.0]);
        let err = bad
            .classify(&ClassifyRequest::new(ds.row(0).to_vec()))
            .unwrap_err();
        assert!(matches!(err, Error::InvalidArgument(_)), "{err}");
        assert!(err.to_string().contains("classes"), "{err}");
    }

    #[test]
    fn regression_models_serve_values() {
        let (_, r) = router();
        let spec = crate::data::synth::RegressionSpec {
            rows: 120,
            bins: 6,
            ..Default::default()
        };
        let ds = crate::data::synth::regression(&spec).unwrap();
        crate::engine::register_forest(
            r.registry(),
            "reg",
            crate::forest::ForestLearner::default().trees(5).seed(3).fit(&ds),
        )
        .unwrap();
        let resp = r
            .classify(&ClassifyRequest::new(ds.row(0).to_vec()).on_model("reg"))
            .unwrap();
        let value = resp.value.expect("regression models always report a value");
        assert!(value.is_finite());
        assert!(resp.votes.is_none() && resp.probs.is_none());
        // the batch path reports the same per-row means
        let mut buf = RowMatrixBuf::with_capacity(ds.n_features(), 8);
        for i in 0..8 {
            buf.push_row(ds.row(i)).unwrap();
        }
        let batch = r
            .classify_batch(buf.as_matrix(), None, Some("reg"), false, true)
            .unwrap();
        let values = batch.values.expect("regression batches carry values");
        assert_eq!(values.len(), 8);
        assert!((values[0] - value).abs() < 1e-12, "batch matches single");
        let votes = batch.votes.expect("probs were requested");
        assert_eq!(votes.len(), 8 * 6);
        for (i, chunk) in votes.chunks_exact(6).enumerate() {
            assert_eq!(argmax(chunk) as u32, batch.classes[i], "row {i}");
        }
        assert_eq!(
            r.metrics()
                .regression_predictions
                .load(std::sync::atomic::Ordering::Relaxed),
            1 + 8
        );
    }

    #[test]
    fn batch_probs_match_single_requests() {
        let (ds, r) = router();
        let mut buf = RowMatrixBuf::with_capacity(ds.n_features(), 10);
        for i in 0..10 {
            buf.push_row(ds.row(i * 7)).unwrap();
        }
        let rows = buf.as_matrix();
        let batch = r
            .classify_batch(rows, Some(BackendKind::Forest), None, false, true)
            .unwrap();
        let votes = batch.votes.as_ref().unwrap();
        assert_eq!(votes.len(), 10 * 3);
        assert!(batch.values.is_none(), "classification has no value table");
        for (i, chunk) in votes.chunks_exact(3).enumerate() {
            let single = r
                .classify(
                    &ClassifyRequest::new(ds.row(i * 7).to_vec())
                        .on_backend(BackendKind::Forest)
                        .with_probs(),
                )
                .unwrap();
            assert_eq!(single.votes.as_deref(), Some(chunk), "row {i}");
            assert_eq!(batch.classes[i], single.class, "row {i}");
        }
        let plain = r
            .classify_batch(rows, Some(BackendKind::Forest), None, false, false)
            .unwrap();
        assert!(plain.votes.is_none());
        assert_eq!(plain.classes, batch.classes);
    }

    #[test]
    fn expired_deadlines_fail_fast_with_a_deadline_error() {
        let (ds, r) = router();
        crate::obs::trace::set_eval_deadline(Some(Instant::now() - Duration::from_millis(5)));
        let err = r
            .classify(&ClassifyRequest::new(ds.row(0).to_vec()))
            .unwrap_err();
        assert!(matches!(err, Error::DeadlineExceeded(_)), "{err}");
        // the explicit batch path enforces the same budget
        let mut buf = RowMatrixBuf::with_capacity(ds.n_features(), 1);
        buf.push_row(ds.row(0)).unwrap();
        let err = r
            .classify_batch(buf.as_matrix(), None, None, false, false)
            .unwrap_err();
        assert!(matches!(err, Error::DeadlineExceeded(_)), "{err}");
        // clearing the deadline restores service on this thread
        crate::obs::trace::set_eval_deadline(None);
        assert!(r
            .classify(&ClassifyRequest::new(ds.row(0).to_vec()))
            .is_ok());
    }
}
