//! Request router: resolves `(model, backend)` to a [`Classifier`] trait
//! object in the shared [`ModelRegistry`] and dispatches.
//!
//! Backends that advertise a batch-oriented cost model
//! (`preferred_batch > 1`, i.e. the XLA engine) have single requests
//! coalesced through the dynamic batcher, which groups concurrent
//! traffic per classifier instance and executes one fused
//! `classify_batch` per group; single-row walkers (`forest`/`dd`) are
//! served inline. Explicit batch requests bypass the batcher and go
//! straight to the backend's batch path.
//!
//! The router holds no model state of its own: a hot-swap in the
//! registry is visible to the very next request, while requests already
//! dispatched finish against the version they resolved (RCU via `Arc`).

use crate::batch::{RowMatrix, RowMatrixBuf};
use crate::classifier::Classifier;
use crate::engine::ModelRegistry;
use crate::error::{Error, Result};
use crate::serve::batcher::{Batcher, BatcherConfig};
use crate::serve::metrics::ServerMetrics;
use crate::serve::{BackendKind, ClassifyRequest, ClassifyResponse};
use std::sync::mpsc::Sender;
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

/// A coalesced single-request job: the resolved classifier, the feature
/// row (moved, never copied, on the hot path), and the reply channel.
type BatchJob = (Arc<dyn Classifier>, Vec<f32>, Sender<Result<u32>>);

/// The serving router (shared across HTTP workers).
pub struct Router {
    registry: Arc<ModelRegistry>,
    metrics: Arc<ServerMetrics>,
    default_backend: BackendKind,
    /// Started lazily on the first batch-first dispatch: a build without
    /// any batch-native backend (e.g. against the offline `xla` stub)
    /// never pays the batcher thread or its queue.
    batcher: OnceLock<Batcher<BatchJob>>,
    batch_cfg: BatcherConfig,
    reply_timeout: Duration,
}

/// Batcher worker: groups a window's jobs per classifier instance
/// (several models/versions may interleave), packs each group's rows
/// into one flat matrix, and runs one fused `classify_batch` per group.
fn start_batcher(metrics: Arc<ServerMetrics>, cfg: BatcherConfig) -> Batcher<BatchJob> {
    Batcher::start("router", cfg, move |jobs: Vec<BatchJob>| {
        metrics.batch_dequeued(jobs.len() as u64);
        metrics.observe_batch(jobs.len());
        let eval_start = Instant::now();
        let mut jobs = jobs;
        while !jobs.is_empty() {
            let clf = jobs[0].0.clone();
            let (group, rest): (Vec<BatchJob>, Vec<BatchJob>) = jobs
                .into_iter()
                .partition(|(c, _, _)| Arc::ptr_eq(c, &clf));
            jobs = rest;
            // Rows of one group share the model's arity (enforced by
            // `check_row` before submission), so they pack into one flat
            // matrix — a contiguous copy each, no per-row Vec downstream.
            let mut rows = RowMatrixBuf::with_capacity(group[0].1.len(), group.len());
            let mut replies = Vec::with_capacity(group.len());
            let mut pack_err = None;
            for (_, row, reply) in group {
                if pack_err.is_none() {
                    if let Err(e) = rows.push_row(&row) {
                        pack_err = Some(e.to_string());
                    }
                }
                replies.push(reply);
            }
            let result = match pack_err {
                Some(msg) => Err(Error::Serve(msg)),
                None => clf.classify_batch(rows.as_matrix()),
            };
            match result {
                Ok(classes) => {
                    for (reply, class) in replies.into_iter().zip(classes) {
                        let _ = reply.send(Ok(class));
                    }
                }
                Err(e) => {
                    let msg = e.to_string();
                    for reply in replies {
                        let _ = reply.send(Err(Error::Serve(msg.clone())));
                    }
                }
            }
        }
        metrics.observe_batch_eval(eval_start.elapsed());
    })
}

impl Router {
    /// Build a router over a model registry. `reply_timeout` bounds how
    /// long a coalesced request waits for its batch to execute
    /// (configurable via `serve::config::ServeConfig::reply_timeout_ms`).
    pub fn new(
        registry: Arc<ModelRegistry>,
        metrics: Arc<ServerMetrics>,
        default_backend: BackendKind,
        batch_cfg: BatcherConfig,
        reply_timeout: Duration,
    ) -> Router {
        Router {
            registry,
            metrics,
            default_backend,
            batcher: OnceLock::new(),
            batch_cfg,
            reply_timeout,
        }
    }

    fn batcher(&self) -> &Batcher<BatchJob> {
        self.batcher
            .get_or_init(|| start_batcher(self.metrics.clone(), self.batch_cfg.clone()))
    }

    /// The model registry served by this router.
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// The metrics registry.
    pub fn metrics(&self) -> &Arc<ServerMetrics> {
        &self.metrics
    }

    /// Default backend for requests without an override.
    pub fn default_backend(&self) -> BackendKind {
        self.default_backend
    }

    /// True when the default model has the XLA backend loaded.
    pub fn has_xla(&self) -> bool {
        self.registry
            .get(None)
            .map(|v| v.has(BackendKind::Xla))
            .unwrap_or(false)
    }

    /// Pick the backend for a request. An explicit backend override wins
    /// (and errors if the model lacks it). Otherwise the router-wide
    /// default applies when the resolved model has it, falling back to
    /// the model's own default backend when it doesn't — uniformly for
    /// tagged and untagged traffic, so a forest-only model serves either
    /// way. Deploy-time misconfiguration (e.g. `--backend xla` with
    /// broken artifacts) is surfaced by the startup warning and the
    /// `/model` endpoint's `xla_loaded`/`default_backend` fields, not by
    /// per-request failures.
    fn pick_backend(
        &self,
        version: &crate::engine::ModelVersion,
        requested: Option<BackendKind>,
    ) -> BackendKind {
        match requested {
            Some(kind) => kind,
            None if version.has(self.default_backend) => self.default_backend,
            None => version.default_backend,
        }
    }

    /// Serve one classification request.
    pub fn classify(&self, req: &ClassifyRequest) -> Result<ClassifyResponse> {
        let start = Instant::now();
        match self.dispatch(req.model.as_deref(), req.backend, &req.features) {
            Ok((backend, model, class, steps, label)) => {
                let latency = start.elapsed();
                self.metrics.observe(backend, latency);
                Ok(ClassifyResponse {
                    class,
                    label,
                    backend,
                    model,
                    steps,
                    latency_us: latency.as_micros() as u64,
                })
            }
            Err(e) => {
                self.metrics.observe_error();
                Err(e)
            }
        }
    }

    fn dispatch(
        &self,
        model: Option<&str>,
        requested: Option<BackendKind>,
        features: &[f32],
    ) -> Result<(BackendKind, String, u32, Option<usize>, String)> {
        let version = self.registry.get(model)?;
        let backend = self.pick_backend(&version, requested);
        let slot = version.slot(backend)?.clone();
        version.check_row(features)?;
        let (class, steps) = if slot.batch_first {
            let (tx, rx) = std::sync::mpsc::channel();
            // depth gauge brackets the submit: a rejected job never counts
            self.metrics.batch_enqueued();
            if let Err(e) = self
                .batcher()
                .submit((slot.classifier.clone(), features.to_vec(), tx))
            {
                self.metrics.batch_dequeued(1);
                return Err(e);
            }
            let class = rx
                .recv_timeout(self.reply_timeout)
                .map_err(|_| Error::Serve("batched backend reply timed out".into()))??;
            (class, None)
        } else {
            slot.classifier.classify_with_steps(features)?
        };
        Ok((
            backend,
            version.id.to_string(),
            class,
            steps,
            version.label_of(class),
        ))
    }

    /// Serve an explicit flat batch (bypasses the single-request batcher
    /// and uses the backend's native batch path directly). With
    /// `want_steps`, metered backends also return the §6 step count per
    /// row (`None` for backends that cannot meter, e.g. XLA) — the batch
    /// counterpart of the single-request `steps` field. Returns the
    /// classes (+ steps) plus the model version that served them, so
    /// callers render labels against the exact version that classified
    /// (not a later hot-swap).
    pub fn classify_batch(
        &self,
        rows: RowMatrix<'_>,
        backend: Option<BackendKind>,
        model: Option<&str>,
        want_steps: bool,
    ) -> Result<(Vec<u32>, Option<Vec<u32>>, Arc<crate::engine::ModelVersion>)> {
        let start = Instant::now();
        let result = (|| {
            let version = self.registry.get(model)?;
            let backend = self.pick_backend(&version, backend);
            let slot = version.slot(backend)?.clone();
            version.check_matrix(rows)?;
            let (classes, steps) = if want_steps {
                slot.classifier.classify_batch_with_steps(rows)?
            } else {
                (slot.classifier.classify_batch(rows)?, None)
            };
            Ok((backend, classes, steps, version))
        })();
        match result {
            Ok((backend, out, steps, version)) => {
                let elapsed = start.elapsed();
                self.metrics.observe(backend, elapsed);
                self.metrics.observe_batch(rows.n_rows());
                self.metrics.observe_batch_eval(elapsed);
                Ok((out, steps, version))
            }
            Err(e) => {
                self.metrics.observe_error();
                Err(e)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Engine;

    fn router() -> (crate::data::Dataset, Router) {
        let ds = crate::data::datasets::iris();
        let engine = Engine::builder()
            .dataset(ds.clone())
            .trees(12)
            .seed(2)
            .build()
            .unwrap();
        let r = Router::new(
            engine.registry().clone(),
            Arc::new(ServerMetrics::default()),
            BackendKind::Dd,
            BatcherConfig::default(),
            Duration::from_secs(5),
        );
        (ds, r)
    }

    #[test]
    fn native_backends_agree() {
        let (ds, r) = router();
        for i in (0..ds.n_rows()).step_by(11) {
            let via_dd = r
                .classify(&ClassifyRequest::new(ds.row(i).to_vec()).on_backend(BackendKind::Dd))
                .unwrap();
            let via_rf = r
                .classify(
                    &ClassifyRequest::new(ds.row(i).to_vec()).on_backend(BackendKind::Forest),
                )
                .unwrap();
            assert_eq!(via_dd.class, via_rf.class, "row {i}");
            assert!(via_dd.steps.unwrap() < via_rf.steps.unwrap());
            assert_eq!(via_dd.model, "default@v1");
        }
    }

    #[test]
    fn default_backend_applies() {
        let (ds, r) = router();
        let resp = r.classify(&ClassifyRequest::new(ds.row(0).to_vec())).unwrap();
        assert_eq!(resp.backend, BackendKind::Dd);
        assert!(!resp.label.is_empty());
    }

    #[test]
    fn bad_rows_rejected_and_counted() {
        let (_, r) = router();
        let err = r.classify(&ClassifyRequest::new(vec![1.0])).unwrap_err();
        assert!(err.to_string().contains("features"));
        assert_eq!(
            r.metrics().errors.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn xla_without_engine_fails_cleanly() {
        let (ds, r) = router();
        let err = r
            .classify(&ClassifyRequest::new(ds.row(0).to_vec()).on_backend(BackendKind::Xla))
            .unwrap_err();
        assert!(err.to_string().contains("not available"));
    }

    #[test]
    fn unknown_model_fails_cleanly() {
        let (ds, r) = router();
        let err = r
            .classify(&ClassifyRequest::new(ds.row(0).to_vec()).on_model("nope"))
            .unwrap_err();
        assert!(err.to_string().contains("unknown model"));
    }

    #[test]
    fn batch_endpoint_native() {
        let (ds, r) = router();
        let mut buf = RowMatrixBuf::with_capacity(ds.n_features(), 30);
        for i in 0..30 {
            buf.push_row(ds.row(i * 5)).unwrap();
        }
        let rows = buf.as_matrix();
        let (dd, no_steps, version) = r
            .classify_batch(rows, Some(BackendKind::Dd), None, false)
            .unwrap();
        assert!(no_steps.is_none(), "steps only on request");
        let (rf, _, _) = r
            .classify_batch(rows, Some(BackendKind::Forest), None, false)
            .unwrap();
        let (frozen, frozen_steps, _) = r
            .classify_batch(rows, Some(BackendKind::Frozen), None, true)
            .unwrap();
        assert_eq!(dd, rf);
        assert_eq!(dd, frozen);
        assert_eq!(dd.len(), 30);
        assert_eq!(version.id.to_string(), "default@v1");
        // §6 metering survives the explicit-batch path, row for row
        let frozen_steps = frozen_steps.expect("frozen walks are metered");
        for (i, row) in rows.iter().enumerate() {
            let single = r
                .classify(
                    &ClassifyRequest::new(row.to_vec()).on_backend(BackendKind::Frozen),
                )
                .unwrap();
            assert_eq!(frozen_steps[i] as usize, single.steps.unwrap(), "row {i}");
        }
        // batch sizes and eval time land in the histograms
        assert!(r.metrics().batch_size.count() >= 3);
        assert!(r.metrics().batch_eval_us.count() >= 3);
    }

    #[test]
    fn untagged_requests_fall_back_to_the_model_default_backend() {
        let (ds, r) = router();
        // a forest-only model lacks the router-wide default backend (dd)
        crate::engine::register_forest(
            r.registry(),
            "baseline",
            crate::forest::ForestLearner::default().trees(4).seed(1).fit(&ds),
        )
        .unwrap();
        let resp = r
            .classify(&ClassifyRequest::new(ds.row(0).to_vec()).on_model("baseline"))
            .unwrap();
        assert_eq!(resp.backend, BackendKind::Forest);
        // an explicit override still errors cleanly
        let err = r
            .classify(
                &ClassifyRequest::new(ds.row(0).to_vec())
                    .on_model("baseline")
                    .on_backend(BackendKind::Dd),
            )
            .unwrap_err();
        assert!(err.to_string().contains("not available"));
    }

    #[test]
    fn per_request_model_selection_and_hot_swap() {
        let (ds, r) = router();
        // register a second, smaller model under another name
        let engine = Engine::with_registry(r.registry().clone());
        engine
            .train_and_register(
                "canary",
                &ds,
                4,
                0,
                9,
                crate::compile::CompileOptions::default(),
            )
            .unwrap();
        let resp = r
            .classify(&ClassifyRequest::new(ds.row(0).to_vec()).on_model("canary"))
            .unwrap();
        assert_eq!(resp.model, "canary@v1");
        // hot-swap the canary; the next request sees v2 without rebuilding
        // the router
        engine
            .train_and_register(
                "canary",
                &ds,
                6,
                0,
                10,
                crate::compile::CompileOptions::default(),
            )
            .unwrap();
        let resp = r
            .classify(&ClassifyRequest::new(ds.row(0).to_vec()).on_model("canary"))
            .unwrap();
        assert_eq!(resp.model, "canary@v2");
    }

    #[test]
    fn metrics_observe_served_requests() {
        let (ds, r) = router();
        for i in 0..5 {
            r.classify(&ClassifyRequest::new(ds.row(i).to_vec()).on_backend(BackendKind::Dd))
                .unwrap();
        }
        assert_eq!(r.metrics().backend(BackendKind::Dd).count(), 5);
    }
}
