//! Server configuration: JSON config file + programmatic defaults.

use crate::error::{Error, Result};
use crate::serve::BackendKind;
use crate::util::json::{self, Json};

/// Which serving front-end handles sockets (`serve --io`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum IoMode {
    /// Evented where a poller exists ([`crate::net::poll::supported`]:
    /// linux epoll, macos kqueue), sync thread-per-connection elsewhere.
    #[default]
    Auto,
    /// Force the sync thread-per-connection front-end.
    Sync,
    /// Force the evented front-end; startup fails where unsupported.
    Evented,
}

impl IoMode {
    /// Parse a config/CLI value.
    pub fn parse(s: &str) -> Result<IoMode> {
        match s {
            "auto" => Ok(IoMode::Auto),
            "sync" => Ok(IoMode::Sync),
            "evented" => Ok(IoMode::Evented),
            other => Err(Error::invalid(format!(
                "unknown io mode '{other}' (expected auto | sync | evented)"
            ))),
        }
    }

    /// Canonical name (inverse of [`parse`](Self::parse)).
    pub fn name(&self) -> &'static str {
        match self {
            IoMode::Auto => "auto",
            IoMode::Sync => "sync",
            IoMode::Evented => "evented",
        }
    }

    /// Resolve to a concrete choice: `Ok(true)` = evented, `Ok(false)` =
    /// sync; forcing `Evented` on a target without a poller is an error.
    pub fn resolve(&self) -> Result<bool> {
        match self {
            IoMode::Auto => Ok(crate::net::poll::supported()),
            IoMode::Sync => Ok(false),
            IoMode::Evented => {
                if crate::net::poll::supported() {
                    Ok(true)
                } else {
                    Err(Error::invalid(
                        "io_mode 'evented' needs epoll or kqueue, which this target lacks — use --io sync",
                    ))
                }
            }
        }
    }
}

/// Full configuration of `forest-add serve`.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address (`127.0.0.1:0` picks a free port).
    pub addr: String,
    /// `fdd` snapshot to serve (v1 or v2) (empty = train from `dataset` instead).
    /// When set, the replica skips training entirely and registers the
    /// frozen model as `default` — the millisecond startup path.
    pub snapshot: String,
    /// `fab-v1` multi-model bundle to serve (empty = none). One `mmap`
    /// boots every entry as a named frozen model (manifest names,
    /// per-request `model` routing, `GET /models` provenance); the first
    /// entry becomes the default model. Mutually exclusive with
    /// `snapshot`.
    pub bundle: String,
    /// Built-in dataset to train on (or a CSV/ARFF path).
    pub dataset: String,
    /// Forest size.
    pub trees: usize,
    /// Per-tree depth cap (`0` = unlimited; the XLA path needs a cap that
    /// fits the artifact depth).
    pub max_depth: usize,
    /// Training seed.
    pub seed: u64,
    /// Default backend for untagged requests.
    pub default_backend: BackendKind,
    /// Dynamic batcher: max items per batch.
    pub batch_max: usize,
    /// Dynamic batcher: max wait in milliseconds.
    pub batch_wait_ms: u64,
    /// How long a coalesced single request waits for its batch reply
    /// before timing out, in milliseconds.
    pub reply_timeout_ms: u64,
    /// HTTP worker threads (sync: connection handlers; evented: the
    /// request-handler pool behind the event loop).
    pub http_workers: usize,
    /// Serving front-end selection (see [`IoMode`]).
    pub io_mode: IoMode,
    /// Per-connection read/idle timeout in milliseconds. Sync mode: a
    /// blocked read past this closes the connection (a stalled client
    /// cannot pin a worker thread). Evented mode: connections idle this
    /// long are swept (`408` when stalled mid-request).
    pub read_timeout_ms: u64,
    /// Dynamic batcher queue depth before requests are shed with `429`
    /// (`0` = auto: `max(batch_max * 16, 256)`).
    pub batch_queue_cap: usize,
    /// Evented dispatch queue depth (parsed requests waiting for a
    /// worker) before admission control sheds with `429` (`0` = auto:
    /// `max(http_workers * 16, 128)`).
    pub dispatch_cap: usize,
    /// Evaluation parallelism for sharded batch classification (`0` =
    /// auto = [`std::thread::available_parallelism`]). The process-wide
    /// worker pool is sized once at startup.
    pub eval_threads: usize,
    /// LLC budget of the frozen backend's cache-tiled batch sweep, in
    /// bytes (`0` = auto, currently 4 MiB). Diagrams whose hot node
    /// planes exceed the budget are swept in topological tiles of this
    /// size so parked rows stay cache-resident.
    pub tile_bytes: usize,
    /// Use the explicit-SIMD batch kernels where the host supports them
    /// (`false` / `serve --no-simd` forces the scalar walk; the
    /// `FOREST_ADD_NO_SIMD` env var wins over both). Every kernel is
    /// bit-identical to the scalar walk — this is a perf/debug knob, not
    /// an accuracy trade.
    pub simd: bool,
    /// Artifacts directory (XLA path).
    pub artifacts_dir: String,
    /// Artifact variant to load.
    pub variant: String,
    /// Load the XLA backend at startup.
    pub enable_xla: bool,
    /// Minimum log level emitted to stderr (`error` | `warn` | `info` |
    /// `debug` | `trace`). The `FOREST_ADD_LOG` env var overrides.
    pub log_level: String,
    /// Emit log records as JSON lines instead of human-readable text.
    pub log_json: bool,
    /// Per-connection in-flight request cap: a pipelining client with
    /// more than this many parsed-but-unanswered requests on one
    /// connection gets `429` + `Retry-After` for the excess, before the
    /// global dispatch queue is touched (one greedy connection cannot
    /// starve the rest). `0` = unlimited (the default: the global queue
    /// caps alone apply).
    pub conn_max_inflight: usize,
    /// Eval failures (errors or quarantined panics) within the breaker's
    /// 10 s sliding window that open a `(model, backend)` circuit
    /// breaker. While open, requests are transparently served by the
    /// next backend in the bit-identical chain `frozen → dd → forest`
    /// (`X-Served-By` announces the reroute). `0` disables breakers.
    pub breaker_threshold: usize,
    /// How long an open breaker waits before admitting a half-open
    /// probe request whose success re-closes it, in milliseconds.
    pub breaker_cooldown_ms: u64,
    /// Per-class decision weights for imbalanced data: the served
    /// decision becomes `argmax_c votes_c · weights_c`
    /// ([`crate::add::terminal::weighted_argmax`]) instead of the plain
    /// majority. One entry per class, each finite and positive; empty =
    /// unweighted. The weights re-rank the *decision* only — reported
    /// probabilities stay the raw vote fractions — and apply to every
    /// backend identically, because they post-map the same vote vector.
    /// Requires a vote-preserving model (word or vector abstraction).
    pub class_weights: Vec<f32>,
    /// Deterministic fault-injection spec, `point:rate:seed` entries
    /// separated by commas (e.g. `eval_shard_panic:0.05:42`); empty =
    /// disarmed. Points: `snapshot_load`, `eval_shard_panic`,
    /// `eval_slow`, `conn_read_err`, `conn_write_short`. The
    /// `FOREST_ADD_FAULT` env var arms additional points at startup.
    /// Same spec + same request sequence = same faults (seeded,
    /// counter-stepped draws) — the chaos harness, not a prod knob.
    pub fault: String,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7878".into(),
            snapshot: String::new(),
            bundle: String::new(),
            dataset: "iris".into(),
            trees: 128,
            max_depth: 8,
            seed: 42,
            default_backend: BackendKind::Dd,
            batch_max: 64,
            batch_wait_ms: 2,
            reply_timeout_ms: 5_000,
            http_workers: 4,
            io_mode: IoMode::Auto,
            read_timeout_ms: 10_000,
            batch_queue_cap: 0,
            dispatch_cap: 0,
            eval_threads: 0,
            tile_bytes: 0,
            simd: true,
            artifacts_dir: "artifacts".into(),
            variant: "base".into(),
            enable_xla: true,
            log_level: "info".into(),
            log_json: false,
            conn_max_inflight: 0,
            breaker_threshold: 3,
            breaker_cooldown_ms: 1_000,
            class_weights: Vec::new(),
            fault: String::new(),
        }
    }
}

impl ServeConfig {
    /// Parse from a JSON document; absent fields keep their defaults.
    pub fn from_json(v: &Json) -> Result<ServeConfig> {
        let mut cfg = ServeConfig::default();
        if let Some(s) = v.get_str("addr") {
            cfg.addr = s.to_string();
        }
        if let Some(s) = v.get_str("snapshot") {
            cfg.snapshot = s.to_string();
        }
        if let Some(s) = v.get_str("bundle") {
            cfg.bundle = s.to_string();
        }
        if let Some(s) = v.get_str("dataset") {
            cfg.dataset = s.to_string();
        }
        if let Some(n) = v.get_i64("trees") {
            cfg.trees = n as usize;
        }
        if let Some(n) = v.get_i64("max_depth") {
            cfg.max_depth = n as usize;
        }
        if let Some(n) = v.get_i64("seed") {
            cfg.seed = n as u64;
        }
        if let Some(s) = v.get_str("default_backend") {
            cfg.default_backend = BackendKind::parse(s)?;
        }
        if let Some(n) = v.get_i64("batch_max") {
            cfg.batch_max = n as usize;
        }
        if let Some(n) = v.get_i64("batch_wait_ms") {
            cfg.batch_wait_ms = n as u64;
        }
        if let Some(n) = v.get_i64("reply_timeout_ms") {
            cfg.reply_timeout_ms = n as u64;
        }
        if let Some(n) = v.get_i64("http_workers") {
            cfg.http_workers = n as usize;
        }
        if let Some(s) = v.get_str("io_mode") {
            cfg.io_mode = IoMode::parse(s)?;
        }
        if let Some(n) = v.get_i64("read_timeout_ms") {
            cfg.read_timeout_ms = n as u64;
        }
        if let Some(n) = v.get_i64("batch_queue_cap") {
            cfg.batch_queue_cap = n as usize;
        }
        if let Some(n) = v.get_i64("dispatch_cap") {
            cfg.dispatch_cap = n as usize;
        }
        if let Some(n) = v.get_i64("eval_threads") {
            cfg.eval_threads = n as usize;
        }
        if let Some(n) = v.get_i64("tile_bytes") {
            cfg.tile_bytes = n as usize;
        }
        if let Some(b) = v.get("simd").and_then(Json::as_bool) {
            cfg.simd = b;
        }
        if let Some(s) = v.get_str("artifacts_dir") {
            cfg.artifacts_dir = s.to_string();
        }
        if let Some(s) = v.get_str("variant") {
            cfg.variant = s.to_string();
        }
        if let Some(b) = v.get("enable_xla").and_then(Json::as_bool) {
            cfg.enable_xla = b;
        }
        if let Some(s) = v.get_str("log_level") {
            cfg.log_level = s.to_string();
        }
        if let Some(b) = v.get("log_json").and_then(Json::as_bool) {
            cfg.log_json = b;
        }
        if let Some(n) = v.get_i64("conn_max_inflight") {
            cfg.conn_max_inflight = n as usize;
        }
        if let Some(n) = v.get_i64("breaker_threshold") {
            cfg.breaker_threshold = n as usize;
        }
        if let Some(n) = v.get_i64("breaker_cooldown_ms") {
            cfg.breaker_cooldown_ms = n as u64;
        }
        if let Some(arr) = v.get("class_weights").and_then(Json::as_arr) {
            cfg.class_weights = arr
                .iter()
                .map(|w| w.as_f64().map(|x| x as f32))
                .collect::<Option<_>>()
                .ok_or_else(|| Error::parse("class_weights entries must be numbers"))?;
        }
        if let Some(s) = v.get_str("fault") {
            cfg.fault = s.to_string();
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a JSON file.
    pub fn load(path: &str) -> Result<ServeConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Sanity-check field combinations.
    pub fn validate(&self) -> Result<()> {
        if self.trees == 0 {
            return Err(Error::invalid("trees must be positive"));
        }
        if !self.snapshot.is_empty() && !self.bundle.is_empty() {
            return Err(Error::invalid(
                "snapshot and bundle are mutually exclusive (a bundle already carries its models)",
            ));
        }
        if self.batch_max == 0 {
            return Err(Error::invalid("batch_max must be positive"));
        }
        if self.http_workers == 0 {
            return Err(Error::invalid("http_workers must be positive"));
        }
        if self.reply_timeout_ms == 0 {
            return Err(Error::invalid("reply_timeout_ms must be positive"));
        }
        if self.read_timeout_ms == 0 {
            return Err(Error::invalid(
                "read_timeout_ms must be positive (a connection must not block forever)",
            ));
        }
        // Wrap defence, as for eval_threads below: a negative JSON value
        // would otherwise become an effectively unbounded queue.
        if self.batch_queue_cap > (1 << 24) {
            return Err(Error::invalid(
                "batch_queue_cap must be at most 2^24 (0 = auto)",
            ));
        }
        if self.dispatch_cap > (1 << 24) {
            return Err(Error::invalid(
                "dispatch_cap must be at most 2^24 (0 = auto)",
            ));
        }
        // Negative JSON values wrap to huge usizes; either way a thread
        // count past this bound is a misconfiguration, not a pool size.
        if self.eval_threads > 1024 {
            return Err(Error::invalid(
                "eval_threads must be at most 1024 (0 = all cores)",
            ));
        }
        // Same wrap defence: no real LLC exceeds this, and a wrapped
        // negative would otherwise disable tiling silently.
        if self.tile_bytes > (1 << 30) {
            return Err(Error::invalid(
                "tile_bytes must be at most 1 GiB (0 = auto)",
            ));
        }
        // Wrap defence, as above: a negative JSON value must read as a
        // misconfiguration, not as "unlimited pipelining".
        if self.conn_max_inflight > (1 << 24) {
            return Err(Error::invalid(
                "conn_max_inflight must be at most 2^24 (0 = unlimited)",
            ));
        }
        if self.breaker_threshold > (1 << 24) {
            return Err(Error::invalid(
                "breaker_threshold must be at most 2^24 (0 = breakers disabled)",
            ));
        }
        if self.breaker_cooldown_ms == 0 {
            return Err(Error::invalid(
                "breaker_cooldown_ms must be positive (an open breaker needs a probe interval)",
            ));
        }
        // Length is checked against the model's class count at startup
        // (the config alone does not know |C|).
        if self.class_weights.iter().any(|w| !w.is_finite() || *w <= 0.0) {
            return Err(Error::invalid(
                "class_weights entries must be finite and positive",
            ));
        }
        if !self.fault.is_empty() {
            crate::runtime::fault::parse_spec(&self.fault).map_err(Error::invalid)?;
        }
        crate::obs::log::Level::parse(&self.log_level)?;
        Ok(())
    }

    /// Batcher queue depth with the `0 = auto` default applied.
    pub fn resolved_batch_queue_cap(&self) -> usize {
        if self.batch_queue_cap == 0 {
            (self.batch_max * 16).max(256)
        } else {
            self.batch_queue_cap
        }
    }

    /// Evented dispatch queue depth with the `0 = auto` default applied.
    pub fn resolved_dispatch_cap(&self) -> usize {
        if self.dispatch_cap == 0 {
            (self.http_workers * 16).max(128)
        } else {
            self.dispatch_cap
        }
    }

    /// Render to JSON (written by `forest-add serve --dump-config`).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("addr", json::s(self.addr.clone())),
            ("snapshot", json::s(self.snapshot.clone())),
            ("bundle", json::s(self.bundle.clone())),
            ("dataset", json::s(self.dataset.clone())),
            ("trees", json::num(self.trees as f64)),
            ("max_depth", json::num(self.max_depth as f64)),
            ("seed", json::num(self.seed as f64)),
            ("default_backend", json::s(self.default_backend.name())),
            ("batch_max", json::num(self.batch_max as f64)),
            ("batch_wait_ms", json::num(self.batch_wait_ms as f64)),
            ("reply_timeout_ms", json::num(self.reply_timeout_ms as f64)),
            ("http_workers", json::num(self.http_workers as f64)),
            ("io_mode", json::s(self.io_mode.name())),
            ("read_timeout_ms", json::num(self.read_timeout_ms as f64)),
            ("batch_queue_cap", json::num(self.batch_queue_cap as f64)),
            ("dispatch_cap", json::num(self.dispatch_cap as f64)),
            ("eval_threads", json::num(self.eval_threads as f64)),
            ("tile_bytes", json::num(self.tile_bytes as f64)),
            ("simd", Json::Bool(self.simd)),
            ("artifacts_dir", json::s(self.artifacts_dir.clone())),
            ("variant", json::s(self.variant.clone())),
            ("enable_xla", Json::Bool(self.enable_xla)),
            ("log_level", json::s(self.log_level.clone())),
            ("log_json", Json::Bool(self.log_json)),
            ("conn_max_inflight", json::num(self.conn_max_inflight as f64)),
            ("breaker_threshold", json::num(self.breaker_threshold as f64)),
            (
                "breaker_cooldown_ms",
                json::num(self.breaker_cooldown_ms as f64),
            ),
            (
                "class_weights",
                Json::Arr(
                    self.class_weights
                        .iter()
                        .map(|&w| json::num(w as f64))
                        .collect(),
                ),
            ),
            ("fault", json::s(self.fault.clone())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        ServeConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let cfg = ServeConfig {
            trees: 500,
            default_backend: BackendKind::Xla,
            enable_xla: false,
            reply_timeout_ms: 250,
            bundle: "fleet.fab".into(),
            eval_threads: 6,
            tile_bytes: 2 << 20,
            simd: false,
            io_mode: IoMode::Sync,
            read_timeout_ms: 750,
            batch_queue_cap: 32,
            dispatch_cap: 48,
            log_level: "debug".into(),
            log_json: true,
            conn_max_inflight: 12,
            breaker_threshold: 5,
            breaker_cooldown_ms: 250,
            class_weights: vec![1.0, 2.5, 0.5],
            fault: "eval_shard_panic:0.05:42,eval_slow:0.1:7".into(),
            ..Default::default()
        };
        let back = ServeConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.trees, 500);
        assert_eq!(back.default_backend, BackendKind::Xla);
        assert!(!back.enable_xla);
        assert_eq!(back.reply_timeout_ms, 250);
        assert_eq!(back.bundle, "fleet.fab");
        assert!(back.snapshot.is_empty());
        assert_eq!(back.eval_threads, 6);
        assert_eq!(back.tile_bytes, 2 << 20);
        assert!(!back.simd);
        assert_eq!(back.io_mode, IoMode::Sync);
        assert_eq!(back.read_timeout_ms, 750);
        assert_eq!(back.batch_queue_cap, 32);
        assert_eq!(back.dispatch_cap, 48);
        assert_eq!(back.log_level, "debug");
        assert!(back.log_json);
        assert_eq!(back.conn_max_inflight, 12);
        assert_eq!(back.breaker_threshold, 5);
        assert_eq!(back.breaker_cooldown_ms, 250);
        assert_eq!(back.class_weights, vec![1.0, 2.5, 0.5]);
        assert_eq!(back.fault, "eval_shard_panic:0.05:42,eval_slow:0.1:7");
    }

    #[test]
    fn io_mode_parses_and_resolves() {
        assert_eq!(IoMode::parse("auto").unwrap(), IoMode::Auto);
        assert_eq!(IoMode::parse("sync").unwrap(), IoMode::Sync);
        assert_eq!(IoMode::parse("evented").unwrap(), IoMode::Evented);
        assert!(IoMode::parse("tokio").is_err());
        for mode in [IoMode::Auto, IoMode::Sync, IoMode::Evented] {
            assert_eq!(IoMode::parse(mode.name()).unwrap(), mode);
        }
        // sync always resolves; auto follows the capability probe
        assert!(!IoMode::Sync.resolve().unwrap());
        assert_eq!(
            IoMode::Auto.resolve().unwrap(),
            crate::net::poll::supported()
        );
        match IoMode::Evented.resolve() {
            Ok(evented) => assert!(evented, "Ok(evented) must mean a poller exists"),
            Err(_) => assert!(!crate::net::poll::supported()),
        }
    }

    #[test]
    fn queue_caps_default_by_formula() {
        let cfg = ServeConfig::default();
        assert_eq!(cfg.resolved_batch_queue_cap(), (cfg.batch_max * 16).max(256));
        assert_eq!(cfg.resolved_dispatch_cap(), (cfg.http_workers * 16).max(128));
        let explicit = ServeConfig {
            batch_queue_cap: 7,
            dispatch_cap: 9,
            ..Default::default()
        };
        assert_eq!(explicit.resolved_batch_queue_cap(), 7);
        assert_eq!(explicit.resolved_dispatch_cap(), 9);
    }

    #[test]
    fn partial_json_keeps_defaults() {
        let cfg = ServeConfig::from_json(&Json::parse(r#"{"trees": 9}"#).unwrap()).unwrap();
        assert_eq!(cfg.trees, 9);
        assert_eq!(cfg.dataset, "iris");
        assert_eq!(cfg.http_workers, 4);
        assert!(cfg.simd, "SIMD kernels default on");
    }

    #[test]
    fn invalid_rejected() {
        assert!(ServeConfig::from_json(&Json::parse(r#"{"trees": 0}"#).unwrap()).is_err());
        // a replica serves a snapshot or a bundle, never both
        assert!(ServeConfig::from_json(
            &Json::parse(r#"{"snapshot": "m.fdd", "bundle": "f.fab"}"#).unwrap()
        )
        .is_err());
        // negative wraps to a huge usize; both directions must be caught
        assert!(
            ServeConfig::from_json(&Json::parse(r#"{"eval_threads": -1}"#).unwrap()).is_err()
        );
        assert!(
            ServeConfig::from_json(&Json::parse(r#"{"eval_threads": 500000}"#).unwrap()).is_err()
        );
        assert!(
            ServeConfig::from_json(&Json::parse(r#"{"tile_bytes": -1}"#).unwrap()).is_err()
        );
        assert!(
            ServeConfig::from_json(&Json::parse(r#"{"reply_timeout_ms": 0}"#).unwrap()).is_err()
        );
        assert!(
            ServeConfig::from_json(&Json::parse(r#"{"read_timeout_ms": 0}"#).unwrap()).is_err()
        );
        assert!(
            ServeConfig::from_json(&Json::parse(r#"{"batch_queue_cap": -1}"#).unwrap()).is_err()
        );
        assert!(
            ServeConfig::from_json(&Json::parse(r#"{"dispatch_cap": -1}"#).unwrap()).is_err()
        );
        assert!(ServeConfig::from_json(&Json::parse(r#"{"io_mode": "tokio"}"#).unwrap()).is_err());
        assert!(
            ServeConfig::from_json(&Json::parse(r#"{"conn_max_inflight": -1}"#).unwrap())
                .is_err()
        );
        assert!(
            ServeConfig::from_json(&Json::parse(r#"{"breaker_cooldown_ms": 0}"#).unwrap())
                .is_err()
        );
        // weights must be finite and positive (length is checked against
        // the model at startup)
        assert!(
            ServeConfig::from_json(&Json::parse(r#"{"class_weights": [1.0, 0.0]}"#).unwrap())
                .is_err()
        );
        assert!(
            ServeConfig::from_json(&Json::parse(r#"{"class_weights": [1.0, "x"]}"#).unwrap())
                .is_err()
        );
        // the fault spec is validated up front, not at arming time
        assert!(
            ServeConfig::from_json(&Json::parse(r#"{"fault": "warp_core:0.5:1"}"#).unwrap())
                .is_err()
        );
        assert!(
            ServeConfig::from_json(&Json::parse(r#"{"fault": "eval_slow:1.5:1"}"#).unwrap())
                .is_err()
        );
        assert!(
            ServeConfig::from_json(&Json::parse(r#"{"log_level": "loud"}"#).unwrap()).is_err()
        );
        assert!(
            ServeConfig::from_json(&Json::parse(r#"{"default_backend": "gpu"}"#).unwrap())
                .is_err()
        );
    }
}
